//! Property-based tests (hand-rolled generative harness — proptest is not
//! in the offline registry). Each property runs across many random seeds;
//! failures print the seed for reproduction.

use acdc::checkpoint::Checkpoint;
use acdc::coordinator::batcher::{BatchPolicy, Decision};
use acdc::dct::{naive_dct2, DctPlan};
use acdc::sell::acdc::{apply_perm, apply_perm_transpose, AcdcCascade, AcdcLayer};
use acdc::sell::init::DiagInit;
use acdc::sell::{materialize, LinearOp};
use acdc::tensor::Tensor;
use acdc::util::json::Json;
use acdc::util::rng::Pcg32;
use std::time::{Duration, Instant};

const TRIALS: usize = 60;

fn pow2(rng: &mut Pcg32, lo: u32, hi: u32) -> usize {
    1usize << (lo + rng.below(hi - lo + 1))
}

#[test]
fn prop_dct_roundtrip_and_energy() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(seed);
        let n = pow2(&mut rng, 1, 9); // 2..512
        let plan = DctPlan::new(n);
        let x0 = rng.normal_vec(n, 0.0, 1.0);
        let mut x = x0.clone();
        let mut scratch = vec![0.0; 2 * n];
        plan.dct2(&mut x, &mut scratch);
        let e0: f64 = x0.iter().map(|v| (*v as f64).powi(2)).sum();
        let e1: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((e0 - e1).abs() / e0.max(1e-9) < 1e-4, "seed={seed} n={n}");
        plan.dct3(&mut x, &mut scratch);
        for i in 0..n {
            assert!((x[i] - x0[i]).abs() < 1e-3, "seed={seed} n={n} i={i}");
        }
    }
}

#[test]
fn prop_dct2_matches_naive_oracle() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(1000 + seed);
        let n = pow2(&mut rng, 1, 7);
        let plan = DctPlan::new(n);
        let x0 = rng.normal_vec(n, 0.0, 2.0);
        let want = naive_dct2(&x0);
        let mut x = x0;
        let mut scratch = vec![0.0; 2 * n];
        plan.dct2(&mut x, &mut scratch);
        for i in 0..n {
            assert!((x[i] - want[i]).abs() < 1e-3, "seed={seed} n={n}");
        }
    }
}

#[test]
fn prop_acdc_fused_equals_multipass() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(2000 + seed);
        let n = pow2(&mut rng, 2, 8);
        let batch = 1 + rng.below(9) as usize;
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.3);
        layer.bias = rng.normal_vec(n, 0.0, 0.2);
        let x = Tensor::from_vec(&[batch, n], rng.normal_vec(batch * n, 0.0, 1.0));
        let f = layer.forward_fused(&x);
        let m = layer.forward_multipass(&x);
        assert!(f.max_abs_diff(&m) < 1e-3, "seed={seed} n={n} b={batch}");
    }
}

#[test]
fn prop_acdc_linearity_in_x() {
    // ACDC without bias is a linear operator: f(αx + βz) = αf(x) + βf(z).
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(3000 + seed);
        let n = pow2(&mut rng, 2, 7);
        let layer = AcdcLayer::random(n, &mut rng, 1.0, 0.4);
        let x = Tensor::from_vec(&[2, n], rng.normal_vec(2 * n, 0.0, 1.0));
        let z = Tensor::from_vec(&[2, n], rng.normal_vec(2 * n, 0.0, 1.0));
        let alpha = rng.uniform_in(-2.0, 2.0) as f32;
        let mut combo = x.clone();
        combo.scale(alpha);
        combo.axpy(1.0, &z);
        let lhs = layer.forward_fused(&combo);
        let mut rhs = layer.forward_fused(&x);
        rhs.scale(alpha);
        rhs.axpy(1.0, &layer.forward_fused(&z));
        assert!(lhs.max_abs_diff(&rhs) < 2e-3, "seed={seed} n={n}");
    }
}

#[test]
fn prop_materialized_cascade_equals_forward() {
    for seed in 0..(TRIALS / 2) as u64 {
        let mut rng = Pcg32::seeded(4000 + seed);
        let n = pow2(&mut rng, 2, 6);
        let k = 1 + rng.below(4) as usize;
        let cascade = AcdcCascade::linear(n, k, DiagInit::IDENTITY, &mut rng);
        let w = cascade.materialize();
        let x = Tensor::from_vec(&[3, n], rng.normal_vec(3 * n, 0.0, 1.0));
        let via = x.matmul(&w);
        let direct = cascade.forward(&x);
        assert!(via.max_abs_diff(&direct) < 2e-3, "seed={seed} n={n} k={k}");
    }
}

#[test]
fn prop_acdc_param_gradients_match_finite_differences() {
    for seed in 0..10u64 {
        let mut rng = Pcg32::seeded(5000 + seed);
        let n = pow2(&mut rng, 2, 4); // 4..16 (fd is O(N) loss evals)
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.2);
        layer.bias = rng.normal_vec(n, 0.0, 0.1);
        let x = Tensor::from_vec(&[2, n], rng.normal_vec(2 * n, 0.0, 1.0));
        let y = layer.forward_fused(&x);
        let (_, grads) = layer.backward(&x, &y);
        let loss = |l: &AcdcLayer| -> f64 {
            l.forward_fused(&x)
                .data()
                .iter()
                .map(|v| 0.5 * (*v as f64).powi(2))
                .sum()
        };
        let eps = 1e-3;
        let idx = rng.below(n as u32) as usize;
        let mut lp = layer.clone();
        lp.d[idx] += eps;
        let mut lm = layer.clone();
        lm.d[idx] -= eps;
        let fd = (loss(&lp) - loss(&lm)) / (2.0 * eps as f64);
        assert!(
            (grads.d[idx] as f64 - fd).abs() < 3e-2 * fd.abs().max(1.0),
            "seed={seed} n={n} idx={idx}: {} vs {fd}",
            grads.d[idx]
        );
    }
}

#[test]
fn prop_perm_transpose_inverts() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(6000 + seed);
        let n = 2 + rng.below(200) as usize;
        let rows = 1 + rng.below(5) as usize;
        let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
        let p = rng.permutation(n);
        let there = apply_perm(&x, &p);
        let back = apply_perm_transpose(&there, &p);
        assert!(back.max_abs_diff(&x) == 0.0, "seed={seed}");
    }
}

#[test]
fn prop_fastfood_and_circulant_are_linear() {
    for seed in 0..(TRIALS / 2) as u64 {
        let mut rng = Pcg32::seeded(7000 + seed);
        let n = pow2(&mut rng, 2, 7);
        let ff = acdc::sell::fastfood::FastfoodLayer::random(n, &mut rng);
        let circ = acdc::sell::circulant::CirculantLayer::random(n, &mut rng);
        let x = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let z = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        for op in [&ff as &dyn LinearOp, &circ as &dyn LinearOp] {
            let lhs = op.forward(&x.add(&z));
            let rhs = op.forward(&x).add(&op.forward(&z));
            let scale = lhs.norm().max(1.0);
            assert!(
                lhs.max_abs_diff(&rhs) / scale < 1e-3,
                "seed={seed} op={} n={n}",
                op.name()
            );
        }
    }
}

#[test]
fn prop_materialize_any_linearop_reproduces_forward() {
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(8000 + seed);
        let n = pow2(&mut rng, 2, 5);
        let ops: Vec<Box<dyn LinearOp>> = vec![
            Box::new(AcdcLayer::random(n, &mut rng, 1.0, 0.3)),
            Box::new(acdc::sell::fastfood::FastfoodLayer::random(n, &mut rng)),
            Box::new(acdc::sell::circulant::CirculantLayer::random(n, &mut rng)),
            Box::new(acdc::sell::lowrank::LowRankLayer::random(n, n / 2, &mut rng)),
        ];
        let x = Tensor::from_vec(&[2, n], rng.normal_vec(2 * n, 0.0, 1.0));
        for op in &ops {
            let w = materialize(op.as_ref());
            let via = x.matmul(&w);
            let direct = op.forward(&x);
            let scale = direct.norm().max(1.0);
            assert!(
                via.max_abs_diff(&direct) / scale < 1e-2,
                "seed={seed} op={} n={n}",
                op.name()
            );
        }
    }
}

#[test]
fn prop_batch_policy_invariants() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(9000 + seed);
        // random ascending bucket set
        let mut buckets: Vec<usize> = (0..1 + rng.below(4))
            .map(|_| 1usize << rng.below(8))
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        let max_wait = Duration::from_micros(1 + rng.below(10_000) as u64);
        let p = BatchPolicy::new(buckets.clone(), max_wait);
        let now = Instant::now();
        for _ in 0..20 {
            let qlen = rng.below(400) as usize;
            let age = Duration::from_micros(rng.below(20_000) as u64);
            let oldest = (qlen > 0).then(|| now - age);
            match p.decide(qlen, oldest, now) {
                Decision::Dispatch { bucket, take } => {
                    assert!(p.buckets.contains(&bucket), "seed={seed}");
                    assert!(take <= bucket, "seed={seed}");
                    assert!(take <= qlen, "seed={seed}");
                    assert!(take > 0, "seed={seed}");
                    // must only dispatch when full or deadline hit
                    assert!(
                        qlen >= p.max_bucket() || age >= max_wait,
                        "seed={seed} premature dispatch qlen={qlen} age={age:?}"
                    );
                }
                Decision::Wait(d) => {
                    assert!(d <= max_wait, "seed={seed}");
                    assert!(qlen < p.max_bucket(), "seed={seed}");
                }
            }
        }
    }
}

#[test]
fn prop_checkpoint_roundtrip_random_banks() {
    for seed in 0..30u64 {
        let mut rng = Pcg32::seeded(10_000 + seed);
        let mut ckpt = Checkpoint::new();
        let n_entries = 1 + rng.below(6) as usize;
        for e in 0..n_entries {
            let rank = rng.below(4) as usize;
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(8) as usize).collect();
            let numel: usize = shape.iter().product();
            ckpt.insert(
                &format!("bank{e}"),
                Tensor::from_vec(&shape, rng.normal_vec(numel, 0.0, 10.0)),
            );
        }
        let re = Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
        assert_eq!(ckpt, re, "seed={seed}");
    }
}

#[test]
fn prop_json_number_array_roundtrip() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Pcg32::seeded(11_000 + seed);
        let vals: Vec<Json> = (0..rng.below(20))
            .map(|_| Json::Num((rng.normal_with(0.0, 1e6) as i64) as f64))
            .collect();
        let v = Json::Arr(vals);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re, "seed={seed}");
    }
}

#[test]
fn prop_dense_equivalent_of_single_layer() {
    // acdc(x) == x @ W + b for the materialized (W, b) — the §3 linkage
    // between the SELL and the dense operator it represents.
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(12_000 + seed);
        let n = pow2(&mut rng, 2, 6);
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.3);
        layer.bias = rng.normal_vec(n, 0.0, 0.3);
        // W = forward of unit rows minus bias row; b = forward of zero row.
        let zero = Tensor::zeros(&[1, n]);
        let b_row = layer.forward_fused(&zero);
        let eye = Tensor::eye(n);
        let mut w = layer.forward_fused(&eye);
        for i in 0..n {
            for j in 0..n {
                let v = w.get2(i, j) - b_row.get2(0, j);
                w.set2(i, j, v);
            }
        }
        let x = Tensor::from_vec(&[3, n], rng.normal_vec(3 * n, 0.0, 1.0));
        let mut want = x.matmul(&w);
        for r in 0..3 {
            for j in 0..n {
                let v = want.get2(r, j) + b_row.get2(0, j);
                want.set2(r, j, v);
            }
        }
        let got = layer.forward_fused(&x);
        assert!(got.max_abs_diff(&want) < 2e-3, "seed={seed} n={n}");
    }
}
