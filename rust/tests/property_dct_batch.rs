//! Property tests for the batched SoA DCT/ACDC engine (hand-rolled
//! generative harness, matching tests/property_sell.rs).
//!
//! The acceptance grid from the batched-engine PR: both row drivers
//! (`DctPlan::dct2_rows/dct3_rows`, scalar pair path) and the SoA
//! [`BatchEngine`] must match the `naive_dct2`/`naive_dct3` f64 oracles
//! within 1e-4 across sizes {2, 8, 64, 512} × batches {1, 3, 16, 257},
//! and `dct3(dct2(x)) == x` must hold on the SoA path.

use acdc::dct::{naive_dct2, naive_dct3, BatchEngine, DctPlan, PlanCache};
use acdc::sell::acdc::AcdcLayer;
use acdc::tensor::Tensor;
use acdc::util::rng::Pcg32;

const SIZES: [usize; 4] = [2, 8, 64, 512];
const BATCHES: [usize; 4] = [1, 3, 16, 257];
const TOL: f32 = 1e-4;

#[test]
fn prop_scalar_dct2_rows_matches_oracle_grid() {
    let mut rng = Pcg32::seeded(100);
    for &n in &SIZES {
        let plan = DctPlan::new(n);
        for &rows in &BATCHES {
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            plan.dct2_rows(&mut data, rows);
            for r in 0..rows {
                let want = naive_dct2(&orig[r * n..(r + 1) * n]);
                for k in 0..n {
                    assert!(
                        (data[r * n + k] - want[k]).abs() < TOL,
                        "scalar dct2 n={n} rows={rows} r={r} k={k}: {} vs {}",
                        data[r * n + k],
                        want[k]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_scalar_dct3_rows_matches_oracle_grid() {
    let mut rng = Pcg32::seeded(200);
    for &n in &SIZES {
        let plan = DctPlan::new(n);
        for &rows in &BATCHES {
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            plan.dct3_rows(&mut data, rows);
            for r in 0..rows {
                let want = naive_dct3(&orig[r * n..(r + 1) * n]);
                for k in 0..n {
                    assert!(
                        (data[r * n + k] - want[k]).abs() < TOL,
                        "scalar dct3 n={n} rows={rows} r={r} k={k}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_soa_dct2_rows_matches_oracle_grid() {
    let mut rng = Pcg32::seeded(300);
    for &n in &SIZES {
        let engine = BatchEngine::for_size(n);
        for &rows in &BATCHES {
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            engine.dct2_rows(&mut data, rows);
            for r in 0..rows {
                let want = naive_dct2(&orig[r * n..(r + 1) * n]);
                for k in 0..n {
                    assert!(
                        (data[r * n + k] - want[k]).abs() < TOL,
                        "soa dct2 n={n} rows={rows} r={r} k={k}: {} vs {}",
                        data[r * n + k],
                        want[k]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_soa_dct3_rows_matches_oracle_grid() {
    let mut rng = Pcg32::seeded(400);
    for &n in &SIZES {
        let engine = BatchEngine::for_size(n);
        for &rows in &BATCHES {
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            engine.dct3_rows(&mut data, rows);
            for r in 0..rows {
                let want = naive_dct3(&orig[r * n..(r + 1) * n]);
                for k in 0..n {
                    assert!(
                        (data[r * n + k] - want[k]).abs() < TOL,
                        "soa dct3 n={n} rows={rows} r={r} k={k}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_soa_roundtrip_is_identity_grid() {
    let mut rng = Pcg32::seeded(500);
    for &n in &SIZES {
        let engine = BatchEngine::for_size(n);
        for &rows in &BATCHES {
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            engine.dct2_rows(&mut data, rows);
            engine.dct3_rows(&mut data, rows);
            for i in 0..rows * n {
                assert!(
                    (data[i] - orig[i]).abs() < TOL,
                    "soa roundtrip n={n} rows={rows} i={i}"
                );
            }
        }
    }
}

#[test]
fn prop_fused_engine_matches_scalar_fused_layer() {
    // The full batched ACDC⁻¹ (a/d/bias fused into the transform stages)
    // must agree with the scalar single-call kernel on random layers.
    for seed in 0..30u64 {
        let mut rng = Pcg32::seeded(600 + seed);
        let n = 1usize << (1 + rng.below(8)); // 2..256
        let rows = 1 + rng.below(20) as usize;
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.3);
        layer.bias = rng.normal_vec(n, 0.0, 0.2);
        let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
        let fused = layer.forward_fused(&x);
        let batched = layer.forward_batch(&x);
        assert!(
            fused.max_abs_diff(&batched) < 1e-3,
            "seed={seed} n={n} rows={rows}"
        );
    }
}

#[test]
fn prop_parallel_engine_is_bit_identical_to_serial() {
    // Panel splitting must not change results at all (same panels, same
    // order of operations within each panel).
    let pool = acdc::util::threadpool::ThreadPool::new(4);
    for seed in 0..15u64 {
        let mut rng = Pcg32::seeded(700 + seed);
        let n = 1usize << (3 + rng.below(5)); // 8..128
        let rows = 1 + rng.below(100) as usize;
        let engine = BatchEngine::new(PlanCache::get(n));
        let a = rng.normal_vec(n, 1.0, 0.2);
        let d = rng.normal_vec(n, 1.0, 0.2);
        let bias = rng.normal_vec(n, 0.0, 0.2);
        let x = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut serial = vec![0.0f32; rows * n];
        engine.acdc_rows(&a, &d, &bias, &x, &mut serial, rows);
        let mut parallel = vec![0.0f32; rows * n];
        engine.acdc_rows_parallel(&a, &d, &bias, &x, &mut parallel, rows, &pool);
        assert_eq!(serial, parallel, "seed={seed} n={n} rows={rows}");
    }
}
