//! Integration: the multi-tenant model registry end-to-end.
//!
//! Covers the registry acceptance path: checkpoint save → registry load →
//! infer is *bit-exact* with the direct in-memory model across every SELL
//! family, and the hot-swap contract under live HTTP traffic — zero
//! failed requests across a version swap, in-flight requests answered by
//! the version they were admitted against (version-tagged bias), new
//! admissions answered by the new version, and unload refusing with 409
//! while requests are pinned.

use acdc::config::{GatewayConfig, ServeConfig};
use acdc::gateway::http;
use acdc::gateway::Gateway;
use acdc::metrics::Registry;
use acdc::registry::{ModelRegistry, SellModel};
use acdc::sell::acdc::{AcdcCascade, AcdcLayer};
use acdc::sell::circulant::DiagonalCirculantCascade;
use acdc::sell::fastfood::FastfoodLayer;
use acdc::sell::init::DiagInit;
use acdc::sell::lowrank::LowRankLayer;
use acdc::tensor::Tensor;
use acdc::util::json::{obj, Json};
use acdc::util::rng::Pcg32;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("acdc_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Single-bucket template: every request is its own bucket-1 batch, so
/// the executor runs the exact same code path as a direct `[1, n]`
/// forward — the precondition for bit-exact comparison.
fn single_bucket_template() -> ServeConfig {
    ServeConfig {
        buckets: vec![1],
        max_wait_us: 100,
        workers: 1,
        queue_cap: 256,
        ..Default::default()
    }
}

#[test]
fn checkpoint_load_infer_roundtrip_is_bit_exact_across_sell_types() {
    let mut rng = Pcg32::seeded(42);
    let models: Vec<(&str, SellModel)> = vec![
        (
            "acdc",
            SellModel::Acdc(AcdcCascade::nonlinear(16, 3, DiagInit::CAFFENET, &mut rng)),
        ),
        (
            "fastfood",
            SellModel::Fastfood(FastfoodLayer::random(16, &mut rng)),
        ),
        (
            "lowrank",
            SellModel::LowRank(LowRankLayer::random(12, 3, &mut rng)),
        ),
        (
            "circulant",
            SellModel::Circulant(DiagonalCirculantCascade::init(
                16,
                2,
                DiagInit::CAFFENET,
                &mut rng,
            )),
        ),
    ];
    let dir = temp_dir("roundtrip");
    let registry = ModelRegistry::new(single_bucket_template(), Arc::new(Registry::new()));
    for (name, model) in &models {
        let path = dir.join(format!("{name}.ckpt"));
        model.to_checkpoint().unwrap().save(&path).unwrap();
        let v = registry.load_path(name, &path, None).unwrap();
        assert_eq!(v, 1);
    }
    for (name, model) in &models {
        let n = model.width();
        let handle = registry.resolve(name).unwrap();
        assert_eq!(handle.width(), n);
        assert_eq!(handle.kind(), *name);
        for trial in 0..3 {
            let x = rng.normal_vec(n, 0.0, 1.0);
            let got = handle
                .infer(x.clone(), Duration::from_secs(10))
                .unwrap_or_else(|e| panic!("{name} infer: {e}"));
            let want = model.forward(&Tensor::from_vec(&[1, n], x));
            assert_eq!(got.len(), n);
            for (i, (g, w)) in got.iter().zip(want.data()).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{name} trial {trial} output[{i}]: {g} != {w} (not bit-exact)"
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Identity ACDC layer plus a spectral bias tuned so `y = x + tag`
/// elementwise — the version tag readable off any response.
fn tagged_model(n: usize, tag: f32) -> SellModel {
    let mut layer = AcdcLayer::identity(n);
    if tag != 0.0 {
        let mut bias = vec![tag; n];
        let mut scratch = vec![0.0f32; 2 * n];
        // y = C⁻¹(C(x·1)·1 + bias) = x + C⁻¹(bias); choosing
        // bias = C([tag; n]) makes the added term exactly [tag; n].
        layer.plan().dct2(&mut bias, &mut scratch);
        layer.bias = bias;
    }
    SellModel::Acdc(AcdcCascade {
        layers: vec![layer],
        perms: None,
        relu: false,
        train_bias: false,
    })
}

struct Observed {
    sent_at: Instant,
    status: u16,
    version: i64,
    tag: f64,
}

fn infer_once(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    path: &str,
    n: usize,
) -> (u16, i64, f64) {
    let features = Json::Arr((0..n).map(|_| Json::Num(1.0)).collect());
    let body = obj(vec![("features", features)]).to_string();
    http::write_request(
        stream,
        "POST",
        path,
        &[("content-type", "application/json")],
        body.as_bytes(),
    )
    .expect("write");
    let resp = http::read_response(reader).expect("response");
    if resp.status != 200 {
        return (resp.status, -1, f64::NAN);
    }
    let v = Json::parse(resp.body_str()).unwrap();
    let version = v.get("version").and_then(|x| x.as_i64()).unwrap_or(-1);
    let out0 = v.get("output").unwrap().as_arr().unwrap()[0]
        .as_f64()
        .unwrap();
    // Probe row is all-ones and the model is identity + tag: out = 1 + tag.
    (resp.status, version, out0 - 1.0)
}

fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

const V1_TAG: f64 = 0.0;
const V2_TAG: f64 = 3.0;

#[test]
fn hot_swap_under_live_load_loses_nothing_and_partitions_by_version() {
    let n = 16;
    let dir = temp_dir("hotswap");
    let v2_path = dir.join("m_v2.ckpt");
    tagged_model(n, V2_TAG as f32)
        .to_checkpoint()
        .unwrap()
        .save(&v2_path)
        .unwrap();

    let template = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 200,
        workers: 2,
        queue_cap: 4_096,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let registry = Arc::new(ModelRegistry::new(
        template.clone(),
        Arc::new(Registry::new()),
    ));
    registry
        .load("m", tagged_model(n, V1_TAG as f32), None)
        .unwrap();
    let gateway = Gateway::start_registry(Arc::clone(&registry), template.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    let check_tag = |version: i64, tag: f64, ctx: &str| {
        let want = if version == 1 { V1_TAG } else { V2_TAG };
        assert!(
            (tag - want).abs() < 1e-3,
            "{ctx}: response claims v{version} but output tag is {tag} (want {want})"
        );
    };

    // Pre-swap: the default-route and named-route both answer on v1.
    let mut probe = TcpStream::connect(addr).unwrap();
    probe
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut probe_reader = BufReader::new(probe.try_clone().unwrap());
    let (status, version, tag) = infer_once(&mut probe, &mut probe_reader, "/v1/models/m/infer", n);
    assert_eq!((status, version), (200, 1));
    check_tag(version, tag, "pre-swap");

    // Live load: 4 keep-alive clients hammer the model across the swap.
    let clients: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(60)))
                    .unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let t_end = Instant::now() + Duration::from_millis(900);
                let mut seen = Vec::new();
                while Instant::now() < t_end {
                    let sent_at = Instant::now();
                    let (status, version, tag) =
                        infer_once(&mut stream, &mut reader, "/v1/models/m/infer", 16);
                    seen.push(Observed {
                        sent_at,
                        status,
                        version,
                        tag,
                    });
                }
                seen
            })
        })
        .collect();

    // Mid-run: hot-swap v2 in through the admin endpoint (the checkpoint
    // manifest path), then prove new admissions land on v2.
    std::thread::sleep(Duration::from_millis(250));
    let body = obj(vec![
        ("path", Json::Str(v2_path.display().to_string())),
        ("version", Json::Num(2.0)),
    ])
    .to_string();
    let resp = one_shot(addr, "POST", "/v1/admin/models/m/load", body.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let swapped_at = Instant::now();

    let (status, version, tag) = infer_once(&mut probe, &mut probe_reader, "/v1/models/m/infer", n);
    assert_eq!(
        (status, version),
        (200, 2),
        "admission after the swap must see the new version"
    );
    check_tag(version, tag, "post-swap");

    // Drain the load and audit every observation.
    let mut all: Vec<Observed> = Vec::new();
    for c in clients {
        all.extend(c.join().unwrap());
    }
    assert!(!all.is_empty());
    let mut v1_seen = 0u64;
    let mut v2_seen = 0u64;
    for (i, o) in all.iter().enumerate() {
        // Zero failed requests across the swap.
        assert_eq!(o.status, 200, "request {i} failed during hot swap");
        assert!(o.version == 1 || o.version == 2, "request {i}: v{}", o.version);
        // Every response's payload matches the version that claims it:
        // in-flight requests finished on the epoch they were admitted
        // against, never a torn mix of old and new parameters.
        check_tag(o.version, o.tag, &format!("request {i}"));
        // Requests admitted after the swap completed must be v2.
        if o.sent_at > swapped_at {
            assert_eq!(o.version, 2, "request {i} sent after swap answered by v1");
        }
        match o.version {
            1 => v1_seen += 1,
            _ => v2_seen += 1,
        }
    }
    assert!(v2_seen > 0, "load never observed the new version");
    // (v1_seen > 0 almost always too, but slow CI may start clients late;
    // the probe connection already proved v1 service pre-swap.)
    let _ = v1_seen;

    // Registry listing reflects the swap.
    let resp = one_shot(addr, "GET", "/v1/models", b"");
    let v = Json::parse(resp.body_str()).unwrap();
    let m0 = &v.get("models").unwrap().as_arr().unwrap()[0];
    assert_eq!(m0.get("version").unwrap().as_i64(), Some(2));
    assert_eq!(m0.get("kind").unwrap().as_str(), Some("acdc"));

    // Unload while busy: a pinned handle must make unload refuse with 409.
    let held = registry.resolve("m").unwrap();
    let resp = one_shot(addr, "POST", "/v1/admin/models/m/unload", b"");
    assert_eq!(resp.status, 409, "{}", resp.body_str());
    assert!(resp.body_str().contains("busy"), "{}", resp.body_str());
    drop(held);
    let resp = one_shot(addr, "POST", "/v1/admin/models/m/unload", b"");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let resp = one_shot(addr, "POST", "/v1/models/m/infer", b"{\"features\": [1.0]}");
    assert_eq!(resp.status, 404, "unloaded model must be gone");

    gateway.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn aliases_and_default_route_through_admin_endpoints() {
    let n = 8;
    let template = ServeConfig {
        buckets: vec![1],
        max_wait_us: 100,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let registry = Arc::new(ModelRegistry::new(
        template.clone(),
        Arc::new(Registry::new()),
    ));
    registry.load("alpha", tagged_model(n, 0.0), None).unwrap();
    registry
        .load("beta", tagged_model(n, V2_TAG as f32), None)
        .unwrap();
    let gateway = Gateway::start_registry(Arc::clone(&registry), template.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    // Alias "stable" → beta, then infer through the alias.
    let resp = one_shot(
        addr,
        "POST",
        "/v1/admin/aliases/stable",
        b"{\"target\": \"beta\"}",
    );
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let features = format!(
        "{{\"features\": [{}]}}",
        vec!["1.0"; n].join(", ")
    );
    let resp = one_shot(addr, "POST", "/v1/models/stable/infer", features.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    assert_eq!(v.get("model").unwrap().as_str(), Some("beta"));

    // Default starts at the first-loaded model, then is re-pointed.
    let resp = one_shot(addr, "POST", "/v1/infer", features.as_bytes());
    let v = Json::parse(resp.body_str()).unwrap();
    assert_eq!(v.get("model").unwrap().as_str(), Some("alpha"));
    let resp = one_shot(addr, "POST", "/v1/admin/default", b"{\"model\": \"beta\"}");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let resp = one_shot(addr, "POST", "/v1/infer", features.as_bytes());
    let v = Json::parse(resp.body_str()).unwrap();
    assert_eq!(v.get("model").unwrap().as_str(), Some("beta"));

    // Unknown model and bad admin bodies are typed errors.
    assert_eq!(
        one_shot(addr, "POST", "/v1/models/nope/infer", features.as_bytes()).status,
        404
    );
    assert_eq!(
        one_shot(addr, "POST", "/v1/admin/models/x/load", b"{}").status,
        400
    );
    assert_eq!(
        one_shot(addr, "GET", "/v1/models/alpha/infer", b"").status,
        405
    );

    gateway.shutdown();
}
