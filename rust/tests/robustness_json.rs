//! Robustness: `util::json` against adversarial and malformed input.
//!
//! The gateway feeds this parser untrusted request bodies, so every
//! malformed input must surface as `Err` — never a panic, never a stack
//! overflow, never a smuggled non-finite number or silently-dropped
//! duplicate key. Deterministic corpus cases plus a seeded
//! random-mutation fuzz loop over valid documents.

use acdc::util::json::{Json, MAX_DEPTH};
use acdc::util::rng::Pcg32;

#[test]
fn depth_cap_boundary_is_exact() {
    // Exactly MAX_DEPTH nests parse; one more is an error, arbitrarily
    // more (a ~40 KB bracket bomb) is an error rather than a blown stack.
    for depth in [MAX_DEPTH - 1, MAX_DEPTH] {
        let doc = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        assert!(Json::parse(&doc).is_ok(), "depth {depth} must parse");
    }
    for depth in [MAX_DEPTH + 1, 10_000] {
        let doc = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        let err = Json::parse(&doc).unwrap_err();
        assert!(err.msg.contains("nesting"), "depth {depth}: {err}");
    }
    // Mixed object/array nesting counts every container level.
    let mut doc = String::new();
    for _ in 0..(MAX_DEPTH / 2 + 1) {
        doc.push_str("{\"k\":[");
    }
    doc.push('1');
    for _ in 0..(MAX_DEPTH / 2 + 1) {
        doc.push_str("]}");
    }
    assert!(Json::parse(&doc).is_err(), "mixed nesting over the cap");
}

#[test]
fn truncated_and_invalid_escapes_error() {
    let cases = [
        r#""\"#,          // backslash then EOF
        r#""\u"#,         // \u then EOF
        r#""\u12"#,       // \u with too few digits then EOF
        r#""\u12G4""#,    // non-hex digit
        r#""\q""#,        // unknown escape
        r#""\ud800""#,    // lone high surrogate, string ends
        r#""\ud800\n""#,  // high surrogate followed by non-\u escape
        r#""\ud800\u0041""#, // high surrogate + non-low-surrogate
        r#""\udfff""#,    // lone low surrogate is an invalid codepoint
        "\"abc",          // unterminated plain string
        "\"ctrl:\u{1}\"", // raw control byte inside a string
    ];
    for c in cases {
        assert!(Json::parse(c).is_err(), "must reject: {c:?}");
    }
}

#[test]
fn non_finite_and_malformed_numbers_error() {
    // JSON has no NaN/Infinity literals, and overflowing literals must
    // not smuggle an inf into the pipeline.
    let bad = [
        "NaN", "nan", "Infinity", "-Infinity", "1e999", "-1e999", "1e+999", "--1", "1.",
        "1.e5", ".5", "+1", "0x10", "1e", "1e+", "-",
    ];
    for c in bad {
        assert!(Json::parse(c).is_err(), "must reject number: {c:?}");
    }
    // Large-but-representable magnitudes still parse.
    for ok in ["1e308", "-1.7976931348623157e308", "2.2250738585072014e-308"] {
        let v = Json::parse(ok).unwrap();
        assert!(v.as_f64().unwrap().is_finite());
    }
    // Sub-denormal literals underflow to 0.0 — finite, accepted.
    assert_eq!(Json::parse("1e-999").unwrap().as_f64(), Some(0.0));
}

#[test]
fn duplicate_keys_error_at_any_depth() {
    let cases = [
        r#"{"a": 1, "a": 2}"#,
        r#"{"a": 1, "b": {"x": 1, "x": 2}}"#,
        r#"{"a": [{"k": 0, "k": 1}]}"#,
    ];
    for c in cases {
        let err = Json::parse(c).unwrap_err();
        assert!(err.msg.contains("duplicate"), "{c}: {err}");
    }
    // Same key at sibling scopes is legal.
    assert!(Json::parse(r#"{"a": {"k": 1}, "b": {"k": 2}}"#).is_ok());
}

#[test]
fn assorted_malformed_documents_error() {
    let cases = [
        "", " ", "[", "]", "{", "}", ",", ":", "[1 2]", "[1,,2]", "[1,]", "{\"a\"}",
        "{\"a\":}", "{\"a\":1,}", "{1: 2}", "{\"a\" 1}", "truefalse", "nul", "[true,",
        "\"a\" \"b\"", "{\"a\": 1} extra", "\u{7f}", "[\"\\ud800\"]",
    ];
    for c in cases {
        assert!(Json::parse(c).is_err(), "must reject: {c:?}");
    }
}

/// Seeded random-mutation fuzz: mutate valid documents byte-wise and
/// require the parser to return (Ok or Err) without panicking; any
/// mutant that still parses must reserialize to a reparseable document.
#[test]
fn seeded_mutation_fuzz_never_panics() {
    let corpus: Vec<String> = vec![
        r#"{"features": [1.0, -2.5e3, 0.125], "rows": [[1, 2], [3, 4]]}"#.to_string(),
        r#"{"a": [1, 2, {"b": null, "c": "d\ne"}], "s": "héllo \u0041 😀"}"#.to_string(),
        r#"[true, false, null, 0, -1, 1e10, "nested", {"k": []}]"#.to_string(),
        format!("{}42{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1)),
        r#"{"path": "m.ckpt", "version": 3}"#.to_string(),
    ];
    // Bytes that steer mutants toward interesting parser states.
    const SPICE: &[u8] = b"{}[]\",:\\ue+-.0129 \t\n\x00\x80\xff";
    let mut rng = Pcg32::seeded(0xACDC);
    let mut parsed_ok = 0u32;
    for round in 0..4_000u32 {
        let base = corpus[rng.below(corpus.len() as u32) as usize].clone();
        let mut bytes = base.into_bytes();
        // 1–4 mutations: flip, insert, delete, truncate, or splice.
        let muts = 1 + rng.below(4) as usize;
        for _ in 0..muts {
            if bytes.is_empty() {
                break;
            }
            let pos = rng.below(bytes.len() as u32) as usize;
            match rng.below(5) {
                0 => bytes[pos] = SPICE[rng.below(SPICE.len() as u32) as usize],
                1 => bytes.insert(pos, SPICE[rng.below(SPICE.len() as u32) as usize]),
                2 => {
                    bytes.remove(pos);
                }
                3 => bytes.truncate(pos),
                _ => {
                    let b = bytes[rng.below(bytes.len() as u32) as usize];
                    bytes.insert(pos, b);
                }
            }
        }
        // The gateway hands the parser &str, so mutants go through the
        // same lossy-UTF-8 door a real request body would.
        let text = String::from_utf8_lossy(&bytes).into_owned();
        if let Ok(v) = Json::parse(&text) {
            parsed_ok += 1;
            let re = Json::parse(&v.to_string()).unwrap_or_else(|e| {
                panic!("round {round}: reserialized mutant failed to reparse: {e}\n{text}")
            });
            assert_eq!(v, re, "round {round}: unstable roundtrip");
        }
    }
    // Sanity: the corpus-driven fuzz isn't vacuous — some mutants parse.
    assert!(parsed_ok > 0, "no mutant ever parsed; fuzz harness is broken");
}
