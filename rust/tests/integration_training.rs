//! Integration: training orchestrators × real AOT artifacts.
//!
//! Covers the Figure-3 trainer (artifact path vs native cross-check) and
//! the MiniCaffeNet trainer (both FC variants), including checkpointing.

mod common;

use acdc::checkpoint::Checkpoint;
use acdc::data::regression::RegressionTask;
use acdc::data::synthimg::ImageCorpus;
use acdc::runtime::Engine;
use acdc::sell::init::DiagInit;
use acdc::trainer::{CnnTrainer, CnnVariant, Fig3NativeTrainer, Fig3Trainer, StepDecay};

#[test]
fn fig3_artifact_identity_init_trains_k4() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let task = RegressionTask::generate(2_000, 32, 1e-4, 1);
    let trainer = Fig3Trainer::new(&engine, 4).unwrap();
    let curve = trainer
        .run(&task, DiagInit::IDENTITY, 200, &StepDecay::constant(2e-4), 42)
        .unwrap();
    let ratio = curve.improvement_ratio().unwrap();
    assert!(ratio < 0.6, "identity init k=4 should train, ratio={ratio}");
}

#[test]
fn fig3_artifact_standard_init_stalls_deep() {
    // Figure 3 right panel: the near-zero init cannot train a deep cascade
    // (the forward signal and the gradients die). 16 layers.
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let task = RegressionTask::generate(2_000, 32, 1e-4, 2);
    let trainer = Fig3Trainer::new(&engine, 16).unwrap();
    let curve = trainer
        .run(&task, DiagInit::STANDARD, 120, &StepDecay::constant(2e-4), 43)
        .unwrap();
    let ratio = curve.improvement_ratio().unwrap_or(f64::NAN);
    assert!(
        !(ratio < 0.9), // no meaningful progress (NaN divergence also counts)
        "standard init k=16 unexpectedly trained: ratio={ratio}"
    );
}

#[test]
fn fig3_artifact_and_native_paths_agree_on_trainability() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let task = RegressionTask::generate(2_000, 32, 1e-4, 5);
    let artifact_curve = Fig3Trainer::new(&engine, 2)
        .unwrap()
        .run(&task, DiagInit::IDENTITY, 150, &StepDecay::constant(2e-4), 7)
        .unwrap();
    let mut native = Fig3NativeTrainer::new(32, 2, DiagInit::IDENTITY, 7);
    let native_curve = native.run(&task, 150, 250, &StepDecay::constant(2e-4));
    let (ra, rn) = (
        artifact_curve.improvement_ratio().unwrap(),
        native_curve.improvement_ratio().unwrap(),
    );
    // Same workload, same hyperparameters, independent implementations:
    // both must improve, within a loose band of each other.
    assert!(ra < 0.8 && rn < 0.8, "ra={ra} rn={rn}");
    assert!((ra - rn).abs() < 0.4, "paths disagree: ra={ra} rn={rn}");
}

#[test]
fn cnn_acdc_trainer_short_run_learns() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let train = ImageCorpus::generate(512, 0.15, 10);
    let test = ImageCorpus::generate(256, 0.15, 11);
    let mut t = CnnTrainer::new(&engine, CnnVariant::Acdc, 1).unwrap();
    let before = t.eval_on_corpus(&test).unwrap();
    let (curve, after) = t
        .run(&train, &test, 60, &StepDecay::constant(0.02), 10)
        .unwrap();
    assert!(curve.last().unwrap().is_finite());
    assert!(
        after.accuracy > before.accuracy,
        "accuracy did not improve: {} -> {}",
        before.accuracy,
        after.accuracy
    );
    assert!(after.accuracy > 0.2, "after 60 steps: {}", after.accuracy);
}

#[test]
fn cnn_dense_trainer_short_run_learns() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let train = ImageCorpus::generate(512, 0.15, 12);
    let test = ImageCorpus::generate(256, 0.15, 13);
    let mut t = CnnTrainer::new(&engine, CnnVariant::Dense, 2).unwrap();
    let (_, after) = t
        .run(&train, &test, 60, &StepDecay::constant(0.05), 10)
        .unwrap();
    assert!(after.accuracy > 0.2, "after 60 steps: {}", after.accuracy);
}

#[test]
fn cnn_param_counts_match_audit() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let acdc_t = CnnTrainer::new(&engine, CnnVariant::Acdc, 3).unwrap();
    let dense_t = CnnTrainer::new(&engine, CnnVariant::Dense, 3).unwrap();
    assert_eq!(
        acdc_t.param_count() as u64,
        acdc::sell::params::mini::acdc_total()
    );
    assert_eq!(
        dense_t.param_count() as u64,
        acdc::sell::params::mini::dense_total()
    );
    let reduction = dense_t.param_count() as f64 / acdc_t.param_count() as f64;
    assert!(reduction > 5.0, "MiniCaffeNet reduction {reduction}");
}

#[test]
fn cnn_checkpoint_roundtrip_preserves_eval() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let train = ImageCorpus::generate(256, 0.15, 14);
    let test = ImageCorpus::generate(256, 0.15, 15);
    let mut t = CnnTrainer::new(&engine, CnnVariant::Acdc, 4).unwrap();
    t.run(&train, &test, 20, &StepDecay::constant(0.02), 5)
        .unwrap();
    let eval1 = t.eval_on_corpus(&test).unwrap();
    let ckpt = t.checkpoint();

    // Persist and restore into a *fresh* trainer.
    let tmp = std::env::temp_dir().join(format!("acdc_cnn_{}.ckpt", std::process::id()));
    ckpt.save(&tmp).unwrap();
    let loaded = Checkpoint::load(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();

    let mut t2 = CnnTrainer::new(&engine, CnnVariant::Acdc, 999).unwrap();
    t2.restore(&loaded).unwrap();
    let eval2 = t2.eval_on_corpus(&test).unwrap();
    assert!(
        (eval1.loss - eval2.loss).abs() < 1e-5,
        "restored eval differs: {} vs {}",
        eval1.loss,
        eval2.loss
    );
    assert_eq!(eval1.accuracy, eval2.accuracy);
}

#[test]
fn fig3_trainer_rejects_unknown_k() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    assert!(Fig3Trainer::new(&engine, 5).is_err()); // only 1,2,4,8,16,32 lowered
}
