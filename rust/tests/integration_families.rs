//! Integration: the SELL-family training matrix over HTTP.
//!
//! For every `model_kind` (`acdc`, `fastfood`, `lowrank`, `circulant`)
//! the same acceptance path must hold: `POST /v1/models/{name}/train`
//! with the family knob → loss drops ≥ 5× from init → auto-promote →
//! the served model is *bit-exact* with the checkpoint manifest on disk
//! under 4 keep-alive clients with zero failed requests — and reloading
//! that manifest through `registry.load_path` serves identically.
//! Low-rank trains at a non-pow2 width (12) to pin the relaxation of
//! the transform families' power-of-two constraint end to end.
//!
//! A second test pins the typed-error matrix: unknown `model_kind`,
//! non-pow2 widths for the transform families, and `rank > width` are
//! all 400s, never panics.

use acdc::checkpoint::Checkpoint;
use acdc::config::{GatewayConfig, ServeConfig, TrainerConfig};
use acdc::gateway::http;
use acdc::gateway::Gateway;
use acdc::metrics::Registry;
use acdc::registry::{ModelRegistry, SellModel};
use acdc::tensor::Tensor;
use acdc::trainer::TrainerPool;
use acdc::util::json::{obj, Json};
use acdc::util::rng::Pcg32;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("acdc_it_families_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Single-bucket template: every request is its own bucket-1 batch, so
/// the executor runs the exact same code path as a direct `[1, n]`
/// forward — the precondition for bit-exact comparison.
fn template() -> ServeConfig {
    ServeConfig {
        buckets: vec![1],
        max_wait_us: 100,
        workers: 1,
        queue_cap: 4_096,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn gateway_with_trainer(tag: &str) -> (Gateway, Arc<ModelRegistry>, PathBuf) {
    let dir = temp_dir(tag);
    let template = template();
    let metrics = Arc::new(Registry::new());
    let registry = Arc::new(ModelRegistry::new(template.clone(), Arc::clone(&metrics)));
    let trainer_defaults = TrainerConfig {
        checkpoint_dir: dir.display().to_string(),
        ..TrainerConfig::default()
    };
    let trainer = Arc::new(TrainerPool::new(
        Arc::clone(&registry),
        metrics,
        trainer_defaults,
    ));
    let gateway =
        Gateway::start_registry_with_trainer(Arc::clone(&registry), trainer, template.gateway)
            .unwrap();
    (gateway, registry, dir)
}

fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

/// One family's training recipe: the mirror-validated SGD knobs from
/// `FamilyTuning`, expressed as an HTTP train body.
struct Family {
    kind: &'static str,
    width: usize,
    depth: usize,
    rank: usize,
    steps: usize,
    lr: f64,
    momentum: f64,
}

const FAMILIES: [Family; 4] = [
    Family { kind: "acdc", width: 16, depth: 2, rank: 0, steps: 2_500, lr: 5e-3, momentum: 0.0 },
    Family { kind: "fastfood", width: 16, depth: 1, rank: 0, steps: 8_000, lr: 1e-3, momentum: 0.9 },
    Family { kind: "lowrank", width: 12, depth: 1, rank: 6, steps: 2_500, lr: 5e-3, momentum: 0.0 },
    Family { kind: "circulant", width: 16, depth: 2, rank: 0, steps: 4_000, lr: 2e-3, momentum: 0.0 },
];

impl Family {
    fn train_body(&self) -> String {
        obj(vec![
            ("model_kind", Json::Str(self.kind.into())),
            ("width", Json::Num(self.width as f64)),
            ("depth", Json::Num(self.depth as f64)),
            ("rank", Json::Num(self.rank as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("batch", Json::Num(32.0)),
            ("rows", Json::Num(512.0)),
            ("lr", Json::Num(self.lr)),
            ("momentum", Json::Num(self.momentum)),
            ("seed", Json::Num(1.0)),
            ("checkpoint_every", Json::Num(0.0)),
            ("target_ratio", Json::Num(0.2)),
            ("promote", Json::Str("auto".into())),
        ])
        .to_string()
    }
}

struct JobView {
    state: String,
    loss: f64,
    first_loss: f64,
    promotions: i64,
    promoted_version: Option<i64>,
    last_checkpoint: Option<String>,
}

fn job_view(addr: SocketAddr, id: i64) -> JobView {
    let resp = one_shot(addr, "GET", "/v1/jobs", b"");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    let jobs = v.get("jobs").unwrap().as_arr().unwrap();
    let job = jobs
        .iter()
        .find(|j| j.get("id").and_then(|x| x.as_i64()) == Some(id))
        .unwrap_or_else(|| panic!("job {id} not listed"));
    JobView {
        state: job.get("state").and_then(|x| x.as_str()).unwrap().to_string(),
        loss: job.get("loss").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
        first_loss: job
            .get("first_loss")
            .and_then(|x| x.as_f64())
            .unwrap_or(f64::NAN),
        promotions: job.get("promotions").and_then(|x| x.as_i64()).unwrap_or(0),
        promoted_version: job.get("promoted_version").and_then(|x| x.as_i64()),
        last_checkpoint: job
            .get("last_checkpoint")
            .and_then(|x| x.as_str())
            .map(str::to_string),
    }
}

/// POST one infer and return (status, output f32 bits). JSON numbers
/// round-trip f64 exactly (shortest-representation formatting), and
/// every f32 is exactly representable as f64, so `output[i] as f32`
/// recovers the served f32 bit for bit.
fn infer_bits(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    path: &str,
    features: &[f32],
) -> (u16, Vec<u32>) {
    let body = obj(vec![(
        "features",
        Json::Arr(features.iter().map(|&f| Json::Num(f as f64)).collect()),
    )])
    .to_string();
    http::write_request(
        stream,
        "POST",
        path,
        &[("content-type", "application/json")],
        body.as_bytes(),
    )
    .expect("write");
    let resp = http::read_response(reader).expect("response");
    if resp.status != 200 {
        return (resp.status, Vec::new());
    }
    let v = Json::parse(resp.body_str()).unwrap();
    let bits = v
        .get("output")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| (x.as_f64().unwrap() as f32).to_bits())
        .collect();
    (resp.status, bits)
}

#[test]
fn http_train_matrix_every_family_promotes_and_serves_bit_exact() {
    let (gateway, registry, dir) = gateway_with_trainer("matrix");
    let addr = gateway.local_addr();

    for fam in &FAMILIES {
        let kind = fam.kind;
        // Submit the family's job; the model name is the family name.
        let resp = one_shot(
            addr,
            "POST",
            &format!("/v1/models/{kind}/train"),
            fam.train_body().as_bytes(),
        );
        assert_eq!(resp.status, 200, "{kind}: {}", resp.body_str());
        let id = Json::parse(resp.body_str())
            .unwrap()
            .get("job")
            .and_then(|x| x.as_i64())
            .expect("job id");

        // Train to completion; the ≥5× loss drop is the acceptance bar.
        let deadline = Instant::now() + Duration::from_secs(300);
        let done = loop {
            let view = job_view(addr, id);
            if view.state == "completed" {
                break view;
            }
            assert_eq!(view.state, "running", "{kind}: unexpected state");
            assert!(Instant::now() < deadline, "{kind}: training never completed");
            std::thread::sleep(Duration::from_millis(50));
        };
        assert!(
            done.loss <= done.first_loss * 0.2,
            "{kind}: loss {} did not drop 5x from {}",
            done.loss,
            done.first_loss
        );
        assert_eq!(done.promotions, 1, "{kind}: exactly one auto-promotion");
        assert_eq!(done.promoted_version, Some(1), "{kind}: promoted v1");

        // The promoted checkpoint manifest is the ground truth.
        let path = PathBuf::from(done.last_checkpoint.expect("checkpoint path"));
        let model = SellModel::from_checkpoint(&Checkpoint::load(&path).unwrap()).unwrap();
        assert_eq!(model.kind(), kind, "manifest records the family");
        assert_eq!(model.width(), fam.width);

        // 4 keep-alive clients, each with a precomputed bit-exact
        // expectation per request; zero failures allowed.
        let n = fam.width;
        let expected: Vec<Vec<(Vec<f32>, Vec<u32>)>> = (0..4)
            .map(|c| {
                let mut rng = Pcg32::seeded(500 + c as u64);
                (0..25)
                    .map(|_| {
                        let x = rng.normal_vec(n, 0.0, 1.0);
                        let want = model.forward(&Tensor::from_vec(&[1, n], x.clone()));
                        let bits = want.data().iter().map(|w| w.to_bits()).collect();
                        (x, bits)
                    })
                    .collect()
            })
            .collect();
        let clients: Vec<_> = expected
            .into_iter()
            .map(|reqs| {
                let path = format!("/v1/models/{kind}/infer");
                std::thread::spawn(move || {
                    let mut stream = TcpStream::connect(addr).expect("connect");
                    stream
                        .set_read_timeout(Some(Duration::from_secs(60)))
                        .unwrap();
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut failures = 0usize;
                    for (x, want) in &reqs {
                        let (status, got) = infer_bits(&mut stream, &mut reader, &path, x);
                        if status != 200 || got != *want {
                            failures += 1;
                        }
                    }
                    failures
                })
            })
            .collect();
        let failures: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(failures, 0, "{kind}: served output diverged from the manifest");

        // Reload the same manifest under a second name: identical serving.
        let reload = format!("{kind}_reload");
        assert_eq!(registry.load_path(&reload, &path, None).unwrap(), 1);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut rng = Pcg32::seeded(900);
        for _ in 0..5 {
            let x = rng.normal_vec(n, 0.0, 1.0);
            let want: Vec<u32> = model
                .forward(&Tensor::from_vec(&[1, n], x.clone()))
                .data()
                .iter()
                .map(|w| w.to_bits())
                .collect();
            let (status, got) =
                infer_bits(&mut stream, &mut reader, &format!("/v1/models/{reload}/infer"), &x);
            assert_eq!(status, 200, "{reload}");
            assert_eq!(got, want, "{reload}: reloaded checkpoint serves differently");
        }
    }

    gateway.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_spec_typed_error_matrix() {
    let (gateway, _registry, dir) = gateway_with_trainer("errors");
    let addr = gateway.local_addr();
    let submit = |name: &str, pairs: Vec<(&str, Json)>| -> http::ClientResponse {
        one_shot(
            addr,
            "POST",
            &format!("/v1/models/{name}/train"),
            obj(pairs).to_string().as_bytes(),
        )
    };

    // Unknown family name is a 400 naming the knob, not a panic.
    let resp = submit(
        "bad_kind",
        vec![("model_kind", Json::Str("dense".into())), ("width", Json::Num(16.0))],
    );
    assert_eq!(resp.status, 400, "{}", resp.body_str());
    assert!(resp.body_str().contains("model_kind"), "{}", resp.body_str());

    // Transform families require power-of-two widths…
    for kind in ["acdc", "fastfood", "circulant"] {
        let resp = submit(
            &format!("bad_{kind}"),
            vec![
                ("model_kind", Json::Str(kind.into())),
                ("width", Json::Num(48.0)),
            ],
        );
        assert_eq!(resp.status, 400, "{kind}: {}", resp.body_str());
    }

    // …low-rank does not, but rejects rank > width.
    let resp = submit(
        "bad_rank",
        vec![
            ("model_kind", Json::Str("lowrank".into())),
            ("width", Json::Num(12.0)),
            ("rank", Json::Num(24.0)),
        ],
    );
    assert_eq!(resp.status, 400, "{}", resp.body_str());
    let resp = submit(
        "ok_lowrank",
        vec![
            ("model_kind", Json::Str("lowrank".into())),
            ("width", Json::Num(12.0)),
            ("rank", Json::Num(6.0)),
            ("steps", Json::Num(10.0)),
            ("batch", Json::Num(8.0)),
            ("rows", Json::Num(32.0)),
            ("momentum", Json::Num(0.0)),
            ("promote", Json::Str("manual".into())),
        ],
    );
    assert_eq!(resp.status, 200, "non-pow2 lowrank: {}", resp.body_str());

    gateway.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
