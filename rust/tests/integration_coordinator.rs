//! Integration: serving coordinator end-to-end (PJRT-backed and native).

mod common;

use acdc::config::ServeConfig;
use acdc::serve::{Server, ServeParams};
use acdc::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

fn serve_cfg(dir: &std::path::Path) -> ServeConfig {
    ServeConfig {
        artifacts_dir: dir.to_string_lossy().into_owned(),
        buckets: vec![1, 8, 32, 128],
        max_wait_us: 1_000,
        workers: 1,
        queue_cap: 1_024,
        ..Default::default()
    }
}

#[test]
fn pjrt_server_answers_requests_with_log_probs() {
    let dir = require_artifacts!();
    let params = ServeParams::random(256, 12, 10, 1);
    let server = Server::start_pjrt(&serve_cfg(&dir), params, 256).unwrap();
    let mut rng = Pcg32::seeded(2);
    for _ in 0..5 {
        let out = server
            .infer(rng.normal_vec(256, 0.0, 1.0), Duration::from_secs(30))
            .unwrap();
        assert_eq!(out.len(), 10);
        let sum: f32 = out.iter().map(|v| v.exp()).sum();
        assert!((sum - 1.0).abs() < 1e-3, "not a log-softmax row: sum={sum}");
    }
    server.shutdown();
}

#[test]
fn pjrt_server_is_deterministic_per_row() {
    let dir = require_artifacts!();
    let params = ServeParams::random(256, 12, 10, 3);
    let server = Server::start_pjrt(&serve_cfg(&dir), params, 256).unwrap();
    let mut rng = Pcg32::seeded(4);
    let row = rng.normal_vec(256, 0.0, 1.0);
    let a = server.infer(row.clone(), Duration::from_secs(30)).unwrap();
    let b = server.infer(row, Duration::from_secs(30)).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-4);
    }
    server.shutdown();
}

#[test]
fn pjrt_server_handles_concurrent_burst_with_batching() {
    let dir = require_artifacts!();
    let params = ServeParams::random(256, 12, 10, 5);
    let mut cfg = serve_cfg(&dir);
    cfg.max_wait_us = 5_000; // encourage batch formation
    let server = Arc::new(Server::start_pjrt(&cfg, params, 256).unwrap());
    let mut rng = Pcg32::seeded(6);

    // Burst of 64 requests; all must be answered correctly.
    let mut rxs = vec![];
    for _ in 0..64 {
        rxs.push(server.submit(rng.normal_vec(256, 0.0, 1.0)).unwrap());
    }
    let mut batched = 0usize;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let out = resp.output.unwrap();
        assert_eq!(out.len(), 10);
        if resp.batch_size > 1 {
            batched += 1;
        }
    }
    assert!(
        batched > 0,
        "burst of 64 should produce at least one multi-row batch"
    );
    let report = server.metrics_report();
    assert!(report.contains("coordinator.accepted 64"), "{report}");
    Arc::try_unwrap(server).ok().map(|s| s.shutdown());
}

#[test]
fn pjrt_and_native_servers_conform_on_bucket_accounting() {
    // Native server (no artifacts needed) sanity: bucketed batch sizes
    // reported in responses must come from the configured bucket set.
    let mut rng = Pcg32::seeded(7);
    let cascade = acdc::sell::acdc::AcdcCascade::nonlinear(
        32,
        3,
        acdc::sell::init::DiagInit::CAFFENET,
        &mut rng,
    );
    let cfg = ServeConfig {
        buckets: vec![2, 4],
        max_wait_us: 500,
        workers: 2,
        queue_cap: 256,
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let mut rxs = vec![];
    for _ in 0..17 {
        rxs.push(server.submit(rng.normal_vec(32, 0.0, 1.0)).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(
            [2usize, 4].contains(&resp.batch_size),
            "unexpected bucket {}",
            resp.batch_size
        );
        resp.output.unwrap();
    }
    server.shutdown();
}
