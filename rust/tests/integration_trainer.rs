//! Integration: the train → checkpoint → registry → hot-swap loop.
//!
//! Covers the trainer acceptance path: a background job on the synthetic
//! eq.-(15) regression drops its loss 10×, writes a bit-exact checkpoint
//! manifest, and promotes it into the registry; a promotion under live
//! keep-alive HTTP load completes with **zero failed requests**, with
//! post-promote responses carrying the new version; and the full
//! `/v1/models/{name}/train` + `/v1/jobs` admin surface round-trips
//! (submit, watch, pause, resume, cancel, typed errors).

use acdc::checkpoint::Checkpoint;
use acdc::config::{GatewayConfig, ServeConfig, TrainerConfig};
use acdc::gateway::http;
use acdc::gateway::Gateway;
use acdc::metrics::Registry;
use acdc::registry::{ModelRegistry, SellModel};
use acdc::sell::acdc::AcdcCascade;
use acdc::sell::init::DiagInit;
use acdc::tensor::Tensor;
use acdc::trainer::{JobSpec, JobState, TrainerPool};
use acdc::util::json::{obj, Json};
use acdc::util::rng::Pcg32;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("acdc_it_trainer_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn template() -> ServeConfig {
    ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 200,
        workers: 2,
        queue_cap: 4_096,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// A job spec that converges fast and deterministically: shallow linear
/// cascade on a small task with the paper's identity-plus-noise init.
fn quick_spec(defaults: &TrainerConfig) -> JobSpec {
    JobSpec {
        width: 16,
        depth: 2,
        steps: 2_500,
        batch: 32,
        dataset_rows: 512,
        dataset_noise: 1e-4,
        lr: 5e-3,
        momentum: 0.0,
        seed: 1,
        checkpoint_every: 0,
        target_ratio: 0.1,
        promote_on_complete: true,
        ..JobSpec::from_config(defaults)
    }
}

#[test]
fn train_job_drops_loss_10x_and_promoted_checkpoint_serves_bit_exact() {
    let dir = temp_dir("tenx");
    let metrics = Arc::new(Registry::new());
    let registry = Arc::new(ModelRegistry::new(template(), Arc::clone(&metrics)));
    let defaults = TrainerConfig {
        checkpoint_dir: dir.display().to_string(),
        ..TrainerConfig::default()
    };
    let pool = TrainerPool::new(Arc::clone(&registry), metrics, defaults);
    let id = pool.submit("m", quick_spec(pool.defaults())).unwrap();
    let status = pool.join(id, Duration::from_secs(300)).expect("job finished");
    assert_eq!(status.state, JobState::Completed, "{:?}", status.error);
    // The acceptance criterion: loss dropped at least 10x.
    assert!(
        status.loss <= status.first_loss * 0.1,
        "loss {} did not drop 10x from {}",
        status.loss,
        status.first_loss
    );
    // Promotion loaded the checkpoint manifest into the registry…
    assert_eq!(status.promoted_version, Some(1));
    let handle = registry.resolve("m").unwrap();
    assert_eq!((handle.version(), handle.kind()), (1, "acdc"));
    // …and serving it is bit-exact with the manifest on disk (bucket-1
    // coordinator == direct [1, n] forward).
    let path = PathBuf::from(status.last_checkpoint.expect("checkpoint path"));
    let model = SellModel::from_checkpoint(&Checkpoint::load(&path).unwrap()).unwrap();
    let mut rng = Pcg32::seeded(77);
    for _ in 0..3 {
        let x = rng.normal_vec(16, 0.0, 1.0);
        let got = handle.infer(x.clone(), Duration::from_secs(10)).unwrap();
        let want = model.forward(&Tensor::from_vec(&[1, 16], x));
        for (g, w) in got.iter().zip(want.data()) {
            assert_eq!(g.to_bits(), w.to_bits(), "not bit-exact");
        }
    }
    drop(handle);
    pool.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

fn job_state(addr: SocketAddr, id: i64) -> (String, i64, Option<i64>) {
    let resp = one_shot(addr, "GET", "/v1/jobs", b"");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    let jobs = v.get("jobs").unwrap().as_arr().unwrap();
    let job = jobs
        .iter()
        .find(|j| j.get("id").and_then(|x| x.as_i64()) == Some(id))
        .unwrap_or_else(|| panic!("job {id} not listed"));
    (
        job.get("state").and_then(|x| x.as_str()).unwrap().to_string(),
        job.get("promotions").and_then(|x| x.as_i64()).unwrap_or(0),
        job.get("promoted_version").and_then(|x| x.as_i64()),
    )
}

fn gateway_with_trainer(tag: &str) -> (Gateway, Arc<ModelRegistry>, PathBuf) {
    let dir = temp_dir(tag);
    let template = template();
    let metrics = Arc::new(Registry::new());
    let registry = Arc::new(ModelRegistry::new(template.clone(), Arc::clone(&metrics)));
    let trainer_defaults = TrainerConfig {
        checkpoint_dir: dir.display().to_string(),
        ..TrainerConfig::default()
    };
    let trainer = Arc::new(TrainerPool::new(
        Arc::clone(&registry),
        metrics,
        trainer_defaults,
    ));
    let gateway =
        Gateway::start_registry_with_trainer(Arc::clone(&registry), trainer, template.gateway)
            .unwrap();
    (gateway, registry, dir)
}

#[test]
fn http_train_then_promote_under_live_load_loses_nothing() {
    let n = 16;
    let (gateway, registry, dir) = gateway_with_trainer("liveload");
    let addr = gateway.local_addr();
    // v1: an untrained cascade is already serving the model.
    let mut rng = Pcg32::seeded(42);
    registry
        .load(
            "live",
            SellModel::Acdc(AcdcCascade::linear(n, 2, DiagInit::IDENTITY, &mut rng)),
            None,
        )
        .unwrap();

    // Live load first: keep-alive clients hammer the model, so the
    // training job's promotion below provably lands under traffic.
    let stop = Arc::new(AtomicBool::new(false));
    let clients: Vec<_> = (0..4)
        .map(|_| {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(60)))
                    .unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let features = Json::Arr((0..n).map(|_| Json::Num(1.0)).collect());
                let body = obj(vec![("features", features)]).to_string();
                let mut seen: Vec<(u16, i64)> = Vec::new();
                while !stop.load(Ordering::Acquire) {
                    http::write_request(
                        &mut stream,
                        "POST",
                        "/v1/models/live/infer",
                        &[("content-type", "application/json")],
                        body.as_bytes(),
                    )
                    .expect("write");
                    let resp = http::read_response(&mut reader).expect("response");
                    let version = if resp.status == 200 {
                        Json::parse(resp.body_str())
                            .unwrap()
                            .get("version")
                            .and_then(|x| x.as_i64())
                            .unwrap_or(-1)
                    } else {
                        -1
                    };
                    seen.push((resp.status, version));
                }
                seen
            })
        })
        .collect();

    // With the load established, submit the training job over HTTP.
    std::thread::sleep(Duration::from_millis(250));
    let body = obj(vec![
        ("width", Json::Num(n as f64)),
        ("depth", Json::Num(2.0)),
        ("steps", Json::Num(2_500.0)),
        ("batch", Json::Num(32.0)),
        ("rows", Json::Num(512.0)),
        ("lr", Json::Num(5e-3)),
        ("momentum", Json::Num(0.0)),
        ("seed", Json::Num(1.0)),
        ("checkpoint_every", Json::Num(0.0)),
        ("target_ratio", Json::Num(0.1)),
        ("promote", Json::Str("auto".into())),
    ])
    .to_string();
    let resp = one_shot(addr, "POST", "/v1/models/live/train", body.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    let job_id = v.get("job").and_then(|x| x.as_i64()).expect("job id");

    // Wait for the job to complete (which auto-promotes v2), then let the
    // load observe the new version before stopping.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (state, promotions, version) = job_state(addr, job_id);
        if state == "completed" {
            assert_eq!(promotions, 1, "exactly one auto-promotion");
            assert_eq!(version, Some(2), "promotion hot-swapped v2");
            break;
        }
        assert!(
            state == "running",
            "unexpected mid-run state '{state}'"
        );
        assert!(Instant::now() < deadline, "training never completed");
        std::thread::sleep(Duration::from_millis(100));
    }
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Ordering::Release);

    let mut all: Vec<(u16, i64)> = Vec::new();
    for c in clients {
        all.extend(c.join().unwrap());
    }
    assert!(!all.is_empty());
    // Zero failed requests across training + promotion, and every
    // response was answered by a committed version.
    let mut v1_seen = 0u64;
    let mut v2_seen = 0u64;
    for (i, (status, version)) in all.iter().enumerate() {
        assert_eq!(*status, 200, "request {i} failed during train/promote");
        match version {
            1 => v1_seen += 1,
            2 => v2_seen += 1,
            other => panic!("request {i} saw version {other}"),
        }
    }
    // The load started before the job and outlived the promotion, so it
    // must have been served by both versions.
    assert!(v1_seen > 0, "load never observed the pre-training version");
    assert!(v2_seen > 0, "load never observed the promoted version");
    // A post-promotion probe is served by the trained version.
    let features = Json::Arr((0..n).map(|_| Json::Num(1.0)).collect());
    let body = obj(vec![("features", features)]).to_string();
    let resp = one_shot(addr, "POST", "/v1/models/live/infer", body.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    assert_eq!(v.get("version").and_then(|x| x.as_i64()), Some(2));
    assert_eq!(v.get("model").and_then(|x| x.as_str()), Some("live"));

    // Terminal-state controls are typed errors on the HTTP surface.
    let resp = one_shot(addr, "POST", &format!("/v1/jobs/{job_id}/resume"), b"");
    assert_eq!(resp.status, 409, "{}", resp.body_str());
    let resp = one_shot(addr, "POST", "/v1/jobs/999/pause", b"");
    assert_eq!(resp.status, 404, "{}", resp.body_str());
    // A second job for the same model is allowed once the first is done.
    let resp = one_shot(addr, "POST", "/v1/models/live/train", body_small(n).as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());

    gateway.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A tiny follow-up job body (used to prove resubmission after completion).
fn body_small(n: usize) -> String {
    obj(vec![
        ("width", Json::Num(n as f64)),
        ("depth", Json::Num(1.0)),
        ("steps", Json::Num(10.0)),
        ("batch", Json::Num(8.0)),
        ("rows", Json::Num(32.0)),
        ("momentum", Json::Num(0.0)),
        ("promote", Json::Str("manual".into())),
    ])
    .to_string()
}

#[test]
fn http_job_controls_pause_resume_cancel() {
    let (gateway, _registry, dir) = gateway_with_trainer("controls");
    let addr = gateway.local_addr();
    // A job that will not finish on its own.
    let body = obj(vec![
        ("width", Json::Num(16.0)),
        ("depth", Json::Num(2.0)),
        ("steps", Json::Num(5_000_000.0)),
        ("batch", Json::Num(32.0)),
        ("rows", Json::Num(256.0)),
        ("momentum", Json::Num(0.0)),
        ("checkpoint_every", Json::Num(0.0)),
        ("target_ratio", Json::Num(1e-12)),
        ("promote", Json::Str("manual".into())),
    ])
    .to_string();
    let resp = one_shot(addr, "POST", "/v1/models/bg/train", body.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let submitted = Json::parse(resp.body_str()).unwrap();
    let id = submitted.get("job").and_then(|x| x.as_i64()).unwrap();

    // Duplicate live job for the same model → 409.
    let resp = one_shot(addr, "POST", "/v1/models/bg/train", body.as_bytes());
    assert_eq!(resp.status, 409, "{}", resp.body_str());
    // Bad spec → 400 (width not a power of two must not panic the plan).
    let bad = obj(vec![("width", Json::Num(48.0))]).to_string();
    let resp = one_shot(addr, "POST", "/v1/models/other/train", bad.as_bytes());
    assert_eq!(resp.status, 400, "{}", resp.body_str());
    // Wrong method on the jobs listing → 405.
    let resp = one_shot(addr, "POST", "/v1/jobs", b"");
    assert_eq!(resp.status, 405, "{}", resp.body_str());

    let resp = one_shot(addr, "POST", &format!("/v1/jobs/{id}/pause"), b"");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    assert_eq!(job_state(addr, id).0, "paused");
    let resp = one_shot(addr, "POST", &format!("/v1/jobs/{id}/resume"), b"");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    assert_eq!(job_state(addr, id).0, "running");
    let resp = one_shot(addr, "POST", &format!("/v1/jobs/{id}/cancel"), b"");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (state, _, _) = job_state(addr, id);
        if state == "cancelled" {
            break;
        }
        assert!(Instant::now() < deadline, "cancel never landed");
        std::thread::sleep(Duration::from_millis(20));
    }
    gateway.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
