//! Zero-allocation steady state: a counting global allocator pins that
//! the `/v1/infer` keep-alive path performs **0 heap allocations per
//! request after warmup** — request parse (reused scratch), admission,
//! registry resolve, slot submit, batch formation (recycled buffers),
//! worker padding/execution (thread-local scratch), arena write-back and
//! response serialization (reused write buffers) included — **with
//! request tracing enabled at default (every-request) sampling**, so the
//! span capture, stage histograms and `x-trace-id` response header are
//! all inside the 0-alloc envelope. Both wire formats are measured: the
//! JSON body and the binary `application/x-acdc-f32` frame.
//!
//! Gated behind the `count-allocs` cargo feature so the allocator shim
//! never taxes ordinary test runs:
//! `cargo test --features count-allocs --test zero_alloc`.
//!
//! The client side of this test is deliberately raw: requests are
//! pre-rendered byte buffers and responses are parsed with fixed-size
//! stack buffers, so the measuring thread itself allocates nothing inside
//! the measured window (the counter is process-global).
#![cfg(feature = "count-allocs")]

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use acdc::config::{GatewayConfig, ServeConfig, TraceConfig};
use acdc::gateway::Gateway;
use acdc::metrics::Registry;
use acdc::registry::{ModelRegistry, SellModel};
use acdc::sell::acdc::AcdcCascade;
use acdc::sell::init::DiagInit;
use acdc::util::rng::Pcg32;

/// Counts every allocation and reallocation process-wide.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Send `req` and read one complete HTTP response using only the caller's
/// fixed buffer. Returns the response's total length. Panics on anything
/// but a 200 (the steady state must be all-success).
fn roundtrip(stream: &mut TcpStream, req: &[u8], buf: &mut [u8]) -> usize {
    stream.write_all(req).expect("write request");
    // Read until the header/body split, then drain content-length bytes.
    let mut filled = 0usize;
    let (head_end, content_len) = loop {
        let n = stream.read(&mut buf[filled..]).expect("read response");
        assert!(n > 0, "server closed mid-response");
        filled += n;
        if let Some(pos) = find_subslice(&buf[..filled], b"\r\n\r\n") {
            let head = &buf[..pos];
            assert!(
                head.starts_with(b"HTTP/1.1 200"),
                "non-200 in steady state: {}",
                String::from_utf8_lossy(head)
            );
            let cl = parse_content_length(head).expect("content-length header");
            break (pos + 4, cl);
        }
        assert!(filled < buf.len(), "response larger than client buffer");
    };
    let total = head_end + content_len;
    while filled < total {
        let n = stream.read(&mut buf[filled..]).expect("read body");
        assert!(n > 0, "server closed mid-body");
        filled += n;
    }
    total
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

/// `content-length: N` (the gateway always writes it lowercase).
fn parse_content_length(head: &[u8]) -> Option<usize> {
    let key = b"content-length:";
    let pos = find_subslice(head, key)?;
    let mut v = 0usize;
    let mut seen = false;
    for &c in &head[pos + key.len()..] {
        match c {
            b' ' if !seen => {}
            b'0'..=b'9' => {
                seen = true;
                v = v * 10 + (c - b'0') as usize;
            }
            _ => break,
        }
    }
    seen.then_some(v)
}

#[test]
fn keep_alive_infer_path_is_allocation_free_after_warmup() {
    const N: usize = 32;
    let mut rng = Pcg32::seeded(1);
    let cascade = AcdcCascade::nonlinear(N, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        // Serial engine buckets (< 32): the pooled fan-out path is the
        // one deliberate exception to the zero-alloc guarantee.
        buckets: vec![1, 8],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 256,
        ..Default::default()
    };
    let metrics = Arc::new(Registry::new());
    let registry = Arc::new(ModelRegistry::new(cfg.clone(), metrics));
    registry
        .load("demo", SellModel::Acdc(cascade), None)
        .expect("load model");
    let gateway = Gateway::start_registry(
        registry,
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 64,
            rate_rps: 0.0, // rate limiting off: nothing sheds in steady state
            request_timeout_ms: 30_000,
            // Tracing ON, every request sampled: the zero-alloc guarantee
            // must hold with span capture + trace-id header enabled.
            trace: TraceConfig {
                enabled: true,
                sample_every: 1,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .expect("gateway");

    // Pre-render both request shapes (1-row "features", 8-row "rows") so
    // the client allocates nothing inside the measured window.
    let mut single = String::from("{\"features\":[");
    for i in 0..N {
        if i > 0 {
            single.push(',');
        }
        single.push_str("0.125");
    }
    single.push_str("]}");
    let mut batch = String::from("{\"rows\":[");
    for r in 0..8 {
        if r > 0 {
            batch.push(',');
        }
        batch.push('[');
        for i in 0..N {
            if i > 0 {
                batch.push(',');
            }
            batch.push_str("-0.5");
        }
        batch.push(']');
    }
    batch.push_str("]}");
    let render = |body: &str| {
        format!(
            "POST /v1/infer HTTP/1.1\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .into_bytes()
    };
    let req_single = render(&single);
    let req_batch = render(&batch);

    let mut stream = TcpStream::connect(gateway.local_addr()).expect("connect");
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .ok();
    let mut buf = vec![0u8; 1 << 20];

    // Warmup: grow every reusable buffer (connection scratch, arena,
    // batcher queue + recycle pool, worker padding/output, cascade
    // scratch) and let every lazy init (thread parkers, waker queues)
    // happen.
    for i in 0..256 {
        let req = if i % 3 == 0 { &req_batch } else { &req_single };
        roundtrip(&mut stream, req, &mut buf);
    }

    // Tracing really is active: every sampled response carries the minted
    // trace id in its head (written from the retained head buffer).
    let len = roundtrip(&mut stream, &req_single, &mut buf);
    assert!(
        find_subslice(&buf[..len], b"x-trace-id: ").is_some(),
        "tracing must be on during the zero-alloc window: {}",
        String::from_utf8_lossy(&buf[..len.min(512)])
    );

    let before = ALLOCS.load(Ordering::Relaxed);
    let measured = 64usize;
    for i in 0..measured {
        let req = if i % 3 == 0 { &req_batch } else { &req_single };
        roundtrip(&mut stream, req, &mut buf);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state keep-alive inference must not allocate: \
         {delta} allocations across {measured} requests"
    );

    // The binary wire frame must live inside the same 0-alloc envelope
    // (same connection scratch, same arena, no float text on either
    // side). Both windows run in one test because the allocation counter
    // is process-global — a second concurrent #[test] would pollute it.
    let render_binary = |vals: &[f32]| {
        let mut frame = Vec::new();
        acdc::gateway::wire::write_binary_request(&mut frame, N, vals);
        let mut req = format!(
            "POST /v1/infer HTTP/1.1\r\ncontent-type: application/x-acdc-f32\r\ncontent-length: {}\r\n\r\n",
            frame.len()
        )
        .into_bytes();
        req.extend_from_slice(&frame);
        req
    };
    let bin_single = render_binary(&[0.125f32; N]);
    let bin_batch = render_binary(&[-0.5f32; 8 * N]);
    // Binary warmup: the parse/serialize branches differ from JSON even
    // though every reusable buffer is already grown.
    for i in 0..64 {
        let req = if i % 3 == 0 { &bin_batch } else { &bin_single };
        roundtrip(&mut stream, req, &mut buf);
    }
    let len = roundtrip(&mut stream, &bin_single, &mut buf);
    assert!(
        find_subslice(&buf[..len], b"x-trace-id: ").is_some(),
        "tracing must stay on during the binary zero-alloc window"
    );
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..measured {
        let req = if i % 3 == 0 { &bin_batch } else { &bin_single };
        roundtrip(&mut stream, req, &mut buf);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state binary-frame inference must not allocate: \
         {delta} allocations across {measured} requests"
    );
    drop(stream);
    gateway.shutdown();
}
