//! Integration: cluster mode end-to-end, multi-process.
//!
//! Spawns real `acdc shard` and `acdc router` processes (via
//! `CARGO_BIN_EXE_acdc`) on ephemeral ports and drives them over HTTP:
//!
//! * **rolling swap under live traffic** — a router fronting 3 shards
//!   (R=2) promotes a model version with 4 keep-alive clients hammering
//!   it: zero failed requests, per-upstream version tags monotonic (each
//!   shard swaps exactly once, in ring drain order), outputs always
//!   consistent with the version the response claims;
//! * **fault injection** — SIGKILL one replica mid-traffic: zero
//!   client-visible errors (transparent retry/hedge onto the surviving
//!   replica), the kill is visible as `acdc_cluster_shard{i}_healthy 0`
//!   in the router's `/metrics`, and a restarted shard is re-admitted
//!   after the `up_after` probe hysteresis and serves again.
//!
//! Children inherit `ACDC_GW_MODE`, so the CI cluster job runs this
//! whole file under both the reactor and threaded gateways. Run with
//! `--test-threads=1`: each test owns a process fleet.

use acdc::cluster::Ring;
use acdc::gateway::http;
use acdc::registry::SellModel;
use acdc::sell::acdc::{AcdcCascade, AcdcLayer};
use acdc::util::json::{obj, Json};
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

/// Vnodes the router uses (config default) — placement computed in-test
/// with the same ring must agree with the router's.
const VNODES: usize = 128;

const V1_TAG: f64 = 0.0;
const V2_TAG: f64 = 3.0;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("acdc_cluster_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Identity ACDC layer plus a spectral bias tuned so `y = x + tag`
/// elementwise — the version tag readable off any response body.
fn tagged_model(n: usize, tag: f32) -> SellModel {
    let mut layer = AcdcLayer::identity(n);
    if tag != 0.0 {
        let mut bias = vec![tag; n];
        let mut scratch = vec![0.0f32; 2 * n];
        layer.plan().dct2(&mut bias, &mut scratch);
        layer.bias = bias;
    }
    SellModel::Acdc(AcdcCascade {
        layers: vec![layer],
        perms: None,
        relu: false,
        train_bias: false,
    })
}

/// A spawned child that is SIGKILLed when the test (or a panic unwind)
/// drops it — no orphaned gateways after a failed assertion.
struct Proc(std::process::Child);

impl Drop for Proc {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

fn spawn(args: &[&str]) -> Proc {
    Proc(
        Command::new(env!("CARGO_BIN_EXE_acdc"))
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn acdc"),
    )
}

/// Poll the `--addr-file` a child writes once its listener is bound.
fn wait_addr(path: &Path) -> SocketAddr {
    let deadline = Instant::now() + Duration::from_secs(20);
    while Instant::now() < deadline {
        if let Ok(s) = std::fs::read_to_string(path) {
            if let Ok(a) = s.trim().parse() {
                return a;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("no address appeared in {}", path.display());
}

fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

/// Poll the router's `GET /v1/cluster` until shard `index` reports
/// `healthy == want` (index `None` = all shards), within 15s.
fn wait_health(router: SocketAddr, index: Option<usize>, want: bool) -> Json {
    let deadline = Instant::now() + Duration::from_secs(15);
    let mut last = Json::Null;
    while Instant::now() < deadline {
        let resp = one_shot(router, "GET", "/v1/cluster", b"");
        if resp.status == 200 {
            last = Json::parse(resp.body_str()).unwrap();
            let shards = last.get("shards").and_then(|s| s.as_arr()).unwrap();
            let ok = match index {
                Some(i) => shards[i].get("healthy").and_then(|h| h.as_bool()) == Some(want),
                None => shards
                    .iter()
                    .all(|s| s.get("healthy").and_then(|h| h.as_bool()) == Some(want)),
            };
            if ok {
                return last;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("cluster never reached healthy={want} for {index:?}; last: {last}");
}

/// One keep-alive inference exchange through the router. Returns
/// `(status, version, tag, upstream)`; non-200 responses carry
/// placeholder payload fields.
fn infer_once(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    n: usize,
) -> (u16, i64, f64, i64) {
    let features = Json::Arr((0..n).map(|_| Json::Num(1.0)).collect());
    let body = obj(vec![("features", features)]).to_string();
    http::write_request(
        stream,
        "POST",
        "/v1/models/m/infer",
        &[("content-type", "application/json")],
        body.as_bytes(),
    )
    .expect("write");
    let resp = http::read_response(reader).expect("response");
    let upstream = resp
        .header("x-acdc-upstream")
        .and_then(|s| s.parse().ok())
        .unwrap_or(-1);
    if resp.status != 200 {
        return (resp.status, -1, f64::NAN, upstream);
    }
    let v = Json::parse(resp.body_str()).unwrap();
    let version = v.get("version").and_then(|x| x.as_i64()).unwrap_or(-1);
    let out0 = v.get("output").unwrap().as_arr().unwrap()[0]
        .as_f64()
        .unwrap();
    // Probe row is all-ones, model is identity + tag: out = 1 + tag.
    (resp.status, version, out0 - 1.0, upstream)
}

struct Cluster {
    dir: PathBuf,
    shard_cfg: PathBuf,
    shards: Vec<Proc>,
    shard_addrs: Vec<SocketAddr>,
    _router: Proc,
    router_addr: SocketAddr,
    v2_path: PathBuf,
}

/// Boot a full fleet: v1/v2 checkpoints, 3 shards preloading v1, and a
/// router with R=2 and fast probe/hysteresis knobs for test turnaround.
fn boot(tag: &str, n: usize) -> Cluster {
    let dir = temp_dir(tag);
    let v1_path = dir.join("m_v1.ckpt");
    let v2_path = dir.join("m_v2.ckpt");
    tagged_model(n, V1_TAG as f32)
        .to_checkpoint()
        .unwrap()
        .save(&v1_path)
        .unwrap();
    tagged_model(n, V2_TAG as f32)
        .to_checkpoint()
        .unwrap()
        .save(&v2_path)
        .unwrap();

    let shard_cfg = dir.join("shard.toml");
    std::fs::write(
        &shard_cfg,
        format!(
            "[serve]\nbuckets = [1, 8]\nmax_wait_us = 200\nworkers = 2\n\n\
             [gateway]\naddr = \"127.0.0.1:0\"\n\n\
             [registry]\nmodels = [\"m={}\"]\ndefault_model = \"m\"\n",
            v1_path.display()
        ),
    )
    .unwrap();

    let mut shards = Vec::new();
    let mut shard_addrs = Vec::new();
    for i in 0..3 {
        let addr_file = dir.join(format!("shard{i}.addr"));
        std::fs::remove_file(&addr_file).ok();
        shards.push(spawn(&[
            "shard",
            "--config",
            shard_cfg.to_str().unwrap(),
            "--no-demo",
            "--addr-file",
            addr_file.to_str().unwrap(),
        ]));
        shard_addrs.push(wait_addr(&addr_file));
    }

    let router_cfg = dir.join("router.toml");
    let shard_list = shard_addrs
        .iter()
        .map(|a| format!("\"{a}\""))
        .collect::<Vec<_>>()
        .join(", ");
    std::fs::write(
        &router_cfg,
        format!(
            "[cluster]\nshards = [{shard_list}]\nreplication = 2\nvnodes = {VNODES}\n\
             probe_interval_ms = 100\ndown_after = 2\nup_after = 2\nhedge_min_ms = 100\n\n\
             [gateway]\naddr = \"127.0.0.1:0\"\n"
        ),
    )
    .unwrap();
    let router_addr_file = dir.join("router.addr");
    let router = spawn(&[
        "router",
        "--config",
        router_cfg.to_str().unwrap(),
        "--addr-file",
        router_addr_file.to_str().unwrap(),
    ]);
    let router_addr = wait_addr(&router_addr_file);
    wait_health(router_addr, None, true);

    Cluster {
        dir,
        shard_cfg,
        shards,
        shard_addrs,
        _router: router,
        router_addr,
        v2_path,
    }
}

/// The model's replica set in drain order, computed with the same ring
/// the router builds from the topology.
fn replica_set(c: &Cluster) -> Vec<usize> {
    let addrs: Vec<String> = c.shard_addrs.iter().map(|a| a.to_string()).collect();
    Ring::new(&addrs, VNODES).place("m", 2)
}

/// A client thread's observation log: (status, version, tag, upstream).
type Seen = Vec<(u16, i64, f64, i64)>;

fn client_loop(router: SocketAddr, n: usize, run_for: Duration) -> Seen {
    let mut stream = TcpStream::connect(router).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let t_end = Instant::now() + run_for;
    let mut seen = Vec::new();
    while Instant::now() < t_end {
        seen.push(infer_once(&mut stream, &mut reader, n));
    }
    seen
}

/// Every observation is a 200, its output tag matches the version the
/// response claims, and per upstream the version never goes backwards
/// (each shard swaps 1 → 2 exactly once).
fn check_seen(seen: &Seen, ctx: &str) {
    let mut last_version: HashMap<i64, i64> = HashMap::new();
    for &(status, version, tag, upstream) in seen {
        assert_eq!(status, 200, "{ctx}: client-visible failure");
        let want = if version == 1 { V1_TAG } else { V2_TAG };
        assert!(
            (tag - want).abs() < 1e-3,
            "{ctx}: response claims v{version} but output tag is {tag}"
        );
        let prev = last_version.entry(upstream).or_insert(version);
        assert!(
            version >= *prev,
            "{ctx}: upstream {upstream} went backwards v{prev} -> v{version}"
        );
        *prev = version;
    }
}

#[test]
fn rolling_swap_under_live_traffic_loses_nothing() {
    let n = 16;
    let c = boot("swap", n);
    let replicas = replica_set(&c);
    assert_eq!(replicas.len(), 2);

    // Pre-swap: v1 everywhere, answered by a shard in the replica set.
    let mut probe = TcpStream::connect(c.router_addr).unwrap();
    probe
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut probe_reader = BufReader::new(probe.try_clone().unwrap());
    let (status, version, tag, upstream) = infer_once(&mut probe, &mut probe_reader, n);
    assert_eq!((status, version), (200, 1));
    assert!((tag - V1_TAG).abs() < 1e-3);
    assert!(
        replicas.contains(&(upstream as usize)),
        "answered by shard {upstream}, expected one of {replicas:?}"
    );

    // 4 keep-alive clients hammer the model across the swap.
    let router = c.router_addr;
    let clients: Vec<_> = (0..4)
        .map(|_| std::thread::spawn(move || client_loop(router, n, Duration::from_millis(1500))))
        .collect();

    std::thread::sleep(Duration::from_millis(300));
    let body = obj(vec![
        ("path", Json::Str(c.v2_path.display().to_string())),
        ("version", Json::Num(2.0)),
    ])
    .to_string();
    let resp = one_shot(
        c.router_addr,
        "POST",
        "/v1/admin/cluster/models/m/load",
        body.as_bytes(),
    );
    assert_eq!(resp.status, 200, "rolling swap failed: {}", resp.body_str());
    let swap = Json::parse(resp.body_str()).unwrap();
    assert_eq!(swap.get("status").and_then(|s| s.as_str()), Some("swapped"));
    let done = swap.get("replicas").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(done.len(), replicas.len(), "one outcome per replica");
    for (entry, &want_shard) in done.iter().zip(&replicas) {
        // Outcomes are listed in ring order — the drain order.
        assert_eq!(
            entry.get("shard").and_then(|s| s.as_i64()),
            Some(want_shard as i64)
        );
        assert_eq!(entry.get("version").and_then(|v| v.as_i64()), Some(2));
    }

    for (i, cl) in clients.into_iter().enumerate() {
        let seen = cl.join().unwrap();
        assert!(!seen.is_empty());
        check_seen(&seen, &format!("client {i}"));
    }

    // Post-swap: the probe connection (admitted pre-swap) sees v2 now.
    let (status, version, tag, _) = infer_once(&mut probe, &mut probe_reader, n);
    assert_eq!((status, version), (200, 2), "post-swap admission on v2");
    assert!((tag - V2_TAG).abs() < 1e-3);

    // The swap is visible in the router's own telemetry.
    let metrics = one_shot(c.router_addr, "GET", "/metrics", b"");
    assert_eq!(metrics.status, 200);
    assert!(
        metrics.body_str().contains("acdc_cluster_rolling_swaps 1"),
        "rolling_swaps counter missing from /metrics"
    );
    wait_health(c.router_addr, None, true);

    std::fs::remove_dir_all(&c.dir).ok();
}

#[test]
fn sigkill_failover_is_invisible_and_restart_readmits() {
    let n = 16;
    let mut c = boot("kill", n);
    let replicas = replica_set(&c);
    let victim = replicas[0];
    let victim_addr = c.shard_addrs[victim];

    // Traffic across the kill: 4 keep-alive clients for ~2s, SIGKILL the
    // model's primary replica 500ms in. Every request must still answer
    // 200 — the router retries/hedges onto the surviving replica.
    let router = c.router_addr;
    let clients: Vec<_> = (0..4)
        .map(|_| std::thread::spawn(move || client_loop(router, n, Duration::from_millis(2000))))
        .collect();
    std::thread::sleep(Duration::from_millis(500));
    c.shards[victim].0.kill().expect("SIGKILL shard");

    for (i, cl) in clients.into_iter().enumerate() {
        let seen = cl.join().unwrap();
        assert!(!seen.is_empty());
        check_seen(&seen, &format!("client {i}"));
        // After the kill no response may come from the dead shard, and
        // the survivor must actually have answered.
        let survivor = replicas[1] as i64;
        assert!(
            seen.iter().any(|&(_, _, _, u)| u == survivor),
            "client {i} never reached surviving replica {survivor}"
        );
    }

    // The kill is observable: probes mark the shard down (hysteresis:
    // down_after=2 at 100ms) and the gauge flips in /metrics.
    wait_health(c.router_addr, Some(victim), false);
    let metrics = one_shot(c.router_addr, "GET", "/metrics", b"");
    assert!(
        metrics
            .body_str()
            .contains(&format!("acdc_cluster_shard{victim}_healthy 0")),
        "mark-down not visible in router /metrics"
    );

    // Restart the shard on its original topology address; `up_after`
    // consecutive probe successes re-admit it.
    c.shards[victim] = spawn(&[
        "shard",
        "--config",
        c.shard_cfg.to_str().unwrap(),
        "--no-demo",
        "--addr",
        &victim_addr.to_string(),
    ]);
    wait_health(c.router_addr, Some(victim), true);
    let metrics = one_shot(c.router_addr, "GET", "/metrics", b"");
    assert!(
        metrics
            .body_str()
            .contains(&format!("acdc_cluster_shard{victim}_healthy 1")),
        "re-admission not visible in router /metrics"
    );

    // The re-admitted fleet serves: drive enough fresh requests that the
    // least-loaded fan-out reaches the restarted replica again.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut stream = TcpStream::connect(c.router_addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut hit_restarted = false;
    while Instant::now() < deadline && !hit_restarted {
        let (status, version, _, upstream) = infer_once(&mut stream, &mut reader, n);
        assert_eq!((status, version), (200, 1), "post-restart inference");
        hit_restarted = upstream as usize == victim;
    }
    assert!(hit_restarted, "restarted shard never served a request");

    std::fs::remove_dir_all(&c.dir).ok();
}
