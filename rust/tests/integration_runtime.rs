//! Integration: PJRT engine × real AOT artifacts.
//!
//! These exercise the full python-AOT → HLO-text → rust-PJRT bridge with
//! the artifacts `make artifacts` produces. Each test is a no-op if the
//! artifacts are absent.

mod common;

use acdc::dct::DctPlan;
use acdc::runtime::values::HostValue;
use acdc::runtime::Engine;
use acdc::sell::acdc::AcdcLayer;
use acdc::tensor::Tensor;
use acdc::util::rng::Pcg32;
use std::sync::Arc;

#[test]
fn manifest_covers_all_experiments() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let m = engine.manifest();
    for exp in ["quickstart", "fig2_pjrt", "serve", "fig3", "table1"] {
        assert!(
            !m.by_experiment(exp).is_empty(),
            "no artifacts for experiment '{exp}'"
        );
    }
    assert_eq!(m.by_experiment("fig3").len(), 7); // k ∈ {1,2,4,8,16,32} + dense
    assert_eq!(m.by_experiment("serve").len(), 4); // buckets 1/8/32/128
}

#[test]
fn acdc_forward_artifacts_match_rust_reference_across_sizes() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let mut rng = Pcg32::seeded(7);
    for n in [256usize, 512] {
        let name = format!("acdc_fwd_b128_n{n}");
        let art = engine.load(&name).unwrap();
        let x = Tensor::from_vec(&[128, n], rng.normal_vec(128 * n, 0.0, 1.0));
        let a = rng.normal_vec(n, 1.0, 0.1);
        let d = rng.normal_vec(n, 1.0, 0.1);
        let b = rng.normal_vec(n, 0.0, 0.1);
        let out = art
            .call(&[
                HostValue::from_tensor(&x),
                HostValue::F32 { shape: vec![n], data: a.clone() },
                HostValue::F32 { shape: vec![n], data: d.clone() },
                HostValue::F32 { shape: vec![n], data: b.clone() },
            ])
            .unwrap();
        let layer = AcdcLayer::new(a, d, b, Arc::new(DctPlan::new(n)));
        let want = layer.forward_fused(&x);
        let diff = out[0].to_tensor().max_abs_diff(&want);
        assert!(diff < 1e-2, "n={n}: pjrt vs reference diff {diff}");
    }
}

#[test]
fn serve_artifacts_agree_across_buckets() {
    // The same feature row must produce the same log-probs whether it is
    // served through the b=1 or the b=8 executable (padding must not leak).
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let b1 = engine.load("serve_cascade_b1_n256_k12").unwrap();
    let b8 = engine.load("serve_cascade_b8_n256_k12").unwrap();
    let (k, n, classes) = (12usize, 256usize, 10usize);
    let mut rng = Pcg32::seeded(11);
    let a = rng.normal_vec(k * n, 1.0, 0.061);
    let d = rng.normal_vec(k * n, 1.0, 0.061);
    let bias = vec![0.0f32; k * n];
    let cls_w = rng.normal_vec(n * classes, 0.0, 0.05);
    let cls_b = vec![0.0f32; classes];
    let row = rng.normal_vec(n, 0.0, 1.0);

    let params = |feat: HostValue| {
        vec![
            HostValue::F32 { shape: vec![k, n], data: a.clone() },
            HostValue::F32 { shape: vec![k, n], data: d.clone() },
            HostValue::F32 { shape: vec![k, n], data: bias.clone() },
            HostValue::F32 { shape: vec![n, classes], data: cls_w.clone() },
            HostValue::F32 { shape: vec![classes], data: cls_b.clone() },
            feat,
        ]
    };

    let out1 = b1
        .call(&params(HostValue::F32 {
            shape: vec![1, n],
            data: row.clone(),
        }))
        .unwrap();
    let mut padded = row.clone();
    padded.extend(vec![0.0; 7 * n]);
    let out8 = b8
        .call(&params(HostValue::F32 {
            shape: vec![8, n],
            data: padded,
        }))
        .unwrap();
    let lp1 = out1[0].as_f32();
    let lp8 = &out8[0].as_f32()[..classes];
    for (x, y) in lp1.iter().zip(lp8) {
        assert!((x - y).abs() < 1e-3, "bucket mismatch: {x} vs {y}");
    }
    // log-softmax rows must exponentiate-sum to 1
    let sum: f32 = lp1.iter().map(|v| v.exp()).sum();
    assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");
}

#[test]
fn fig3_step_artifact_reduces_loss_and_updates_params() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let art = engine.load("fig3_step_k2").unwrap();
    let (k, n, batch) = (2usize, 32usize, 250usize);
    let task = acdc::data::regression::RegressionTask::generate(batch, n, 1e-4, 3);
    let mut rng = Pcg32::seeded(5);
    let mut a = HostValue::F32 { shape: vec![k, n], data: rng.normal_vec(k * n, 1.0, 0.1) };
    let mut d = HostValue::F32 { shape: vec![k, n], data: rng.normal_vec(k * n, 1.0, 0.1) };
    let x = HostValue::from_tensor(&task.x);
    let y = HostValue::from_tensor(&task.y);
    let mut losses = vec![];
    for _ in 0..40 {
        let out = art
            .call(&[
                a.clone(),
                d.clone(),
                x.clone(),
                y.clone(),
                HostValue::scalar_f32(2e-4),
            ])
            .unwrap();
        a = out[0].clone();
        d = out[1].clone();
        losses.push(out[2].scalar());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        *losses.last().unwrap() < losses[0] * 0.9,
        "no improvement: {:?}",
        &losses[..3]
    );
}

#[test]
fn fig3_dense_step_converges_toward_bayes_floor() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let art = engine.load("fig3_dense_step").unwrap();
    let (n, batch) = (32usize, 250usize);
    let task = acdc::data::regression::RegressionTask::generate(batch, n, 1e-4, 9);
    let mut w = HostValue::F32 {
        shape: vec![n, n],
        data: vec![0.0; n * n],
    };
    let x = HostValue::from_tensor(&task.x);
    let y = HostValue::from_tensor(&task.y);
    let mut last = f64::INFINITY;
    for _ in 0..300 {
        let out = art
            .call(&[w.clone(), x.clone(), y.clone(), HostValue::scalar_f32(0.02)])
            .unwrap();
        w = out[0].clone();
        last = out[1].scalar();
    }
    // Bayes floor is n·noise_var ≈ 32e-4; full-batch GD should be well
    // under 1.0 by 300 steps.
    assert!(last < 1.0, "dense loss stuck at {last}");
}

#[test]
fn engine_caches_compilations() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    assert_eq!(engine.cached_count(), 0);
    let _ = engine.load("fig3_step_k1").unwrap();
    let _ = engine.load("fig3_step_k1").unwrap();
    let _ = engine.load("fig3_dense_step").unwrap();
    assert_eq!(engine.cached_count(), 2);
}

#[test]
fn manifest_shapes_match_paper_configuration() {
    let dir = require_artifacts!();
    let engine = Engine::open(&dir).unwrap();
    let m = engine.manifest();
    // Fig 3: X is [250, 32] minibatches of the 10000×32 problem.
    let f = m.get("fig3_step_k16").unwrap();
    assert_eq!(f.inputs[f.input_index("x").unwrap()].shape, vec![250, 32]);
    assert_eq!(f.inputs[f.input_index("a_stack").unwrap()].shape, vec![16, 32]);
    // CNN: 12-layer ACDC at width 256 (paper §6.2 scaled per DESIGN S2).
    let c = m.get("cnn_acdc_train_step").unwrap();
    assert_eq!(c.inputs[c.input_index("a_stack").unwrap()].shape, vec![12, 256]);
    assert_eq!(c.tag_usize("k"), Some(12));
}

#[test]
fn hlo_text_contains_real_constants() {
    // Regression test for the print_large_constants pitfall: elided
    // constants (`constant({...})`) silently parse as zeros in
    // xla_extension 0.5.1 and zero out the DCT matrices.
    let dir = require_artifacts!();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("txt") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            !text.contains("constant({...})"),
            "{} contains elided constants",
            path.display()
        );
    }
}
