//! Shared helpers for integration tests.

use std::path::PathBuf;

/// The artifacts directory, if `make artifacts` has been run.
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Skip-or-open helper: integration tests are no-ops without artifacts
/// (CI runs `make artifacts` first; unit tests never need it).
#[macro_export]
macro_rules! require_artifacts {
    () => {
        match common::artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}
