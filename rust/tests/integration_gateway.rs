//! Integration: the network gateway end-to-end over ephemeral ports.
//!
//! Covers the acceptance path for the serving gateway: concurrent
//! `POST /v1/infer` traffic against a native-executor server, the load
//! generator's latency/shed report under saturation, the
//! queue-full → 503 → drain contract, the HTTP framing regressions
//! (duplicate `Content-Length`, `Connection` token lists), stalled-reader
//! eviction and mass idle keep-alive on the epoll reactor, and the
//! binary-wire-format ↔ JSON bit-identity contract.

use acdc::config::{GatewayConfig, ServeConfig};
use acdc::coordinator::worker::{BatchExecutor, ExecutorFactory};
use acdc::gateway::http;
use acdc::gateway::loadgen::{ArrivalMode, LoadgenConfig};
use acdc::gateway::wire;
use acdc::gateway::Gateway;
use acdc::sell::acdc::AcdcCascade;
use acdc::sell::init::DiagInit;
use acdc::serve::Server;
use acdc::tensor::Tensor;
use acdc::util::json::Json;
use acdc::util::rng::Pcg32;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// One HTTP exchange on a fresh connection.
fn one_shot(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> http::ClientResponse {
    one_shot_typed(addr, method, path, "application/json", body)
}

/// One HTTP exchange on a fresh connection, with an explicit
/// `Content-Type` (the binary wire frame negotiates through it).
fn one_shot_typed(
    addr: SocketAddr,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", content_type)],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

/// Write raw request bytes and read one response — for wire-level cases
/// `http::write_request` cannot produce (duplicate headers, token lists).
fn raw_exchange(stream: &mut TcpStream, req: &[u8]) -> http::ClientResponse {
    use std::io::Write;
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    stream.write_all(req).expect("write raw request");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::read_response(&mut reader).expect("read response")
}

fn infer_body(row: &[f32]) -> Vec<u8> {
    let features = Json::Arr(row.iter().map(|v| Json::Num(*v as f64)).collect());
    acdc::util::json::obj(vec![("features", features)])
        .to_string()
        .into_bytes()
}

#[test]
fn gateway_serves_concurrent_infer_traffic_end_to_end() {
    let n = 32;
    let mut rng = Pcg32::seeded(11);
    let cascade = AcdcCascade::nonlinear(n, 4, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 300,
        workers: 2,
        queue_cap: 512,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade.clone());
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    // 8 concurrent clients, 5 keep-alive requests each.
    let handles: Vec<_> = (0..8)
        .map(|client| {
            std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(100 + client);
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(60)))
                    .unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                for _ in 0..5 {
                    let row = rng.normal_vec(32, 0.0, 1.0);
                    http::write_request(
                        &mut stream,
                        "POST",
                        "/v1/infer",
                        &[("content-type", "application/json")],
                        &infer_body(&row),
                    )
                    .expect("write");
                    let resp = http::read_response(&mut reader).expect("response");
                    assert_eq!(resp.status, 200, "{}", resp.body_str());
                    let v = Json::parse(resp.body_str()).unwrap();
                    assert_eq!(v.get("output").unwrap().as_arr().unwrap().len(), 32);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // One more request whose output we can check numerically.
    let mut rng = Pcg32::seeded(500);
    let row = rng.normal_vec(n, 0.0, 1.0);
    let resp = one_shot(addr, "POST", "/v1/infer", &infer_body(&row));
    assert_eq!(resp.status, 200);
    let v = Json::parse(resp.body_str()).unwrap();
    let got: Vec<f64> = v
        .get("output")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    let want = cascade.forward(&Tensor::from_vec(&[1, n], row));
    for (g, w) in got.iter().zip(want.data()) {
        assert!((g - *w as f64).abs() < 1e-3, "gateway output drifted");
    }

    // Health and metrics endpoints.
    let health = one_shot(addr, "GET", "/healthz", b"");
    assert_eq!(health.status, 200);
    let hv = Json::parse(health.body_str()).unwrap();
    assert_eq!(hv.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(hv.get("width").unwrap().as_usize(), Some(n));

    let metrics = one_shot(addr, "GET", "/metrics", b"");
    assert_eq!(metrics.status, 200);
    let text = metrics.body_str();
    assert!(text.contains("acdc_gateway_admitted"), "{text}");
    assert!(text.contains("acdc_coordinator_accepted"), "{text}");
    assert!(text.contains("acdc_gateway_request_ns_count"), "{text}");

    // Unknown routes and wrong methods are typed errors.
    assert_eq!(one_shot(addr, "GET", "/nope", b"").status, 404);
    assert_eq!(one_shot(addr, "GET", "/v1/infer", b"").status, 405);
    assert_eq!(one_shot(addr, "POST", "/v1/infer", b"not json").status, 400);

    gateway.shutdown();
}

#[test]
fn gateway_batch_rows_request_answers_every_row() {
    let n = 16;
    let mut rng = Pcg32::seeded(21);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 4],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let rows: Vec<Json> = (0..3)
        .map(|_| {
            let vals = rng.normal_vec(n, 0.0, 1.0);
            Json::Arr(vals.iter().map(|v| Json::Num(*v as f64)).collect())
        })
        .collect();
    let body = acdc::util::json::obj(vec![("rows", Json::Arr(rows))]).to_string();
    let resp = one_shot(gateway.local_addr(), "POST", "/v1/infer", body.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    assert_eq!(v.get("rows").unwrap().as_usize(), Some(3));
    let outputs = v.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(outputs.len(), 3);
    for out in outputs {
        assert_eq!(out.as_arr().unwrap().len(), n);
    }
    gateway.shutdown();
}

/// Echo executor with a configurable service time, to saturate tiny
/// queues deterministically.
struct SlowEcho {
    n: usize,
    delay: Duration,
}

impl BatchExecutor for SlowEcho {
    fn width(&self) -> usize {
        self.n
    }
    fn out_width(&self) -> usize {
        self.n
    }
    fn execute_into(
        &mut self,
        _bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(padded);
        Ok(())
    }
}

fn slow_gateway(n: usize, delay: Duration, queue_cap: usize, timeout_ms: u64) -> Gateway {
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 1,
        workers: 1,
        queue_cap,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 64,
            request_timeout_ms: timeout_ms,
            drain_timeout_ms: 30_000,
            ..Default::default()
        },
        ..Default::default()
    };
    let factory: ExecutorFactory =
        Arc::new(move || Ok(Box::new(SlowEcho { n, delay }) as Box<dyn BatchExecutor>));
    let server = Server::start_custom(&cfg, n, factory);
    Gateway::start(server, cfg.gateway.clone()).unwrap()
}

#[test]
fn loadgen_reports_latency_and_nonzero_sheds_past_queue_cap() {
    // 1 worker × 10ms service time ≈ 100 req/s capacity; 12 closed-loop
    // clients against queue_cap 2 must shed hard.
    let gateway = slow_gateway(8, Duration::from_millis(10), 2, 10_000);
    let addr = gateway.local_addr();
    let report = acdc::gateway::loadgen::run(&LoadgenConfig {
        addr: addr.to_string(),
        mode: ArrivalMode::Closed,
        concurrency: 12,
        duration: Duration::from_millis(1_500),
        width: 8,
        rows_mix: vec![1],
        timeout: Duration::from_secs(30),
        seed: 3,
        binary: false,
        ..Default::default()
    })
    .unwrap();

    assert!(report.ok > 0, "some requests must succeed: {report:?}");
    assert!(
        report.shed > 0,
        "driving 12 clients past queue_cap=2 must shed: {report:?}"
    );
    assert!(report.errors == 0, "sheds are not errors: {report:?}");
    assert!(report.p50_ms > 0.0 && report.p99_ms >= report.p50_ms, "{report:?}");
    assert!(report.goodput_rps() > 0.0);
    // JSON report carries the same story.
    let j = report.to_json();
    assert!(j.get("shed").unwrap().as_f64().unwrap() > 0.0);

    // The gateway's own accounting saw the queue-full sheds.
    let metrics = one_shot(addr, "GET", "/metrics", b"");
    let text = metrics.body_str();
    let shed_line = text
        .lines()
        .find(|l| l.starts_with("acdc_gateway_shed_queue_full "))
        .unwrap_or_else(|| panic!("no shed counter in:\n{text}"));
    let shed_count: f64 = shed_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    assert!(shed_count > 0.0, "{shed_line}");

    gateway.shutdown();
}

#[test]
fn queue_full_maps_to_503_and_drain_completes_inflight() {
    // Pipeline capacity with buckets [1], 1 worker, queue_cap 2 and a
    // bounded batch channel (2 × workers): 6 requests absorbed; the 7th
    // must see 503 + Retry-After while the first is still executing.
    let delay = Duration::from_millis(600);
    let gateway = slow_gateway(4, delay, 2, 30_000);
    let addr = gateway.local_addr();

    let clients: Vec<_> = (0..6)
        .map(|i| {
            let h = std::thread::spawn(move || {
                let row = vec![i as f32; 4];
                one_shot(addr, "POST", "/v1/infer", &infer_body(&row))
            });
            // Paced so the batcher absorbs each submit in order.
            std::thread::sleep(Duration::from_millis(15));
            h
        })
        .collect();
    // Everything is queued, nothing finished (first completes at ~600ms).
    std::thread::sleep(Duration::from_millis(200));

    let shed = one_shot(addr, "POST", "/v1/infer", &infer_body(&[9.0; 4]));
    assert_eq!(shed.status, 503, "{}", shed.body_str());
    assert_eq!(shed.header("retry-after"), Some("1"));
    assert!(shed.body_str().contains("queue full"), "{}", shed.body_str());

    let metrics = one_shot(addr, "GET", "/metrics", b"");
    assert!(
        metrics.body_str().contains("acdc_gateway_shed_queue_full 1"),
        "{}",
        metrics.body_str()
    );

    // Drain: shutdown must let all six in-flight requests finish with 200s.
    gateway.shutdown();
    for (i, h) in clients.into_iter().enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "client {i} lost during drain");
        let v = Json::parse(resp.body_str()).unwrap();
        let out = v.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out[0].as_f64(), Some(i as f64), "echo row identity");
    }
}

#[test]
fn shutdown_drains_promptly_with_idle_keepalive_connections() {
    let n = 8;
    let mut rng = Pcg32::seeded(31);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 100,
        workers: 1,
        queue_cap: 16,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();
    // A served request plus an idle parked keep-alive connection.
    let mut idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let ok = one_shot(addr, "POST", "/v1/infer", &infer_body(&[0.5; 8]));
    assert_eq!(ok.status, 200);
    // Drain must not wait out the idle connection's socket: parked
    // connections poll the drain flag and exit within the idle interval,
    // and shutdown blocks on the connection-exit condvar — an event, not
    // a sleep-poll — so returning here means every connection thread has
    // actually finished (nothing detached, nothing joined-on-timeout).
    let t0 = std::time::Instant::now();
    gateway.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain stalled on an idle keep-alive connection: {:?}",
        t0.elapsed()
    );
    // Deterministic teardown: the server side closed the parked
    // connection during the drain, so the very next read sees EOF (not a
    // timeout against a half-open socket).
    use std::io::Read;
    let mut buf = [0u8; 16];
    match idle.read(&mut buf) {
        Ok(0) => {}                   // clean EOF — connection was closed
        Ok(n) => panic!("unexpected {n} bytes on a drained idle connection"),
        Err(e) => panic!("idle connection not closed by drain: {e}"),
    }
}

/// A small native gateway pinned to an explicit I/O mode (the regression
/// tests below run once per mode so neither path can drift).
fn mode_gateway(n: usize, mode: &str) -> Gateway {
    let mut rng = Pcg32::seeded(61);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            mode: mode.into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    Gateway::start(server, cfg.gateway.clone()).unwrap()
}

#[test]
fn duplicate_content_length_is_rejected_on_the_wire_in_both_modes() {
    // Request smuggling guard: two Content-Length headers (even agreeing
    // ones) must die with a 400 from the authoritative parser, in both
    // I/O architectures.
    for mode in ["reactor", "threaded"] {
        let gateway = mode_gateway(8, mode);
        let mut stream = TcpStream::connect(gateway.local_addr()).unwrap();
        let req = b"POST /v1/infer HTTP/1.1\r\n\
                    content-type: application/json\r\n\
                    content-length: 2\r\n\
                    content-length: 2\r\n\
                    \r\n{}";
        let resp = raw_exchange(&mut stream, req);
        assert_eq!(resp.status, 400, "mode {mode}: {}", resp.body_str());
        assert!(
            resp.body_str().contains("duplicate content-length"),
            "mode {mode}: {}",
            resp.body_str()
        );
        gateway.shutdown();
    }
}

#[test]
fn connection_close_inside_a_token_list_actually_closes_in_both_modes() {
    // `Connection: close, x-experimental` is a token list; the old
    // whole-value comparison kept such connections alive. The server must
    // answer with `connection: close` and then really close the socket.
    for mode in ["reactor", "threaded"] {
        let gateway = mode_gateway(8, mode);
        let mut stream = TcpStream::connect(gateway.local_addr()).unwrap();
        let body = infer_body(&[0.5; 8]);
        let head = format!(
            "POST /v1/infer HTTP/1.1\r\n\
             content-type: application/json\r\n\
             connection: close, x-experimental\r\n\
             content-length: {}\r\n\r\n",
            body.len()
        );
        let mut req = head.into_bytes();
        req.extend_from_slice(&body);
        let resp = raw_exchange(&mut stream, &req);
        assert_eq!(resp.status, 200, "mode {mode}: {}", resp.body_str());
        assert!(!resp.keep_alive(), "mode {mode}: response promised keep-alive");
        // The next read must see EOF, not a parked keep-alive socket.
        use std::io::Read;
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut buf = [0u8; 8];
        match stream.read(&mut buf) {
            Ok(0) => {}
            Ok(k) => panic!("mode {mode}: {k} bytes after connection: close"),
            Err(e) => panic!("mode {mode}: socket not closed after close token: {e}"),
        }
        gateway.shutdown();
    }
}

/// Shrink a connected socket's receive buffer so the peer's writes hit
/// flow control almost immediately (stalled-reader simulation).
fn shrink_rcvbuf(stream: &TcpStream) {
    use std::os::fd::AsRawFd;
    const SOL_SOCKET: i32 = 1;
    const SO_RCVBUF: i32 = 8;
    extern "C" {
        fn setsockopt(
            fd: i32,
            level: i32,
            name: i32,
            value: *const core::ffi::c_void,
            len: u32,
        ) -> i32;
    }
    let sz: i32 = 4096;
    let rc = unsafe {
        setsockopt(
            stream.as_raw_fd(),
            SOL_SOCKET,
            SO_RCVBUF,
            &sz as *const i32 as *const core::ffi::c_void,
            std::mem::size_of::<i32>() as u32,
        )
    };
    assert_eq!(rc, 0, "setsockopt(SO_RCVBUF) failed");
}

#[test]
fn stalled_reader_is_evicted_instead_of_wedging_a_server_thread() {
    // A client that requests an 8 MB response and then never reads: the
    // kernel can buffer ~4-5 MB (server send buffer + client receive
    // buffer, shrunk here), after which the server's write stalls. With
    // `write_stall_ms` bounding the stall, both I/O modes must abandon
    // the write and evict the connection while staying healthy for
    // everyone else.
    let n = 256usize;
    let rows = 8_192usize;
    for mode in ["reactor", "threaded"] {
        let cfg = ServeConfig {
            buckets: vec![256],
            max_wait_us: 100,
            workers: 1,
            queue_cap: 16_384,
            gateway: GatewayConfig {
                addr: "127.0.0.1:0".into(),
                mode: mode.into(),
                max_body_bytes: 16 << 20,
                max_rows_per_request: rows,
                request_timeout_ms: 60_000,
                write_stall_ms: 300,
                ..Default::default()
            },
            ..Default::default()
        };
        let factory: ExecutorFactory = Arc::new(move || {
            Ok(Box::new(SlowEcho {
                n,
                delay: Duration::ZERO,
            }) as Box<dyn BatchExecutor>)
        });
        let server = Server::start_custom(&cfg, n, factory);
        let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
        let addr = gateway.local_addr();

        let mut vals = vec![0f32; rows * n];
        for (i, v) in vals.iter_mut().enumerate() {
            *v = ((i % 2048) as f32 - 1024.0) / 1024.0;
        }
        let mut frame = Vec::new();
        wire::write_binary_request(&mut frame, n, &vals);

        let mut stream = TcpStream::connect(addr).unwrap();
        shrink_rcvbuf(&stream);
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        http::write_request(
            &mut stream,
            "POST",
            "/v1/infer",
            &[("content-type", wire::CONTENT_TYPE)],
            &frame,
        )
        .expect("write request");
        // Stall: don't read. write_stall_ms=300 must fire well within
        // this window and the gateway must keep serving others meanwhile.
        std::thread::sleep(Duration::from_millis(1_500));
        let health = one_shot(addr, "GET", "/healthz", b"");
        assert_eq!(health.status, 200, "mode {mode}: gateway wedged");

        // Now drain what the kernel buffered. The connection must be
        // closed early: strictly fewer body bytes than the frame header
        // promised, ending in EOF or a reset — never a still-open socket.
        use std::io::Read;
        let full = wire::RESP_HEADER_BYTES + rows * n * 4;
        let mut total = 0usize;
        let mut buf = vec![0u8; 64 * 1024];
        let closed = loop {
            match stream.read(&mut buf) {
                Ok(0) => break true,
                Ok(k) => {
                    total += k;
                    if total > 2 * full {
                        break false;
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    break false;
                }
                Err(_) => break true,
            }
        };
        assert!(closed, "mode {mode}: stalled connection was never evicted");
        assert!(
            total < full,
            "mode {mode}: full {full}-byte response delivered ({total}) — write never stalled"
        );
        gateway.shutdown();
    }
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

/// Soft `RLIMIT_NOFILE`, after a best-effort raise toward the hard cap
/// (CI runners often default the soft limit to 1024). Both ends of every
/// test connection live in this process, so the parked-connection count
/// budgets against this.
fn nofile_soft_limit() -> u64 {
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    let mut r = Rlimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } != 0 {
        return 1_024;
    }
    let want = r.max.min(25_000);
    if want > r.cur {
        let raised = Rlimit { cur: want, max: r.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &raised) } == 0 {
            return want;
        }
    }
    r.cur
}

#[test]
fn reactor_parks_ten_thousand_idle_keepalive_conns_and_drains_cleanly() {
    // The tentpole capacity claim: thousands of idle keep-alive
    // connections parked on the epoll shards (10k+ where the fd limit
    // allows — each connection consumes two fds here, client and server
    // end both being in-process), live traffic still served through and
    // around them, and a drain that closes every parked socket promptly.
    let limit = nofile_soft_limit();
    let target = 10_000u64.min(limit.saturating_sub(600) / 2) as usize;
    assert!(
        target >= 512,
        "RLIMIT_NOFILE {limit} leaves no room for a mass-connection test"
    );
    let n = 8;
    let mut rng = Pcg32::seeded(71);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            mode: "reactor".into(),
            max_open_conns: target + 64,
            drain_timeout_ms: 30_000,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    let mut conns: Vec<TcpStream> = Vec::with_capacity(target);
    for i in 0..target {
        let s = TcpStream::connect(addr)
            .unwrap_or_else(|e| panic!("connect {i}/{target} failed: {e}"));
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        conns.push(s);
    }

    // The parked mass must not starve live traffic: requests through a
    // sample of the parked connections and through a fresh one all serve.
    for idx in [0, target / 2, target - 1] {
        let stream = &mut conns[idx];
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        http::write_request(
            stream,
            "POST",
            "/v1/infer",
            &[("content-type", "application/json")],
            &infer_body(&[0.25; 8]),
        )
        .expect("write through parked conn");
        let resp = http::read_response(&mut reader).expect("response");
        assert_eq!(resp.status, 200, "conn {idx}: {}", resp.body_str());
    }
    let health = one_shot(addr, "GET", "/healthz", b"");
    assert_eq!(health.status, 200);

    let t0 = std::time::Instant::now();
    gateway.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "drain stalled against {target} idle connections: {:?}",
        t0.elapsed()
    );
    // Every parked socket was really closed by the drain: sampled reads
    // see EOF, not a timeout against a half-open connection.
    use std::io::Read;
    for idx in [0, 1, target / 2, target - 1] {
        let mut buf = [0u8; 8];
        match conns[idx].read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(k) => panic!("conn {idx}: {k} unexpected bytes after drain"),
        }
    }
}

#[test]
fn binary_frame_is_bit_identical_to_json_and_shares_error_wording() {
    let n = 16usize;
    let mut rng = Pcg32::seeded(81);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    // Inputs on the 2^-10 grid are exact in both f32 and f64, so the JSON
    // request path (decimal → f64 parse → f32 cast) and the binary path
    // (raw little-endian f32) feed the executor identical bits; any
    // output divergence is then the serving paths' fault.
    let rows = 6usize;
    let mut vals: Vec<f32> = Vec::with_capacity(rows * n);
    let mut k: i64 = -700;
    for _ in 0..rows * n {
        vals.push(k as f32 / 1024.0);
        k += 13;
    }

    let json_rows: Vec<Json> = vals
        .chunks(n)
        .map(|row| Json::Arr(row.iter().map(|v| Json::Num(*v as f64)).collect()))
        .collect();
    let jbody =
        acdc::util::json::obj(vec![("rows", Json::Arr(json_rows))]).to_string();
    let jresp = one_shot(addr, "POST", "/v1/infer", jbody.as_bytes());
    assert_eq!(jresp.status, 200, "{}", jresp.body_str());
    assert_eq!(jresp.header("content-type"), Some("application/json"));
    let jv = Json::parse(jresp.body_str()).unwrap();
    let mut json_bits: Vec<u32> = Vec::new();
    for row in jv.get("outputs").unwrap().as_arr().unwrap() {
        for x in row.as_arr().unwrap() {
            json_bits.push((x.as_f64().unwrap() as f32).to_bits());
        }
    }

    let mut frame = Vec::new();
    wire::write_binary_request(&mut frame, n, &vals);
    let bresp = one_shot_typed(addr, "POST", "/v1/infer", wire::CONTENT_TYPE, &frame);
    assert_eq!(bresp.status, 200, "{}", bresp.body_str());
    assert_eq!(bresp.header("content-type"), Some(wire::CONTENT_TYPE));
    let mut outs: Vec<f32> = Vec::new();
    let head = wire::parse_binary_response(&bresp.body, &mut outs).unwrap();
    assert_eq!(head.rows, rows);
    assert_eq!(head.width, n);
    let bin_bits: Vec<u32> = outs.iter().map(|v| v.to_bits()).collect();
    assert_eq!(json_bits.len(), rows * n);
    assert_eq!(json_bits, bin_bits, "binary output bits diverge from JSON");

    // Validation is single-sourced: a width-mismatched binary frame gets
    // the very wording the JSON path uses.
    let bad_vals = vec![0.0f32; n + 1];
    let mut bad = Vec::new();
    wire::write_binary_request(&mut bad, n + 1, &bad_vals);
    let err = one_shot_typed(addr, "POST", "/v1/infer", wire::CONTENT_TYPE, &bad);
    assert_eq!(err.status, 400, "{}", err.body_str());
    let want = format!("row has {} features, model width is {n}", n + 1);
    assert!(err.body_str().contains(&want), "{}", err.body_str());
    gateway.shutdown();
}

#[test]
fn rate_limited_gateway_sheds_with_429_and_retry_after() {
    let n = 8;
    let mut rng = Pcg32::seeded(41);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 100,
        workers: 2,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            // 2-token burst, glacial refill: the 3rd rapid request is shed.
            rate_rps: 0.001,
            rate_burst: 2.0,
            retry_after_s: 7,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();
    let body = infer_body(&[1.0; 8]);
    assert_eq!(one_shot(addr, "POST", "/v1/infer", &body).status, 200);
    assert_eq!(one_shot(addr, "POST", "/v1/infer", &body).status, 200);
    let shed = one_shot(addr, "POST", "/v1/infer", &body);
    assert_eq!(shed.status, 429, "{}", shed.body_str());
    assert_eq!(shed.header("retry-after"), Some("7"));
    let metrics = one_shot(addr, "GET", "/metrics", b"");
    assert!(
        metrics.body_str().contains("acdc_gateway_shed_rate_limited 1"),
        "{}",
        metrics.body_str()
    );
    gateway.shutdown();
}
