//! Integration: the network gateway end-to-end over ephemeral ports.
//!
//! Covers the acceptance path for the serving gateway: concurrent
//! `POST /v1/infer` traffic against a native-executor server, the load
//! generator's latency/shed report under saturation, and the
//! queue-full → 503 → drain contract.

use acdc::config::{GatewayConfig, ServeConfig};
use acdc::coordinator::worker::{BatchExecutor, ExecutorFactory};
use acdc::gateway::http;
use acdc::gateway::loadgen::{ArrivalMode, LoadgenConfig};
use acdc::gateway::Gateway;
use acdc::sell::acdc::AcdcCascade;
use acdc::sell::init::DiagInit;
use acdc::serve::Server;
use acdc::tensor::Tensor;
use acdc::util::json::Json;
use acdc::util::rng::Pcg32;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// One HTTP exchange on a fresh connection.
fn one_shot(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

fn infer_body(row: &[f32]) -> Vec<u8> {
    let features = Json::Arr(row.iter().map(|v| Json::Num(*v as f64)).collect());
    acdc::util::json::obj(vec![("features", features)])
        .to_string()
        .into_bytes()
}

#[test]
fn gateway_serves_concurrent_infer_traffic_end_to_end() {
    let n = 32;
    let mut rng = Pcg32::seeded(11);
    let cascade = AcdcCascade::nonlinear(n, 4, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 300,
        workers: 2,
        queue_cap: 512,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade.clone());
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    // 8 concurrent clients, 5 keep-alive requests each.
    let handles: Vec<_> = (0..8)
        .map(|client| {
            std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(100 + client);
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(60)))
                    .unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                for _ in 0..5 {
                    let row = rng.normal_vec(32, 0.0, 1.0);
                    http::write_request(
                        &mut stream,
                        "POST",
                        "/v1/infer",
                        &[("content-type", "application/json")],
                        &infer_body(&row),
                    )
                    .expect("write");
                    let resp = http::read_response(&mut reader).expect("response");
                    assert_eq!(resp.status, 200, "{}", resp.body_str());
                    let v = Json::parse(resp.body_str()).unwrap();
                    assert_eq!(v.get("output").unwrap().as_arr().unwrap().len(), 32);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // One more request whose output we can check numerically.
    let mut rng = Pcg32::seeded(500);
    let row = rng.normal_vec(n, 0.0, 1.0);
    let resp = one_shot(addr, "POST", "/v1/infer", &infer_body(&row));
    assert_eq!(resp.status, 200);
    let v = Json::parse(resp.body_str()).unwrap();
    let got: Vec<f64> = v
        .get("output")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    let want = cascade.forward(&Tensor::from_vec(&[1, n], row));
    for (g, w) in got.iter().zip(want.data()) {
        assert!((g - *w as f64).abs() < 1e-3, "gateway output drifted");
    }

    // Health and metrics endpoints.
    let health = one_shot(addr, "GET", "/healthz", b"");
    assert_eq!(health.status, 200);
    let hv = Json::parse(health.body_str()).unwrap();
    assert_eq!(hv.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(hv.get("width").unwrap().as_usize(), Some(n));

    let metrics = one_shot(addr, "GET", "/metrics", b"");
    assert_eq!(metrics.status, 200);
    let text = metrics.body_str();
    assert!(text.contains("acdc_gateway_admitted"), "{text}");
    assert!(text.contains("acdc_coordinator_accepted"), "{text}");
    assert!(text.contains("acdc_gateway_request_ns_count"), "{text}");

    // Unknown routes and wrong methods are typed errors.
    assert_eq!(one_shot(addr, "GET", "/nope", b"").status, 404);
    assert_eq!(one_shot(addr, "GET", "/v1/infer", b"").status, 405);
    assert_eq!(one_shot(addr, "POST", "/v1/infer", b"not json").status, 400);

    gateway.shutdown();
}

#[test]
fn gateway_batch_rows_request_answers_every_row() {
    let n = 16;
    let mut rng = Pcg32::seeded(21);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 4],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let rows: Vec<Json> = (0..3)
        .map(|_| {
            let vals = rng.normal_vec(n, 0.0, 1.0);
            Json::Arr(vals.iter().map(|v| Json::Num(*v as f64)).collect())
        })
        .collect();
    let body = acdc::util::json::obj(vec![("rows", Json::Arr(rows))]).to_string();
    let resp = one_shot(gateway.local_addr(), "POST", "/v1/infer", body.as_bytes());
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = Json::parse(resp.body_str()).unwrap();
    assert_eq!(v.get("rows").unwrap().as_usize(), Some(3));
    let outputs = v.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(outputs.len(), 3);
    for out in outputs {
        assert_eq!(out.as_arr().unwrap().len(), n);
    }
    gateway.shutdown();
}

/// Echo executor with a configurable service time, to saturate tiny
/// queues deterministically.
struct SlowEcho {
    n: usize,
    delay: Duration,
}

impl BatchExecutor for SlowEcho {
    fn width(&self) -> usize {
        self.n
    }
    fn out_width(&self) -> usize {
        self.n
    }
    fn execute_into(
        &mut self,
        _bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(padded);
        Ok(())
    }
}

fn slow_gateway(n: usize, delay: Duration, queue_cap: usize, timeout_ms: u64) -> Gateway {
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 1,
        workers: 1,
        queue_cap,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 64,
            request_timeout_ms: timeout_ms,
            drain_timeout_ms: 30_000,
            ..Default::default()
        },
        ..Default::default()
    };
    let factory: ExecutorFactory =
        Arc::new(move || Ok(Box::new(SlowEcho { n, delay }) as Box<dyn BatchExecutor>));
    let server = Server::start_custom(&cfg, n, factory);
    Gateway::start(server, cfg.gateway.clone()).unwrap()
}

#[test]
fn loadgen_reports_latency_and_nonzero_sheds_past_queue_cap() {
    // 1 worker × 10ms service time ≈ 100 req/s capacity; 12 closed-loop
    // clients against queue_cap 2 must shed hard.
    let gateway = slow_gateway(8, Duration::from_millis(10), 2, 10_000);
    let addr = gateway.local_addr();
    let report = acdc::gateway::loadgen::run(&LoadgenConfig {
        addr: addr.to_string(),
        mode: ArrivalMode::Closed,
        concurrency: 12,
        duration: Duration::from_millis(1_500),
        width: 8,
        rows_mix: vec![1],
        timeout: Duration::from_secs(30),
        seed: 3,
    })
    .unwrap();

    assert!(report.ok > 0, "some requests must succeed: {report:?}");
    assert!(
        report.shed > 0,
        "driving 12 clients past queue_cap=2 must shed: {report:?}"
    );
    assert!(report.errors == 0, "sheds are not errors: {report:?}");
    assert!(report.p50_ms > 0.0 && report.p99_ms >= report.p50_ms, "{report:?}");
    assert!(report.goodput_rps() > 0.0);
    // JSON report carries the same story.
    let j = report.to_json();
    assert!(j.get("shed").unwrap().as_f64().unwrap() > 0.0);

    // The gateway's own accounting saw the queue-full sheds.
    let metrics = one_shot(addr, "GET", "/metrics", b"");
    let text = metrics.body_str();
    let shed_line = text
        .lines()
        .find(|l| l.starts_with("acdc_gateway_shed_queue_full "))
        .unwrap_or_else(|| panic!("no shed counter in:\n{text}"));
    let shed_count: f64 = shed_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    assert!(shed_count > 0.0, "{shed_line}");

    gateway.shutdown();
}

#[test]
fn queue_full_maps_to_503_and_drain_completes_inflight() {
    // Pipeline capacity with buckets [1], 1 worker, queue_cap 2 and a
    // bounded batch channel (2 × workers): 6 requests absorbed; the 7th
    // must see 503 + Retry-After while the first is still executing.
    let delay = Duration::from_millis(600);
    let gateway = slow_gateway(4, delay, 2, 30_000);
    let addr = gateway.local_addr();

    let clients: Vec<_> = (0..6)
        .map(|i| {
            let h = std::thread::spawn(move || {
                let row = vec![i as f32; 4];
                one_shot(addr, "POST", "/v1/infer", &infer_body(&row))
            });
            // Paced so the batcher absorbs each submit in order.
            std::thread::sleep(Duration::from_millis(15));
            h
        })
        .collect();
    // Everything is queued, nothing finished (first completes at ~600ms).
    std::thread::sleep(Duration::from_millis(200));

    let shed = one_shot(addr, "POST", "/v1/infer", &infer_body(&[9.0; 4]));
    assert_eq!(shed.status, 503, "{}", shed.body_str());
    assert_eq!(shed.header("retry-after"), Some("1"));
    assert!(shed.body_str().contains("queue full"), "{}", shed.body_str());

    let metrics = one_shot(addr, "GET", "/metrics", b"");
    assert!(
        metrics.body_str().contains("acdc_gateway_shed_queue_full 1"),
        "{}",
        metrics.body_str()
    );

    // Drain: shutdown must let all six in-flight requests finish with 200s.
    gateway.shutdown();
    for (i, h) in clients.into_iter().enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "client {i} lost during drain");
        let v = Json::parse(resp.body_str()).unwrap();
        let out = v.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out[0].as_f64(), Some(i as f64), "echo row identity");
    }
}

#[test]
fn shutdown_drains_promptly_with_idle_keepalive_connections() {
    let n = 8;
    let mut rng = Pcg32::seeded(31);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 100,
        workers: 1,
        queue_cap: 16,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();
    // A served request plus an idle parked keep-alive connection.
    let mut idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let ok = one_shot(addr, "POST", "/v1/infer", &infer_body(&[0.5; 8]));
    assert_eq!(ok.status, 200);
    // Drain must not wait out the idle connection's socket: parked
    // connections poll the drain flag and exit within the idle interval,
    // and shutdown blocks on the connection-exit condvar — an event, not
    // a sleep-poll — so returning here means every connection thread has
    // actually finished (nothing detached, nothing joined-on-timeout).
    let t0 = std::time::Instant::now();
    gateway.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain stalled on an idle keep-alive connection: {:?}",
        t0.elapsed()
    );
    // Deterministic teardown: the server side closed the parked
    // connection during the drain, so the very next read sees EOF (not a
    // timeout against a half-open socket).
    use std::io::Read;
    let mut buf = [0u8; 16];
    match idle.read(&mut buf) {
        Ok(0) => {}                   // clean EOF — connection was closed
        Ok(n) => panic!("unexpected {n} bytes on a drained idle connection"),
        Err(e) => panic!("idle connection not closed by drain: {e}"),
    }
}

#[test]
fn rate_limited_gateway_sheds_with_429_and_retry_after() {
    let n = 8;
    let mut rng = Pcg32::seeded(41);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8],
        max_wait_us: 100,
        workers: 2,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            // 2-token burst, glacial refill: the 3rd rapid request is shed.
            rate_rps: 0.001,
            rate_burst: 2.0,
            retry_after_s: 7,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();
    let body = infer_body(&[1.0; 8]);
    assert_eq!(one_shot(addr, "POST", "/v1/infer", &body).status, 200);
    assert_eq!(one_shot(addr, "POST", "/v1/infer", &body).status, 200);
    let shed = one_shot(addr, "POST", "/v1/infer", &body);
    assert_eq!(shed.status, 429, "{}", shed.body_str());
    assert_eq!(shed.header("retry-after"), Some("7"));
    let metrics = one_shot(addr, "GET", "/metrics", b"");
    assert!(
        metrics.body_str().contains("acdc_gateway_shed_rate_limited 1"),
        "{}",
        metrics.body_str()
    );
    gateway.shutdown();
}
