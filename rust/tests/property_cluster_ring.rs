//! Property suite for the cluster consistent-hash ring
//! ([`acdc::cluster::Ring`]).
//!
//! These pin the three guarantees the router's placement layer is built
//! on (DESIGN.md §8):
//!
//! * **uniformity** — per-shard load within 15% of the ideal share over
//!   1k synthetic model names, for 3- and 5-shard topologies;
//! * **minimal movement** — a shard joining only pulls keys *onto*
//!   itself; a shard leaving only moves the keys it owned;
//! * **distinct replica sets** — a replica set never names the same
//!   shard twice, across topologies and replication factors.
//!
//! The ring is fully deterministic (FNV-1a/64 + SplitMix64, no process
//! state), so these are exact assertions, not statistical flakes: the
//! measured deviations below are constants of the hash function.

use acdc::cluster::{Ring, DEFAULT_VNODES};

/// 1k synthetic model names — the workload ISSUE.md's uniformity bound
/// is stated over.
fn keys() -> Vec<String> {
    (0..1000).map(|i| format!("model-{i}")).collect()
}

fn local_shards(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
}

/// Max relative deviation of per-shard primary counts from the ideal
/// `keys / shards` share.
fn max_deviation(ring: &Ring, keys: &[String]) -> f64 {
    let mut counts = vec![0usize; ring.len()];
    for k in keys {
        counts[ring.primary(k)] += 1;
    }
    let ideal = keys.len() as f64 / ring.len() as f64;
    counts
        .iter()
        .map(|&c| (c as f64 - ideal).abs() / ideal)
        .fold(0.0, f64::max)
}

#[test]
fn uniformity_within_15pct_three_shards() {
    let ring = Ring::new(&local_shards(3), DEFAULT_VNODES);
    let dev = max_deviation(&ring, &keys());
    assert!(
        dev < 0.15,
        "3-shard max deviation {dev:.3} exceeds the 15% bound"
    );
}

#[test]
fn uniformity_within_15pct_five_shards() {
    let shards: Vec<String> = (0..5).map(|i| format!("10.0.0.{i}:7000")).collect();
    let ring = Ring::new(&shards, DEFAULT_VNODES);
    let dev = max_deviation(&ring, &keys());
    assert!(
        dev < 0.15,
        "5-shard max deviation {dev:.3} exceeds the 15% bound"
    );
}

#[test]
fn join_moves_keys_only_onto_the_new_shard() {
    let before = Ring::new(&local_shards(3), DEFAULT_VNODES);
    let mut grown = local_shards(3);
    grown.push("127.0.0.1:9003".to_string());
    let after = Ring::new(&grown, DEFAULT_VNODES);

    let keys = keys();
    let mut moved = 0usize;
    for k in &keys {
        let (old, new) = (before.primary(k), after.primary(k));
        if old != new {
            // Shard indices 0..3 are shared between the two topologies
            // (same order), so any key that changed primaries must have
            // landed on the joiner — anything else is gratuitous churn.
            assert_eq!(
                new, 3,
                "key {k} moved from shard {old} to pre-existing shard {new}"
            );
            moved += 1;
        }
    }
    // The joiner should take roughly its fair share (1/4) and no more:
    // allow a generous band, but reject both "nothing moved" (join had
    // no effect) and "half the keyspace moved" (non-minimal movement).
    assert!(
        moved > 0 && moved < keys.len() / 2,
        "join moved {moved}/{} keys",
        keys.len()
    );
}

#[test]
fn leave_preserves_surviving_primaries() {
    let before = Ring::new(&local_shards(4), DEFAULT_VNODES);
    // Remove the last shard so surviving indices line up 1:1.
    let after = Ring::new(&local_shards(3), DEFAULT_VNODES);

    for k in &keys() {
        let old = before.primary(k);
        if old != 3 {
            assert_eq!(
                after.primary(k),
                old,
                "key {k} moved off surviving shard {old} when shard 3 left"
            );
        }
    }
}

#[test]
fn replica_sets_are_always_distinct_shards() {
    for n in [2usize, 3, 5] {
        let ring = Ring::new(&local_shards(n), DEFAULT_VNODES);
        for r in 1..=n + 1 {
            for k in keys().iter().step_by(7) {
                let set = ring.place(k, r);
                assert_eq!(set.len(), r.min(n), "set {set:?} for {k} r={r} n={n}");
                let mut dedup = set.clone();
                dedup.sort_unstable();
                dedup.dedup();
                assert_eq!(dedup.len(), set.len(), "duplicate shard in {set:?}");
            }
        }
    }
}

#[test]
fn replica_sets_move_minimally_on_join() {
    // The stronger form of minimal movement: with R=2, a join may only
    // insert the new shard into a set (possibly displacing one member);
    // it never reshuffles a set that the new shard didn't touch.
    let before = Ring::new(&local_shards(3), DEFAULT_VNODES);
    let mut grown = local_shards(3);
    grown.push("127.0.0.1:9003".to_string());
    let after = Ring::new(&grown, DEFAULT_VNODES);

    for k in &keys() {
        let old = before.place(k, 2);
        let new = after.place(k, 2);
        if !new.contains(&3) {
            assert_eq!(
                new, old,
                "replica set for {k} changed without involving the joiner"
            );
        }
    }
}

#[test]
fn placement_is_deterministic_across_ring_instances() {
    let a = Ring::new(&local_shards(5), DEFAULT_VNODES);
    let b = Ring::new(&local_shards(5), DEFAULT_VNODES);
    for k in &keys() {
        assert_eq!(a.place(k, 3), b.place(k, 3));
    }
}
