//! Integration: per-request tracing end-to-end over ephemeral ports.
//!
//! Covers the observability acceptance path: a synthetic worker stall is
//! attributed to the `execute` stage in `GET /v1/debug/slow` under the
//! same trace ID the client saw in its `x-trace-id` response header; the
//! opt-in `x-acdc-debug: 1` header returns the inline stage breakdown;
//! disabling `[trace]` removes the header and records nothing; and
//! `sample_every` thins the minted IDs deterministically.

use acdc::config::{GatewayConfig, ServeConfig, TraceConfig};
use acdc::coordinator::worker::{BatchExecutor, ExecutorFactory};
use acdc::gateway::http;
use acdc::gateway::Gateway;
use acdc::serve::Server;
use acdc::util::json::Json;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// One HTTP exchange on a fresh connection, with caller-chosen headers.
fn one_shot(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(&mut stream, method, path, headers, body).expect("write request");
    http::read_response(&mut reader).expect("read response")
}

const JSON_CT: (&str, &str) = ("content-type", "application/json");

fn infer_body(row: &[f32]) -> Vec<u8> {
    let features = Json::Arr(row.iter().map(|v| Json::Num(*v as f64)).collect());
    acdc::util::json::obj(vec![("features", features)])
        .to_string()
        .into_bytes()
}

/// Echo executor with a configurable service time: the injected stall.
struct SlowEcho {
    n: usize,
    delay: Duration,
}

impl BatchExecutor for SlowEcho {
    fn width(&self) -> usize {
        self.n
    }
    fn out_width(&self) -> usize {
        self.n
    }
    fn execute_into(
        &mut self,
        _bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(padded);
        Ok(())
    }
}

fn traced_gateway(n: usize, delay: Duration, trace: TraceConfig) -> Gateway {
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 1,
        workers: 1,
        queue_cap: 16,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 64,
            request_timeout_ms: 30_000,
            trace,
            ..Default::default()
        },
        ..Default::default()
    };
    let factory: ExecutorFactory =
        Arc::new(move || Ok(Box::new(SlowEcho { n, delay }) as Box<dyn BatchExecutor>));
    let server = Server::start_custom(&cfg, n, factory);
    Gateway::start(server, cfg.gateway.clone()).unwrap()
}

fn assert_hex16(id: &str) {
    assert_eq!(id.len(), 16, "trace id '{id}' is not 16 hex chars");
    assert!(
        id.chars().all(|c| c.is_ascii_hexdigit()),
        "trace id '{id}' is not hex"
    );
}

#[test]
fn worker_stall_lands_in_slow_ring_attributed_to_execute() {
    // 200ms execute against a 50ms threshold: every request is slow, and
    // the slow stage is unambiguously the worker's execute.
    let gateway = traced_gateway(
        8,
        Duration::from_millis(200),
        TraceConfig {
            slow_ms: 50,
            ..Default::default()
        },
    );
    let addr = gateway.local_addr();

    let resp = one_shot(addr, "POST", "/v1/infer", &[JSON_CT], &infer_body(&[1.0; 8]));
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let tid = resp
        .header("x-trace-id")
        .expect("traced response must echo x-trace-id")
        .to_string();
    assert_hex16(&tid);
    // Without the debug header the body carries no inline breakdown.
    let v = Json::parse(resp.body_str()).unwrap();
    assert!(v.get("trace").is_none(), "{}", resp.body_str());

    // The ring records just after the response flush: poll briefly so a
    // fast client can't outrun the recording connection thread.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let entry = loop {
        let debug = one_shot(addr, "GET", "/v1/debug/slow", &[], b"");
        assert_eq!(debug.status, 200, "{}", debug.body_str());
        let d = Json::parse(debug.body_str()).unwrap();
        assert_eq!(d.get("threshold_us").unwrap().as_i64(), Some(50_000));
        assert!(d.get("capacity").unwrap().as_i64().unwrap() >= 1);
        let hit = d
            .get("entries")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("trace_id").and_then(|x| x.as_str()) == Some(tid.as_str()))
            .cloned();
        if let Some(entry) = hit {
            assert!(d.get("recorded").unwrap().as_i64().unwrap() >= 1);
            break entry;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "trace {tid} never captured in {}",
            debug.body_str()
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    let entry = &entry;

    // The stall is attributed to the execute stage, under the right ID.
    assert_eq!(entry.get("slowest").unwrap().as_str(), Some("execute"));
    assert_eq!(entry.get("status").unwrap().as_i64(), Some(200));
    assert_eq!(entry.get("rows").unwrap().as_i64(), Some(1));
    assert!(entry.get("batch_size").unwrap().as_i64().unwrap() >= 1);
    assert!(entry.get("unix_ms").unwrap().as_i64().unwrap() > 0);
    let stages = entry.get("stages").unwrap();
    let execute_us = stages.get("execute_us").unwrap().as_i64().unwrap();
    assert!(execute_us >= 100_000, "execute stage lost the stall: {execute_us}µs");
    let total_us = entry.get("total_us").unwrap().as_i64().unwrap();
    assert!(total_us >= execute_us, "total {total_us} < execute {execute_us}");
    // Every stage renders, even the cheap ones.
    for key in [
        "parse_us",
        "admission_us",
        "queue_wait_us",
        "batch_form_us",
        "serialize_us",
        "write_us",
    ] {
        assert!(stages.get(key).is_some(), "missing stage {key}");
    }

    // The debug endpoint is GET-only.
    assert_eq!(one_shot(addr, "POST", "/v1/debug/slow", &[], b"").status, 405);
    gateway.shutdown();
}

#[test]
fn debug_header_returns_inline_stage_breakdown() {
    let gateway = traced_gateway(8, Duration::from_millis(0), TraceConfig::default());
    let addr = gateway.local_addr();
    let resp = one_shot(
        addr,
        "POST",
        "/v1/infer",
        &[JSON_CT, ("x-acdc-debug", "1")],
        &infer_body(&[0.5; 8]),
    );
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let tid = resp.header("x-trace-id").expect("x-trace-id").to_string();
    let v = Json::parse(resp.body_str()).unwrap();
    let trace = v
        .get("trace")
        .unwrap_or_else(|| panic!("no trace object in {}", resp.body_str()));
    // The inline object carries the same ID the header echoed, plus the
    // µs stage values known at serialization time.
    assert_eq!(trace.get("id").and_then(|x| x.as_str()), Some(tid.as_str()));
    for key in [
        "parse_us",
        "admission_us",
        "queue_wait_us",
        "batch_form_us",
        "execute_us",
    ] {
        assert!(
            trace.get(key).and_then(|x| x.as_f64()).is_some(),
            "missing numeric {key} in {}",
            resp.body_str()
        );
    }
    // The ordinary (non-debug) response shape is untouched.
    let plain = one_shot(addr, "POST", "/v1/infer", &[JSON_CT], &infer_body(&[0.5; 8]));
    assert_eq!(plain.status, 200);
    assert!(plain.header("x-trace-id").is_some());
    let pv = Json::parse(plain.body_str()).unwrap();
    assert!(pv.get("trace").is_none(), "{}", plain.body_str());
    gateway.shutdown();
}

#[test]
fn disabled_tracing_omits_header_and_records_nothing() {
    // Even with every request far past the 1ms threshold, disabled
    // tracing mints no IDs, echoes no header and fills no ring.
    let gateway = traced_gateway(
        8,
        Duration::from_millis(20),
        TraceConfig {
            enabled: false,
            slow_ms: 1,
            ..Default::default()
        },
    );
    let addr = gateway.local_addr();
    for _ in 0..3 {
        let resp = one_shot(addr, "POST", "/v1/infer", &[JSON_CT], &infer_body(&[2.0; 8]));
        assert_eq!(resp.status, 200);
        assert!(resp.header("x-trace-id").is_none(), "untraced response grew a header");
    }
    // The debug header is also inert without a minted trace.
    let dbg = one_shot(
        addr,
        "POST",
        "/v1/infer",
        &[JSON_CT, ("x-acdc-debug", "1")],
        &infer_body(&[2.0; 8]),
    );
    assert_eq!(dbg.status, 200);
    let dv = Json::parse(dbg.body_str()).unwrap();
    assert!(dv.get("trace").is_none(), "{}", dbg.body_str());
    let debug = one_shot(addr, "GET", "/v1/debug/slow", &[], b"");
    let d = Json::parse(debug.body_str()).unwrap();
    assert_eq!(d.get("recorded").unwrap().as_i64(), Some(0));
    assert_eq!(d.get("entries").unwrap().as_arr().unwrap().len(), 0);
    gateway.shutdown();
}

#[test]
fn sample_every_thins_minted_trace_ids_deterministically() {
    let gateway = traced_gateway(
        8,
        Duration::from_millis(0),
        TraceConfig {
            sample_every: 2,
            ..Default::default()
        },
    );
    let addr = gateway.local_addr();
    // The global sequence starts at 0 and only /v1/infer admissions
    // advance it: serial requests alternate traced / untraced.
    let mut traced = 0;
    for _ in 0..4 {
        let resp = one_shot(addr, "POST", "/v1/infer", &[JSON_CT], &infer_body(&[0.1; 8]));
        assert_eq!(resp.status, 200);
        if let Some(tid) = resp.header("x-trace-id") {
            assert_hex16(tid);
            traced += 1;
        }
    }
    assert_eq!(traced, 2, "sample_every=2 must trace exactly half of 4 requests");
    gateway.shutdown();
}
