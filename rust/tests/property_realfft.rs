//! Property tests for the real-FFT Makhoul path and the runtime SIMD
//! dispatch (hand-rolled generative harness, matching the other
//! `tests/property_*.rs` suites).
//!
//! Acceptance grid from the real-FFT/SIMD PR:
//!
//! * the scalar real-FFT `DctPlan::dct2/dct3` and the SoA engine must
//!   match the f64 closed-form oracles within 1e-4 across
//!   {1, 2, 8, 64, 512, 4096} × odd / non-multiple-of-8 row counts;
//! * every SIMD arm must match the portable (scalar-dispatch) arm —
//!   they are mul/add-only in identical op order, so the pin is
//!   *bit-identical*, far inside the 1e-6 acceptance bound;
//! * the fused `ACDC⁻¹` panel must match the f64 oracle of the whole
//!   layer, under both dispatch arms;
//! * `dct3(dct2(x)) == x` on the real-FFT path.
//!
//! The forced-scalar lane for non-AVX2 CI: these tests always exercise
//! `simd::scalar()` explicitly, and CI additionally runs the whole suite
//! with `ACDC_SIMD=scalar` so the process-wide `active()` dispatch is the
//! portable arm end to end.

use acdc::dct::simd;
use acdc::dct::{naive_dct2, naive_dct3, BatchEngine, DctPlan, PlanCache};
use acdc::util::rng::Pcg32;
use std::sync::Arc;

/// The acceptance sizes; 4096 runs a reduced row set to keep the O(N²)
/// oracle affordable in debug builds.
const SIZES: [usize; 5] = [1, 2, 8, 64, 512];
const ROWS: [usize; 5] = [1, 3, 5, 9, 12]; // odd + non-multiples of 8
const TOL: f32 = 1e-4;

fn engines(n: usize) -> Vec<(&'static str, BatchEngine)> {
    let plan = PlanCache::get(n);
    let mut out = vec![
        ("scalar", BatchEngine::with_dispatch(Arc::clone(&plan), simd::scalar())),
        ("active", BatchEngine::new(Arc::clone(&plan))),
    ];
    if let Some(d) = simd::avx2() {
        out.push(("avx2", BatchEngine::with_dispatch(plan, d)));
    }
    out
}

#[test]
fn prop_scalar_real_dct_matches_oracle_grid() {
    let mut rng = Pcg32::seeded(300);
    for &n in &SIZES {
        let plan = DctPlan::new(n);
        let mut scratch = vec![0.0f32; 2 * n];
        for trial in 0..3 {
            let x0 = rng.normal_vec(n, 0.0, 1.0);
            let mut x = x0.clone();
            plan.dct2(&mut x, &mut scratch);
            let want = naive_dct2(&x0);
            for k in 0..n {
                assert!(
                    (x[k] - want[k]).abs() < TOL,
                    "dct2 n={n} trial={trial} k={k}: {} vs {}",
                    x[k],
                    want[k]
                );
            }
            let mut y = x0.clone();
            plan.dct3(&mut y, &mut scratch);
            let want3 = naive_dct3(&x0);
            for k in 0..n {
                assert!(
                    (y[k] - want3[k]).abs() < TOL,
                    "dct3 n={n} trial={trial} k={k}"
                );
            }
            // Roundtrip on the real-FFT path.
            plan.dct2(&mut y, &mut scratch); // y = dct2(dct3(x0)) = x0
            for k in 0..n {
                assert!((y[k] - x0[k]).abs() < 1e-3, "roundtrip n={n} k={k}");
            }
        }
    }
}

#[test]
fn prop_scalar_real_dct_matches_oracle_4096() {
    let mut rng = Pcg32::seeded(301);
    let n = 4096;
    let plan = PlanCache::get(n);
    let mut scratch = vec![0.0f32; 2 * n];
    let x0 = rng.normal_vec(n, 0.0, 1.0);
    let mut x = x0.clone();
    plan.dct2(&mut x, &mut scratch);
    let want = naive_dct2(&x0);
    for k in 0..n {
        assert!((x[k] - want[k]).abs() < TOL, "dct2 n=4096 k={k}");
    }
    plan.dct3(&mut x, &mut scratch);
    for k in 0..n {
        assert!((x[k] - x0[k]).abs() < 1e-3, "roundtrip n=4096 k={k}");
    }
}

#[test]
fn prop_soa_real_dct_matches_oracle_grid() {
    let mut rng = Pcg32::seeded(302);
    for &n in &SIZES {
        for (arm, engine) in engines(n) {
            for &rows in &ROWS {
                let orig = rng.normal_vec(rows * n, 0.0, 1.0);
                let mut data = orig.clone();
                engine.dct2_rows(&mut data, rows);
                for r in 0..rows {
                    let want = naive_dct2(&orig[r * n..(r + 1) * n]);
                    for k in 0..n {
                        assert!(
                            (data[r * n + k] - want[k]).abs() < TOL,
                            "{arm} dct2 n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
                engine.dct3_rows(&mut data, rows);
                for i in 0..rows * n {
                    assert!(
                        (data[i] - orig[i]).abs() < 1e-3,
                        "{arm} roundtrip n={n} rows={rows} i={i}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_soa_real_dct_matches_oracle_4096() {
    let mut rng = Pcg32::seeded(303);
    let n = 4096;
    for (arm, engine) in engines(n) {
        let rows = 3; // one padded tail panel
        let orig = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut data = orig.clone();
        engine.dct2_rows(&mut data, rows);
        let want = naive_dct2(&orig[..n]);
        for k in 0..n {
            assert!((data[k] - want[k]).abs() < TOL, "{arm} n=4096 k={k}");
        }
        engine.dct3_rows(&mut data, rows);
        for i in 0..rows * n {
            assert!((data[i] - orig[i]).abs() < 1e-3, "{arm} roundtrip i={i}");
        }
    }
}

#[test]
fn prop_simd_arms_bit_identical_to_portable() {
    // The 1e-6 acceptance bound is pinned at its strongest form: the AVX2
    // arm is mul/add-only in scalar op order, so outputs are identical
    // bits. (On non-AVX2 hosts this degenerates to scalar vs scalar,
    // while the CI forced-scalar lane covers dispatch-forcing itself.)
    let mut rng = Pcg32::seeded(304);
    for &n in &[2usize, 8, 64, 512, 4096] {
        let plan = PlanCache::get(n);
        let scalar = BatchEngine::with_dispatch(Arc::clone(&plan), simd::scalar());
        let other = match simd::avx2() {
            Some(d) => BatchEngine::with_dispatch(Arc::clone(&plan), d),
            None => BatchEngine::new(Arc::clone(&plan)),
        };
        for &rows in &[1usize, 5, 9] {
            let a = rng.normal_vec(n, 1.0, 0.3);
            let d = rng.normal_vec(n, 1.0, 0.3);
            let bias = rng.normal_vec(n, 0.0, 0.2);
            let x = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut out_s = vec![0.0f32; rows * n];
            let mut out_o = vec![0.0f32; rows * n];
            scalar.acdc_rows(&a, &d, &bias, &x, &mut out_s, rows);
            other.acdc_rows(&a, &d, &bias, &x, &mut out_o, rows);
            for i in 0..rows * n {
                assert_eq!(
                    out_s[i].to_bits(),
                    out_o[i].to_bits(),
                    "acdc n={n} rows={rows} i={i}"
                );
            }
            let mut d2_s = x.clone();
            let mut d2_o = x.clone();
            scalar.dct2_rows(&mut d2_s, rows);
            other.dct2_rows(&mut d2_o, rows);
            for i in 0..rows * n {
                assert_eq!(d2_s[i].to_bits(), d2_o[i].to_bits(), "dct2 n={n} i={i}");
            }
            scalar.dct3_rows(&mut d2_s, rows);
            other.dct3_rows(&mut d2_o, rows);
            for i in 0..rows * n {
                assert_eq!(d2_s[i].to_bits(), d2_o[i].to_bits(), "dct3 n={n} i={i}");
            }
        }
    }
}

#[test]
fn prop_fused_acdc_matches_f64_oracle_under_every_arm() {
    let mut rng = Pcg32::seeded(305);
    for &n in &[2usize, 8, 64, 512] {
        for (arm, engine) in engines(n) {
            let rows = 9;
            let a = rng.normal_vec(n, 1.0, 0.3);
            let d = rng.normal_vec(n, 1.0, 0.3);
            let bias = rng.normal_vec(n, 0.0, 0.2);
            let x = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut got = vec![0.0f32; rows * n];
            engine.acdc_rows(&a, &d, &bias, &x, &mut got, rows);
            for r in 0..rows {
                // f64 oracle of the whole layer: ((x⊙a)·C ⊙ d + bias)·Cᵀ.
                let h1: Vec<f32> = x[r * n..(r + 1) * n]
                    .iter()
                    .zip(&a)
                    .map(|(&v, &av)| v * av)
                    .collect();
                let mut h3 = naive_dct2(&h1);
                for k in 0..n {
                    h3[k] = h3[k] * d[k] + bias[k];
                }
                let want = naive_dct3(&h3);
                for k in 0..n {
                    assert!(
                        (got[r * n + k] - want[k]).abs() < 2.0 * TOL,
                        "{arm} fused n={n} r={r} k={k}: {} vs {}",
                        got[r * n + k],
                        want[k]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_scalar_real_path_consistent_with_pair_path() {
    // dct2_rows pairs even rows through the (unchanged) complex pair path
    // and routes the odd tail through the new real-FFT single path; both
    // must agree within the acceptance band across odd row counts.
    let mut rng = Pcg32::seeded(306);
    for &n in &[2usize, 8, 64, 512] {
        let plan = DctPlan::new(n);
        let rows = 5;
        let orig = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut paired = orig.clone();
        plan.dct2_rows(&mut paired, rows);
        let mut scratch = vec![0.0f32; 2 * n];
        for r in 0..rows {
            let mut single = orig[r * n..(r + 1) * n].to_vec();
            plan.dct2(&mut single, &mut scratch);
            for k in 0..n {
                assert!(
                    (single[k] - paired[r * n + k]).abs() < 1e-4,
                    "n={n} r={r} k={k}"
                );
            }
        }
    }
}

#[test]
fn dispatch_env_override_reports_scalar_when_forced() {
    // When CI forces ACDC_SIMD=scalar the process-wide dispatch must be
    // the portable arm; otherwise it is whatever the host supports.
    let active = simd::active();
    match std::env::var("ACDC_SIMD").as_deref() {
        Ok("scalar") => assert_eq!(active.name(), "scalar"),
        _ => assert!(active.name() == "scalar" || active.name() == "avx2"),
    }
}
