//! Integration: the full `GET /metrics` payload of a live gateway parses
//! as strict Prometheus text exposition.
//!
//! A small hand-rolled parser walks every line of the real payload —
//! `# TYPE` declarations, bare samples, labeled samples — and enforces
//! the format invariants Prometheus scrapers rely on: valid metric and
//! label names, every sample covered by a declared family, histogram
//! `_bucket` series with increasing `le` bounds and non-decreasing
//! cumulative counts ending at `+Inf == _count`, and summary quantile
//! lines carrying a `quantile` label. It also pins the presence of the
//! deploy-correlation series (`acdc_build_info`,
//! `process_start_time_seconds`) and the per-stage trace histograms
//! after traffic.

use acdc::config::{GatewayConfig, ServeConfig};
use acdc::gateway::http;
use acdc::gateway::Gateway;
use acdc::sell::acdc::AcdcCascade;
use acdc::sell::init::DiagInit;
use acdc::serve::Server;
use acdc::util::json::Json;
use acdc::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn one_shot(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        body,
    )
    .expect("write request");
    http::read_response(&mut reader).expect("read response")
}

/// `[a-zA-Z_:][a-zA-Z0-9_:]*` — Prometheus metric-name charset.
fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// `[a-zA-Z_][a-zA-Z0-9_]*` — label-name charset (no colons).
fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

#[derive(Debug)]
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

impl Sample {
    fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Parse one sample line: `name value` or `name{k="v",...} value`.
fn parse_sample(line: &str) -> Result<Sample, String> {
    let (name, labels, rest) = match line.find('{') {
        Some(open) => {
            let close = line.rfind('}').ok_or("unterminated label set")?;
            let name = &line[..open];
            let mut labels = Vec::new();
            // Walk `k="v"` pairs left to right; commas may legally appear
            // *inside* quoted values (e.g. features="pjrt,count-allocs"),
            // so split on the closing quote, not on commas.
            let mut rest = &line[open + 1..close];
            while !rest.is_empty() {
                let eq = rest
                    .find('=')
                    .ok_or_else(|| format!("label segment '{rest}' has no '='"))?;
                let key = &rest[..eq];
                if !valid_label_name(key) {
                    return Err(format!("bad label name '{key}'"));
                }
                let after = &rest[eq + 1..];
                if !after.starts_with('"') {
                    return Err(format!("unquoted label value after '{key}'"));
                }
                let end = after[1..]
                    .find('"')
                    .ok_or_else(|| format!("unterminated value for '{key}'"))?
                    + 1;
                let inner = &after[1..end];
                if inner.contains('\\') || inner.contains('\n') {
                    return Err(format!("unescaped char in label value '{inner}'"));
                }
                labels.push((key.to_string(), inner.to_string()));
                rest = &after[end + 1..];
                if let Some(stripped) = rest.strip_prefix(',') {
                    if stripped.is_empty() {
                        return Err("trailing comma in label set".into());
                    }
                    rest = stripped;
                } else if !rest.is_empty() {
                    return Err(format!("junk after label value: '{rest}'"));
                }
            }
            (name, labels, &line[close + 1..])
        }
        None => {
            let sp = line.find(' ').ok_or("sample has no value")?;
            (&line[..sp], Vec::new(), &line[sp..])
        }
    };
    if !valid_metric_name(name) {
        return Err(format!("bad metric name '{name}'"));
    }
    let value_str = rest.trim();
    if value_str.is_empty() || value_str.contains(' ') {
        return Err(format!("malformed value field '{value_str}'"));
    }
    let value: f64 = value_str
        .parse()
        .map_err(|e| format!("value '{value_str}': {e}"))?;
    Ok(Sample {
        name: name.to_string(),
        labels,
        value,
    })
}

/// Map a sample name back to its declared family: exact match, or a
/// `_sum` / `_count` / `_bucket` suffix of a summary/histogram family.
fn family_of<'a>(types: &'a BTreeMap<String, String>, sample: &Sample) -> Option<(&'a str, &'a str)> {
    if let Some((name, ty)) = types.get_key_value(&sample.name) {
        return Some((name.as_str(), ty.as_str()));
    }
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample.name.strip_suffix(suffix) {
            if let Some((name, ty)) = types.get_key_value(base) {
                let suffix_ok = match ty.as_str() {
                    "histogram" => true,
                    "summary" => suffix != "_bucket",
                    _ => false,
                };
                if suffix_ok {
                    return Some((name.as_str(), ty.as_str()));
                }
            }
        }
    }
    None
}

#[test]
fn full_metrics_payload_is_strict_prometheus_exposition() {
    let n = 16;
    let mut rng = Pcg32::seeded(61);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 4],
        max_wait_us: 200,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    // Traffic first, so every request-path series has recorded samples:
    // single-row and multi-row requests through the traced infer path.
    let single = {
        let features = Json::Arr((0..n).map(|_| Json::Num(0.25)).collect());
        acdc::util::json::obj(vec![("features", features)]).to_string()
    };
    let batch = {
        let row = Json::Arr((0..n).map(|_| Json::Num(-0.5)).collect());
        let rows = Json::Arr(vec![row.clone(), row.clone(), row]);
        acdc::util::json::obj(vec![("rows", rows)]).to_string()
    };
    for i in 0..6 {
        let body = if i % 2 == 0 { &single } else { &batch };
        let resp = one_shot(addr, "POST", "/v1/infer", body.as_bytes());
        assert_eq!(resp.status, 200, "{}", resp.body_str());
    }

    // Spans are recorded just after each response flush: poll until the
    // 6th request's stages have landed so the counts below are exact.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let text = loop {
        let metrics = one_shot(addr, "GET", "/metrics", b"");
        assert_eq!(metrics.status, 200);
        let t = metrics.body_str().to_string();
        if t.contains("acdc_trace_write_ns_hist_count 6") {
            break t;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "trace histograms never reached 6 requests:\n{t}"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    gateway.shutdown();

    // ---- strict parse of every line ----
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut samples: Vec<Sample> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let ctx = |e: String| format!("line {}: '{line}': {e}", lineno + 1);
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap_or("");
            let ty = it.next().unwrap_or("");
            assert!(it.next().is_none(), "{}", ctx("trailing TYPE tokens".into()));
            assert!(valid_metric_name(name), "{}", ctx("bad family name".into()));
            assert!(
                matches!(ty, "counter" | "gauge" | "summary" | "histogram"),
                "{}",
                ctx(format!("unknown type '{ty}'"))
            );
            assert!(
                types.insert(name.to_string(), ty.to_string()).is_none(),
                "{}",
                ctx("duplicate TYPE declaration".into())
            );
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment lines are legal, uninterpreted.
        }
        match parse_sample(line) {
            Ok(s) => samples.push(s),
            Err(e) => panic!("{}", ctx(e)),
        }
    }

    // Every sample belongs to a declared family; suffix/label shape match.
    for s in &samples {
        let (family, ty) = family_of(&types, s)
            .unwrap_or_else(|| panic!("sample '{}' has no TYPE declaration", s.name));
        match ty {
            "histogram" if s.name.ends_with("_bucket") => {
                assert!(s.label("le").is_some(), "{} bucket without le", s.name);
            }
            "summary" if s.name == family => {
                assert!(
                    s.label("quantile").is_some(),
                    "summary base sample '{}' without quantile label",
                    s.name
                );
            }
            _ => {}
        }
    }
    // Every declared family rendered at least one sample.
    for family in types.keys() {
        assert!(
            samples
                .iter()
                .any(|s| family_of(&types, s).is_some_and(|(f, _)| f == family.as_str())),
            "TYPE {family} declared but no samples rendered"
        );
    }

    // ---- histogram invariants, family by family ----
    let hist_families: Vec<&String> = types
        .iter()
        .filter(|(_, ty)| ty.as_str() == "histogram")
        .map(|(name, _)| name)
        .collect();
    assert!(!hist_families.is_empty(), "no histogram families rendered");
    for family in hist_families {
        let buckets: Vec<&Sample> = samples
            .iter()
            .filter(|s| s.name == format!("{family}_bucket"))
            .collect();
        assert!(!buckets.is_empty(), "{family}: no _bucket series");
        let mut last_le = f64::NEG_INFINITY;
        let mut last_count = 0.0f64;
        for (i, b) in buckets.iter().enumerate() {
            let le_str = b.label("le").unwrap();
            let le = if le_str == "+Inf" {
                assert_eq!(i, buckets.len() - 1, "{family}: +Inf bucket not last");
                f64::INFINITY
            } else {
                le_str.parse::<f64>().unwrap_or_else(|e| {
                    panic!("{family}: unparsable le '{le_str}': {e}")
                })
            };
            assert!(le > last_le, "{family}: le not increasing at '{le_str}'");
            assert!(
                b.value >= last_count,
                "{family}: cumulative count regressed at le='{le_str}'"
            );
            last_le = le;
            last_count = b.value;
        }
        assert_eq!(
            buckets.last().unwrap().label("le"),
            Some("+Inf"),
            "{family}: bucket series must end at +Inf"
        );
        let count = samples
            .iter()
            .find(|s| s.name == format!("{family}_count"))
            .unwrap_or_else(|| panic!("{family}: missing _count"));
        assert_eq!(
            buckets.last().unwrap().value,
            count.value,
            "{family}: +Inf bucket disagrees with _count"
        );
        assert!(
            samples.iter().any(|s| s.name == format!("{family}_sum")),
            "{family}: missing _sum"
        );
    }

    // ---- deploy-correlation and observability series presence ----
    let build = samples
        .iter()
        .find(|s| s.name == "acdc_build_info")
        .expect("acdc_build_info sample");
    assert_eq!(build.value, 1.0);
    for label in ["version", "features", "simd"] {
        assert!(
            build.label(label).is_some_and(|v| !v.is_empty()),
            "acdc_build_info missing label {label}"
        );
    }
    let start = samples
        .iter()
        .find(|s| s.name == "process_start_time_seconds")
        .expect("process_start_time_seconds sample");
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_secs() as f64;
    assert!(
        start.value > 1.6e9 && start.value <= now + 10.0,
        "implausible process start time {}",
        start.value
    );

    // The per-stage trace histograms are live after traffic: the execute
    // stage saw all 6 requests end-to-end.
    for stage in [
        "parse",
        "admission",
        "queue_wait",
        "batch_form",
        "execute",
        "serialize",
        "write",
    ] {
        let name = format!("acdc_trace_{stage}_ns_hist_count");
        let s = samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing trace stage histogram {name}"));
        assert_eq!(s.value, 6.0, "{name} missed requests");
    }
    // Batch-occupancy and queue-depth series from the coordinator side.
    assert!(
        samples.iter().any(|s| s.name == "acdc_worker_batch_occupancy_rows_count"),
        "missing worker batch-occupancy histogram"
    );
    assert!(
        samples.iter().any(|s| s.name == "acdc_coordinator_queue_depth"),
        "missing coordinator queue-depth gauge"
    );
}
