//! Property test: finite-difference gradient checks for every SELL
//! family's backward pass — `AcdcLayer` (paper eqs. 10–14),
//! `FastfoodLayer` (S/G/B through the FWHT chain), `LowRankLayer` (U/V)
//! and `DiagonalCirculantLayer` (r/d through the FFT).
//!
//! The ACDC and Fastfood backward passes have two implementations picked
//! by batch size: the scalar per-row path below `MIN_SOA_ROWS` and the
//! batched SoA path from `MIN_SOA_ROWS` up. The sweeps drive both across
//! several widths N and batch sizes that straddle the path boundary and
//! are deliberately not multiples of the 8-lane panel (so padded tail
//! lanes are exercised). N itself is constrained to powers of two by
//! `DctPlan` (the paper's radix-2 FFT substrate); the sweep covers the
//! even-N family end to end and pins that constraint in a test so a
//! silent relaxation would fail loudly here. Low-rank is plain matmul —
//! its sweep includes a non-pow2 width to pin the exemption.

use acdc::dct::{DctPlan, MIN_SOA_ROWS};
use acdc::sell::acdc::AcdcLayer;
use acdc::sell::circulant::DiagonalCirculantLayer;
use acdc::sell::fastfood::FastfoodLayer;
use acdc::sell::init::DiagInit;
use acdc::sell::lowrank::LowRankLayer;
use acdc::sell::LinearOp;
use acdc::tensor::Tensor;
use acdc::util::rng::Pcg32;

/// Widths × batch shapes for the family sweeps: rows straddle the
/// scalar/SoA boundary (MIN_SOA_ROWS = 4) and avoid multiples of the
/// 8-lane panel, so 5, 9 and 12 leave partially-filled tail panels.
const FAMILY_WIDTHS: [usize; 3] = [8, 16, 64];
const FAMILY_ROWS: [usize; 5] = [1, 3, 5, 9, 12];

/// Central finite difference of the scalar loss `L = 0.5·Σ y²` under a
/// single-parameter perturbation.
fn loss(layer: &AcdcLayer, x: &Tensor) -> f64 {
    layer
        .forward_batch(x)
        .data()
        .iter()
        .map(|v| 0.5 * (*v as f64).powi(2))
        .sum()
}

fn fd_check(got: f32, fd: f64, what: &str) {
    let got = got as f64;
    let tol = 3e-2 * fd.abs().max(1.0);
    assert!(
        (got - fd).abs() < tol,
        "{what}: analytic {got} vs finite-difference {fd} (tol {tol})"
    );
}

#[test]
fn backward_matches_finite_differences_on_both_paths() {
    let eps = 1e-3_f32;
    // Batch sizes straddling the scalar/SoA boundary (MIN_SOA_ROWS = 4)
    // and avoiding multiples of the 8-lane panel: 5, 9 and 12 leave
    // partially-filled tail panels.
    let row_counts = [1usize, 3, MIN_SOA_ROWS, 5, 9, 12];
    for n in [8usize, 16, 64] {
        for rows in row_counts {
            let mut rng = Pcg32::seeded(1000 + (n * 31 + rows) as u64);
            let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.2);
            layer.bias = rng.normal_vec(n, 0.0, 0.1);
            let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
            // L = 0.5·||y||² ⇒ ∂L/∂y = y.
            let y = layer.forward_batch(&x);
            let (gx, grads) = layer.backward(&x, &y);
            let ctx = |p: &str, i: usize| format!("n={n} rows={rows} {p}[{i}]");

            for idx in [0usize, n / 2, n - 1] {
                for (param, got) in [("a", grads.a[idx]), ("d", grads.d[idx]), ("bias", grads.bias[idx])]
                {
                    let perturb = |dir: f32| {
                        let mut l = layer.clone();
                        match param {
                            "a" => l.a[idx] += dir * eps,
                            "d" => l.d[idx] += dir * eps,
                            _ => l.bias[idx] += dir * eps,
                        }
                        loss(&l, &x)
                    };
                    let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                    fd_check(got, fd, &ctx(param, idx));
                }
            }

            // ∂L/∂x at scattered coordinates (first row, middle row, last
            // row — the SoA path maps these to different panel lanes).
            for (r, i) in [(0usize, 0usize), (rows / 2, n / 2), (rows - 1, n - 1)] {
                let perturb = |dir: f32| {
                    let mut xp = x.clone();
                    let v = xp.get2(r, i) + dir * eps;
                    xp.set2(r, i, v);
                    loss(&layer, &xp)
                };
                let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                fd_check(gx.get2(r, i), fd, &format!("n={n} rows={rows} x[{r},{i}]"));
            }
        }
    }
}

/// `L = 0.5·Σ y²` through any family's serve-path forward.
fn op_loss(op: &dyn LinearOp, x: &Tensor) -> f64 {
    op.forward(x)
        .data()
        .iter()
        .map(|v| 0.5 * (*v as f64).powi(2))
        .sum()
}

#[test]
fn fastfood_backward_matches_finite_differences_on_both_paths() {
    let eps = 1e-3_f32;
    for n in FAMILY_WIDTHS {
        for rows in FAMILY_ROWS {
            let mut rng = Pcg32::seeded(2000 + (n * 31 + rows) as u64);
            let layer = FastfoodLayer::random(n, &mut rng);
            let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
            // L = 0.5·||y||² ⇒ ∂L/∂y = y.
            let y = layer.forward(&x);
            let (gx, grads) = layer.backward(&x, &y);

            for idx in [0usize, n / 2, n - 1] {
                for (param, got) in [("s", grads.s[idx]), ("g", grads.g[idx]), ("b", grads.b[idx])]
                {
                    let perturb = |dir: f32| {
                        let mut l = layer.clone();
                        match param {
                            "s" => l.s[idx] += dir * eps,
                            "g" => l.g[idx] += dir * eps,
                            _ => l.b[idx] += dir * eps,
                        }
                        op_loss(&l, &x)
                    };
                    let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                    fd_check(got, fd, &format!("fastfood n={n} rows={rows} {param}[{idx}]"));
                }
            }

            for (r, i) in [(0usize, 0usize), (rows / 2, n / 2), (rows - 1, n - 1)] {
                let perturb = |dir: f32| {
                    let mut xp = x.clone();
                    let v = xp.get2(r, i) + dir * eps;
                    xp.set2(r, i, v);
                    op_loss(&layer, &xp)
                };
                let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                fd_check(gx.get2(r, i), fd, &format!("fastfood n={n} rows={rows} x[{r},{i}]"));
            }
        }
    }
}

#[test]
fn fastfood_backward_paths_agree_at_the_boundary() {
    // Scalar per-row gradients (rows < MIN_SOA_ROWS) padded with one zero
    // row must match the SoA panel path — the pad lanes of the panel
    // buffers are zero-filled, so summed parameter gradients can't pick
    // up garbage from uninitialized tail lanes.
    let n = 16;
    let mut rng = Pcg32::seeded(17);
    let layer = FastfoodLayer::random(n, &mut rng);
    let small = MIN_SOA_ROWS - 1;
    let x_small = Tensor::from_vec(&[small, n], rng.normal_vec(small * n, 0.0, 1.0));
    let g_small = Tensor::from_vec(&[small, n], rng.normal_vec(small * n, 0.0, 1.0));
    let (gx_small, grads_small) = layer.backward(&x_small, &g_small);

    let mut x_pad = x_small.data().to_vec();
    x_pad.extend(vec![0.0; n]);
    let mut g_pad = g_small.data().to_vec();
    g_pad.extend(vec![0.0; n]);
    let x_big = Tensor::from_vec(&[MIN_SOA_ROWS, n], x_pad);
    let g_big = Tensor::from_vec(&[MIN_SOA_ROWS, n], g_pad);
    let (gx_big, grads_big) = layer.backward(&x_big, &g_big);

    for i in 0..n {
        assert!((grads_small.s[i] - grads_big.s[i]).abs() < 1e-3, "s[{i}]");
        assert!((grads_small.g[i] - grads_big.g[i]).abs() < 1e-3, "g[{i}]");
        assert!((grads_small.b[i] - grads_big.b[i]).abs() < 1e-3, "b[{i}]");
    }
    for r in 0..small {
        for i in 0..n {
            assert!(
                (gx_small.get2(r, i) - gx_big.get2(r, i)).abs() < 1e-4,
                "gx[{r},{i}]"
            );
        }
    }
}

#[test]
fn lowrank_backward_matches_finite_differences_including_non_pow2() {
    // Width 12 rides along: low-rank is plain matmul and is exempt from
    // the pow2 constraint the transform families carry.
    let eps = 1e-3_f32;
    for n in [8usize, 12, 16, 64] {
        for rows in FAMILY_ROWS {
            let rank = (n / 2).max(1);
            let mut rng = Pcg32::seeded(3000 + (n * 31 + rows) as u64);
            let layer = LowRankLayer::random(n, rank, &mut rng);
            let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
            let y = layer.forward(&x);
            let (gx, grads) = layer.backward(&x, &y);

            for (i, j) in [(0usize, 0usize), (n / 2, rank / 2), (n - 1, rank - 1)] {
                let fd_u = {
                    let perturb = |dir: f32| {
                        let mut l = layer.clone();
                        let v = l.u.get2(i, j) + dir * eps;
                        l.u.set2(i, j, v);
                        op_loss(&l, &x)
                    };
                    (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64)
                };
                fd_check(grads.u.get2(i, j), fd_u, &format!("lowrank n={n} rows={rows} u[{i},{j}]"));
                let fd_v = {
                    let perturb = |dir: f32| {
                        let mut l = layer.clone();
                        let v = l.v.get2(j, i) + dir * eps;
                        l.v.set2(j, i, v);
                        op_loss(&l, &x)
                    };
                    (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64)
                };
                fd_check(grads.v.get2(j, i), fd_v, &format!("lowrank n={n} rows={rows} v[{j},{i}]"));
            }

            for (r, i) in [(0usize, 0usize), (rows / 2, n / 2), (rows - 1, n - 1)] {
                let perturb = |dir: f32| {
                    let mut xp = x.clone();
                    let v = xp.get2(r, i) + dir * eps;
                    xp.set2(r, i, v);
                    op_loss(&layer, &xp)
                };
                let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                fd_check(gx.get2(r, i), fd, &format!("lowrank n={n} rows={rows} x[{r},{i}]"));
            }
        }
    }
}

#[test]
fn circulant_backward_matches_finite_differences() {
    let eps = 1e-3_f32;
    for n in FAMILY_WIDTHS {
        for rows in FAMILY_ROWS {
            let mut rng = Pcg32::seeded(4000 + (n * 31 + rows) as u64);
            let layer = DiagonalCirculantLayer::init(
                n,
                DiagInit {
                    mean: 1.0,
                    sigma: 0.2,
                },
                &mut rng,
            );
            let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
            let y = layer.forward(&x);
            let (gx, grads) = layer.backward(&x, &y);

            for idx in [0usize, n / 2, n - 1] {
                for (param, got) in [("r", grads.r[idx]), ("d", grads.d[idx])] {
                    let perturb = |dir: f32| {
                        let mut l = layer.clone();
                        match param {
                            "r" => l.r[idx] += dir * eps,
                            _ => l.d[idx] += dir * eps,
                        }
                        op_loss(&l, &x)
                    };
                    let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                    fd_check(got, fd, &format!("circulant n={n} rows={rows} {param}[{idx}]"));
                }
            }

            for (r, i) in [(0usize, 0usize), (rows / 2, n / 2), (rows - 1, n - 1)] {
                let perturb = |dir: f32| {
                    let mut xp = x.clone();
                    let v = xp.get2(r, i) + dir * eps;
                    xp.set2(r, i, v);
                    op_loss(&layer, &xp)
                };
                let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps as f64);
                fd_check(gx.get2(r, i), fd, &format!("circulant n={n} rows={rows} x[{r},{i}]"));
            }
        }
    }
}

#[test]
fn backward_paths_agree_at_the_boundary() {
    // rows = MIN_SOA_ROWS-1 (scalar) summed per-row must equal
    // rows = MIN_SOA_ROWS (SoA) on the same leading rows' gradients when
    // the extra row carries zero upstream gradient and zero input — the
    // batch-sum property the training loop relies on.
    let n = 16;
    let mut rng = Pcg32::seeded(7);
    let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.2);
    layer.bias = rng.normal_vec(n, 0.0, 0.1);
    let small = MIN_SOA_ROWS - 1;
    let x_small = Tensor::from_vec(&[small, n], rng.normal_vec(small * n, 0.0, 1.0));
    let g_small = Tensor::from_vec(&[small, n], rng.normal_vec(small * n, 0.0, 1.0));
    let (gx_small, grads_small) = layer.backward(&x_small, &g_small);

    // Pad with one zero row: same totals through the SoA path.
    let mut x_pad = x_small.data().to_vec();
    x_pad.extend(vec![0.0; n]);
    let mut g_pad = g_small.data().to_vec();
    g_pad.extend(vec![0.0; n]);
    let x_big = Tensor::from_vec(&[MIN_SOA_ROWS, n], x_pad);
    let g_big = Tensor::from_vec(&[MIN_SOA_ROWS, n], g_pad);
    let (gx_big, grads_big) = layer.backward(&x_big, &g_big);

    for i in 0..n {
        assert!((grads_small.a[i] - grads_big.a[i]).abs() < 1e-3, "a[{i}]");
        assert!((grads_small.d[i] - grads_big.d[i]).abs() < 1e-3, "d[{i}]");
        assert!(
            (grads_small.bias[i] - grads_big.bias[i]).abs() < 1e-3,
            "bias[{i}]"
        );
    }
    for r in 0..small {
        for i in 0..n {
            assert!(
                (gx_small.get2(r, i) - gx_big.get2(r, i)).abs() < 1e-4,
                "gx[{r},{i}]"
            );
        }
    }
}

#[test]
fn dct_plan_is_power_of_two_only() {
    // The sweep above cannot cover odd N because the radix-2 FFT
    // substrate rejects it; pin that contract so a future generalization
    // (mixed-radix / Bluestein) knows to extend the gradient sweep too.
    for n in [3usize, 6, 12] {
        let r = std::panic::catch_unwind(|| DctPlan::new(n));
        assert!(r.is_err(), "DctPlan::new({n}) unexpectedly succeeded");
    }
}

#[test]
fn pooled_training_loss_is_bit_identical_to_serial_engine() {
    // The trainer's hot path (`forward_train_pooled` → backward →
    // update) fans panels across the thread pool; panel ranges are
    // disjoint, so the pooled sweep must reproduce the serial engine's
    // training loss TO THE BIT across batch shapes, including
    // non-multiples of the 8-lane panel. A drift here would make
    // training results depend on pool sizing.
    use acdc::sell::acdc::AcdcCascade;
    use acdc::sell::init::DiagInit;
    use acdc::util::threadpool::ThreadPool;

    let pool = ThreadPool::new(3);
    for (n, k) in [(16usize, 2usize), (32, 3)] {
        for rows in [MIN_SOA_ROWS, 7, 16, 33] {
            let mut rng = Pcg32::seeded(9000 + (n * 7 + rows) as u64);
            let cascade = AcdcCascade::linear(n, k, DiagInit::IDENTITY, &mut rng);
            let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
            let target = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));

            let loss_of = |pred: &Tensor| -> f64 {
                let diff = pred.sub(&target);
                let sum: f64 = diff.data().iter().map(|v| (*v as f64).powi(2)).sum();
                sum / rows as f64
            };
            let (pred_serial, cache_serial) = cascade.forward_train(&x);
            let (pred_pooled, cache_pooled) = cascade.forward_train_pooled(&x, &pool);
            let (l_serial, l_pooled) = (loss_of(&pred_serial), loss_of(&pred_pooled));
            assert_eq!(
                l_serial.to_bits(),
                l_pooled.to_bits(),
                "n={n} k={k} rows={rows}: pooled loss {l_pooled} != serial {l_serial}"
            );

            // Gradients from the two caches agree bit-for-bit too (the
            // backward itself runs on the serial engine in both cases).
            let mut g = pred_serial.sub(&target);
            g.scale(2.0 / rows as f32);
            let (_, grads_serial) = cascade.backward(&cache_serial, &g);
            let (_, grads_pooled) = cascade.backward(&cache_pooled, &g);
            for (gs, gp) in grads_serial.iter().zip(&grads_pooled) {
                for (a, b) in gs.a.iter().zip(&gp.a) {
                    assert_eq!(a.to_bits(), b.to_bits(), "grad a n={n} rows={rows}");
                }
                for (a, b) in gs.d.iter().zip(&gp.d) {
                    assert_eq!(a.to_bits(), b.to_bits(), "grad d n={n} rows={rows}");
                }
            }
        }
    }
}
