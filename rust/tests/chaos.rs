//! Chaos suite: deterministic fault injection, deadline propagation,
//! brownout degradation, and circuit breaking under induced failure.
//!
//! Covers the robustness acceptance paths:
//!
//! * **deadline cancellation** — a 100%-probability injected executor
//!   delay makes every batch slower than the client deadline: queued
//!   requests are reaped before execution (`gateway.deadline_reaped`),
//!   the executor runs measurably fewer rows than were offered, and a
//!   control run without deadlines executes everything;
//! * **typed fault surfacing** — injected executor errors come back as
//!   500s with the executor message, not opaque timeouts;
//! * **header parity** — the JSON and binary wire frames travel the same
//!   `x-acdc-deadline-ms` path (same 504 + `Retry-After` outcome);
//! * **clamp properties** — deadline clamping is total, monotone, and
//!   saturating on `[1, max_deadline_ms]`;
//! * **budget propagation** — a router hop forwards a strictly smaller
//!   deadline budget than it received, and a hedge is refused when the
//!   remaining budget cannot cover the hedge target's observed p50;
//! * **brownout** — sustained in-flight pressure walks the degradation
//!   ladder up (`acdc_brownout_level` > 0) and hysteresis walks it back
//!   to zero when the load stops;
//! * **circuit breaking** — a SIGSTOPped shard trips its breaker on
//!   request-path timeouts and is re-admitted through a half-open probe,
//!   while `/healthz` hysteresis never marks it down.
//!
//! Multi-process tests inherit `ACDC_GW_MODE`, so the CI chaos lane runs
//! this file under both the reactor and threaded gateways, single
//! threaded (`--test-threads=1`).

use acdc::config::{BrownoutConfig, ClusterConfig, FaultsConfig, GatewayConfig, ServeConfig};
use acdc::coordinator::worker::{BatchExecutor, ExecutorFactory};
use acdc::gateway::http;
use acdc::gateway::wire;
use acdc::gateway::Gateway;
use acdc::registry::SellModel;
use acdc::sell::acdc::{AcdcCascade, AcdcLayer};
use acdc::sell::init::DiagInit;
use acdc::serve::Server;
use acdc::util::json::{obj, Json};
use acdc::util::rng::Pcg32;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One HTTP exchange on a fresh connection, with arbitrary extra headers.
fn one_shot(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> http::ClientResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    http::write_request(&mut stream, method, path, headers, body).expect("write request");
    http::read_response(&mut reader).expect("read response")
}

fn infer_body(row: &[f32]) -> Vec<u8> {
    let features = Json::Arr(row.iter().map(|v| Json::Num(*v as f64)).collect());
    obj(vec![("features", features)]).to_string().into_bytes()
}

/// Exact-name lookup in a Prometheus `/metrics` payload
/// (`acdc_foo_bar 3` lines; labelled/histogram series are skipped).
fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| {
            let mut parts = l.split_whitespace();
            match (parts.next(), parts.next()) {
                (Some(n), Some(v)) if n == name => v.parse().ok(),
                _ => None,
            }
        })
        .unwrap_or_else(|| panic!("metric {name} not found in:\n{text}"))
}

fn scrape(addr: SocketAddr) -> String {
    let resp = one_shot(addr, "GET", "/metrics", &[], b"");
    assert_eq!(resp.status, 200);
    resp.body_str().to_string()
}

/// A serving gateway over a native ACDC cascade with the given injected
/// faults: 1 worker, bucket [1], immediate batch formation — every
/// request is its own batch, so per-batch fault draws map 1:1 onto
/// requests.
fn faulty_gateway(n: usize, faults: FaultsConfig, gateway: GatewayConfig) -> Gateway {
    let mut rng = Pcg32::seeded(5);
    let cascade = AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 1,
        workers: 1,
        queue_cap: 64,
        faults,
        gateway,
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    Gateway::start(server, cfg.gateway.clone()).unwrap()
}

#[test]
fn deadlines_cancel_work_an_injected_delay_made_stale() {
    let n = 16;
    let delay = FaultsConfig {
        enabled: true,
        delay_ms: 200,
        delay_prob: 1.0,
        ..Default::default()
    };
    let gateway = faulty_gateway(
        n,
        delay.clone(),
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            request_timeout_ms: 30_000,
            ..Default::default()
        },
    );
    let addr = gateway.local_addr();

    // 4 clients × 3 requests, each carrying a 50ms budget against a
    // 200ms injected executor delay: at most the first batch or two can
    // execute before every queued deadline has passed.
    let offered = 12u64;
    let handles: Vec<_> = (0..4)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(40 + c);
                let mut statuses = Vec::new();
                for _ in 0..3 {
                    let row: Vec<f32> = rng.normal_vec(16, 0.0, 1.0);
                    let resp = one_shot(
                        addr,
                        "POST",
                        "/v1/infer",
                        &[("content-type", "application/json"), ("x-acdc-deadline-ms", "50")],
                        &infer_body(&row),
                    );
                    statuses.push((resp.status, resp.header("retry-after").is_some()));
                }
                statuses
            })
        })
        .collect();
    let mut saw_504 = false;
    for h in handles {
        for (status, has_retry_after) in h.join().unwrap() {
            assert!(
                status == 200 || status == 504,
                "only success or deadline-exceeded expected, got {status}"
            );
            if status == 504 {
                saw_504 = true;
                assert!(has_retry_after, "504 must carry Retry-After");
            }
        }
    }
    assert!(saw_504, "50ms budgets against 200ms delays must expire");

    // Let the worker drain whatever the batcher already formed, then
    // check the cancellation actually reached the executor.
    std::thread::sleep(Duration::from_millis(600));
    let text = scrape(addr);
    let reaped = metric_value(&text, "acdc_gateway_deadline_reaped");
    let rows = metric_value(&text, "acdc_worker_rows");
    assert!(reaped > 0.0, "expired requests must be reaped, got {text}");
    assert!(
        rows < offered as f64,
        "executor ran {rows} rows but only expired work was queued (offered {offered})"
    );
    gateway.shutdown();

    // Control: same injected delay, no client deadlines (the 5s default
    // dwarfs the queueing) — everything executes, nothing is reaped.
    let control = faulty_gateway(
        n,
        delay,
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            request_timeout_ms: 30_000,
            ..Default::default()
        },
    );
    let caddr = control.local_addr();
    let control_offered = 8;
    let handles: Vec<_> = (0..4)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(80 + c);
                for _ in 0..2 {
                    let row: Vec<f32> = rng.normal_vec(16, 0.0, 1.0);
                    let resp = one_shot(
                        caddr,
                        "POST",
                        "/v1/infer",
                        &[("content-type", "application/json")],
                        &infer_body(&row),
                    );
                    assert_eq!(resp.status, 200, "control run must execute everything");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let text = scrape(caddr);
    assert_eq!(metric_value(&text, "acdc_gateway_deadline_reaped"), 0.0);
    assert_eq!(metric_value(&text, "acdc_worker_rows"), f64::from(control_offered));
    control.shutdown();
}

#[test]
fn injected_executor_errors_surface_as_typed_500s() {
    let gateway = faulty_gateway(
        8,
        FaultsConfig {
            enabled: true,
            error_prob: 1.0,
            ..Default::default()
        },
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
    );
    let addr = gateway.local_addr();
    let resp = one_shot(
        addr,
        "POST",
        "/v1/infer",
        &[("content-type", "application/json")],
        &infer_body(&[0.5; 8]),
    );
    assert_eq!(resp.status, 500);
    assert!(
        resp.body_str().contains("executor") && resp.body_str().contains("injected"),
        "error must carry the executor message: {}",
        resp.body_str()
    );
    gateway.shutdown();
}

#[test]
fn json_and_binary_frames_share_the_deadline_header_path() {
    let n = 8;
    let gateway = faulty_gateway(
        n,
        FaultsConfig {
            enabled: true,
            delay_ms: 150,
            delay_prob: 1.0,
            ..Default::default()
        },
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            request_timeout_ms: 30_000,
            ..Default::default()
        },
    );
    let addr = gateway.local_addr();
    let row = [0.25f32; 8];
    let mut frame = Vec::new();
    wire::write_binary_request(&mut frame, n, &row);

    // A 20ms budget against a 150ms injected delay expires on both wire
    // formats, with the same typed outcome.
    for (content_type, body) in [
        ("application/json", infer_body(&row)),
        (wire::CONTENT_TYPE, frame.clone()),
    ] {
        let resp = one_shot(
            addr,
            "POST",
            "/v1/infer",
            &[("content-type", content_type), ("x-acdc-deadline-ms", "20")],
            &body,
        );
        assert_eq!(resp.status, 504, "{content_type} must expire");
        assert!(
            resp.header("retry-after").is_some(),
            "{content_type}: 504 must carry Retry-After"
        );
    }
    // Without the header the default 5s budget absorbs the delay: both
    // formats succeed.
    for (content_type, body) in [
        ("application/json", infer_body(&row)),
        (wire::CONTENT_TYPE, frame),
    ] {
        let resp = one_shot(
            addr,
            "POST",
            "/v1/infer",
            &[("content-type", content_type)],
            &body,
        );
        assert_eq!(resp.status, 200, "{content_type} without a deadline");
    }
    // Malformed budgets are a client error, not a default.
    let resp = one_shot(
        addr,
        "POST",
        "/v1/infer",
        &[("content-type", "application/json"), ("x-acdc-deadline-ms", "soon")],
        &infer_body(&row),
    );
    assert_eq!(resp.status, 400);
    gateway.shutdown();
}

#[test]
fn deadline_clamp_is_total_monotone_and_saturating() {
    use acdc::config::LimitsConfig;
    let limits = LimitsConfig {
        default_deadline_ms: 500,
        max_deadline_ms: 1_000,
    };
    assert_eq!(limits.clamp_deadline_ms(None), 500, "absent header → default");
    assert_eq!(limits.clamp_deadline_ms(Some(0)), 1, "zero saturates up to 1");
    assert_eq!(
        limits.clamp_deadline_ms(Some(u64::MAX)),
        1_000,
        "overflow saturates at the max"
    );
    // Deterministic value sweep: total (never panics, never 0), bounded,
    // and monotone in the requested budget.
    let probe = |i: u64| (i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).rotate_left((i % 64) as u32);
    let mut values: Vec<u64> = (0..4_000).map(probe).collect();
    values.extend([0, 1, 2, 499, 500, 501, 999, 1_000, 1_001, u64::MAX]);
    for &v in &values {
        let out = limits.clamp_deadline_ms(Some(v));
        assert!((1..=1_000).contains(&out), "clamp({v}) = {out} out of range");
    }
    values.sort_unstable();
    for pair in values.windows(2) {
        assert!(
            limits.clamp_deadline_ms(Some(pair[0])) <= limits.clamp_deadline_ms(Some(pair[1])),
            "clamp must be monotone: {} vs {}",
            pair[0],
            pair[1]
        );
    }
}

// ---------------------------------------------------------------------------
// Fake upstream shards: real TCP listeners that record the deadline
// budget the router forwards and fail on command.

const MODE_OK: u8 = 0;
/// Read the request, sleep ~40ms, close without answering (transport
/// error → the router retries elsewhere with a smaller budget).
const MODE_DROP: u8 = 1;
/// Read the request and hold the connection open without answering
/// (models a wedged shard; the router's budget expires against it).
const MODE_STALL: u8 = 2;

struct FakeShard {
    addr: SocketAddr,
    /// `x-acdc-deadline-ms` values of inference POSTs, in arrival order.
    seen: Arc<Mutex<Vec<u64>>>,
    mode: Arc<AtomicU8>,
}

impl FakeShard {
    fn start(ok_delay: Duration) -> FakeShard {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mode = Arc::new(AtomicU8::new(MODE_OK));
        let (seen2, mode2) = (Arc::clone(&seen), Arc::clone(&mode));
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let (seen, mode) = (Arc::clone(&seen2), Arc::clone(&mode2));
                std::thread::spawn(move || serve_fake_conn(stream, &seen, &mode, ok_delay));
            }
        });
        FakeShard { addr, seen, mode }
    }

    fn seen_count(&self) -> usize {
        self.seen.lock().unwrap().len()
    }
}

/// Minimal keep-alive HTTP/1.1 server loop: answers `GET` (health
/// probes) with 200, records + answers/fails inference POSTs per the
/// shared mode flag.
fn serve_fake_conn(
    mut stream: TcpStream,
    seen: &Mutex<Vec<u64>>,
    mode: &AtomicU8,
    ok_delay: Duration,
) {
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            return;
        }
        let is_post = line.starts_with("POST");
        let mut content_len = 0usize;
        let mut deadline_ms = None;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h).unwrap_or(0) == 0 {
                return;
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((name, value)) = h.split_once(':') {
                let (name, value) = (name.trim().to_ascii_lowercase(), value.trim());
                if name == "content-length" {
                    content_len = value.parse().unwrap_or(0);
                } else if name == "x-acdc-deadline-ms" {
                    deadline_ms = value.parse().ok();
                }
            }
        }
        let mut body = vec![0u8; content_len];
        if content_len > 0 && reader.read_exact(&mut body).is_err() {
            return;
        }
        if is_post {
            if let Some(ms) = deadline_ms {
                seen.lock().unwrap().push(ms);
            }
            match mode.load(Ordering::Acquire) {
                MODE_DROP => {
                    std::thread::sleep(Duration::from_millis(40));
                    return; // close without a response
                }
                MODE_STALL => {
                    std::thread::sleep(Duration::from_secs(10));
                    return;
                }
                _ => std::thread::sleep(ok_delay),
            }
        }
        let payload = br#"{"output":[0.0],"version":1}"#;
        let resp = format!(
            "HTTP/1.1 200 OK\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n",
            payload.len()
        );
        if stream.write_all(resp.as_bytes()).is_err() || stream.write_all(payload).is_err() {
            return;
        }
    }
}

#[test]
fn router_budget_decrements_across_hops_and_gates_hedges_below_p50() {
    // Two fake shards; slow health probes and huge hysteresis keep
    // /healthz out of the picture, a 64-wide breaker window never trips
    // on the handful of induced failures.
    let a = FakeShard::start(Duration::from_millis(150));
    let b = FakeShard::start(Duration::from_millis(150));
    let cluster = ClusterConfig {
        shards: vec![a.addr.to_string(), b.addr.to_string()],
        replication: 2,
        probe_interval_ms: 60_000,
        down_after: 100,
        up_after: 1,
        hedge_min_ms: 50,
        breaker_window: 64,
        breaker_cooldown_ms: 60_000,
        request_timeout_ms: 10_000,
        ..Default::default()
    };
    let router = Gateway::start_router(
        cluster,
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )
    .unwrap();
    let raddr = router.local_addr();
    let infer = |budget_ms: &str| {
        one_shot(
            raddr,
            "POST",
            "/v1/models/m/infer",
            &[("content-type", "application/json"), ("x-acdc-deadline-ms", budget_ms)],
            &infer_body(&[1.0; 4]),
        )
    };

    // Which shard is ring-primary for "m"? Both idle → the first probe
    // lands on it.
    let resp = infer("5000");
    assert_eq!(resp.status, 200);
    let (primary, secondary) = if a.seen_count() == 1 {
        (&a, &b)
    } else {
        (&b, &a)
    };
    assert_eq!(primary.seen_count() + secondary.seen_count(), 1);

    // Hop decrement: the primary burns ~40ms and fails; the retry must
    // reach the secondary with a strictly smaller budget.
    primary.mode.store(MODE_DROP, Ordering::Release);
    primary.seen.lock().unwrap().clear();
    secondary.seen.lock().unwrap().clear();
    let resp = infer("800");
    assert_eq!(resp.status, 200, "retry onto the live replica");
    let first = primary.seen.lock().unwrap()[0];
    let second = secondary.seen.lock().unwrap()[0];
    assert!(first <= 800, "forwarded budget exceeds the client's: {first}");
    assert!(
        second < first,
        "budget must shrink across hops: {first} → {second}"
    );
    assert!(second >= 1, "forwarded budget floors at 1ms");

    // Warm the secondary's latency history (~150ms p50) through a few
    // more failed-primary retries.
    for _ in 0..4 {
        assert_eq!(infer("5000").status, 200);
    }

    // Hedge gating. The primary now stalls silently. With a fat budget
    // the hedge fires at hedge_min (50ms) and the secondary answers;
    // with 160ms the remaining ~110ms cannot cover the secondary's
    // ~150ms p50, so the hedge is refused and the budget expires.
    primary.mode.store(MODE_STALL, Ordering::Release);
    let before = secondary.seen_count();
    let resp = infer("5000");
    assert_eq!(resp.status, 200, "hedge rescues the stalled primary");
    assert_eq!(secondary.seen_count(), before + 1);

    let before = secondary.seen_count();
    let resp = infer("160");
    assert_eq!(resp.status, 504, "no viable hedge → the budget expires");
    assert!(
        resp.header("retry-after").is_some(),
        "router 504 must carry Retry-After"
    );
    assert_eq!(
        secondary.seen_count(),
        before,
        "a hedge was fired against an upstream whose p50 exceeds the remaining budget"
    );
    router.shutdown();
}

/// Echo executor with a configurable service time (saturates tiny
/// in-flight caps deterministically).
struct SlowEcho {
    n: usize,
    delay: Duration,
}

impl BatchExecutor for SlowEcho {
    fn width(&self) -> usize {
        self.n
    }
    fn out_width(&self) -> usize {
        self.n
    }
    fn execute_into(
        &mut self,
        _bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(padded);
        Ok(())
    }
}

#[test]
fn brownout_ladder_engages_under_sustained_pressure_and_recovers() {
    let n = 8;
    let cfg = ServeConfig {
        buckets: vec![1],
        max_wait_us: 1,
        workers: 1,
        queue_cap: 64,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 4,
            request_timeout_ms: 10_000,
            brownout: BrownoutConfig {
                enabled: true,
                tick_ms: 10,
                hot_inflight_pct: 0.5,
                up_after: 2,
                down_after: 3,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let factory: ExecutorFactory = Arc::new(move || {
        Ok(Box::new(SlowEcho {
            n,
            delay: Duration::from_millis(30),
        }) as Box<dyn BatchExecutor>)
    });
    let server = Server::start_custom(&cfg, n, factory);
    let gateway = Gateway::start(server, cfg.gateway.clone()).unwrap();
    let addr = gateway.local_addr();

    // 12 closed-loop clients against max_inflight 4 keep the in-flight
    // gauge pinned past the 50% hot threshold.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let handles: Vec<_> = (0..12)
        .map(|_| {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let _ = one_shot(
                        addr,
                        "POST",
                        "/v1/infer",
                        &[("content-type", "application/json")],
                        &infer_body(&[1.0; 8]),
                    );
                }
            })
        })
        .collect();

    // The ladder must climb within a few ticks (10ms tick, up_after 2).
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut level = 0.0;
    while Instant::now() < deadline {
        level = metric_value(&scrape(addr), "acdc_brownout_level");
        if level >= 1.0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(level >= 1.0, "brownout never engaged under saturation");

    // Load stops → cool ticks walk the ladder back to zero.
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        level = metric_value(&scrape(addr), "acdc_brownout_level");
        if level == 0.0 {
            break;
        }
        assert!(Instant::now() < deadline, "brownout never recovered: level {level}");
        std::thread::sleep(Duration::from_millis(25));
    }

    // Fully recovered: a normal request flows again.
    let resp = one_shot(
        addr,
        "POST",
        "/v1/infer",
        &[("content-type", "application/json")],
        &infer_body(&[1.0; 8]),
    );
    assert_eq!(resp.status, 200);
    gateway.shutdown();
}

// ---------------------------------------------------------------------------
// Multi-process breaker test: real shard processes, SIGSTOP as the fault.

/// A spawned child that is SIGKILLed when the test (or a panic unwind)
/// drops it — no orphaned gateways after a failed assertion.
struct Proc(std::process::Child);

impl Drop for Proc {
    fn drop(&mut self) {
        signal(self.0.id(), "-CONT"); // a stopped child ignores SIGKILL
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

fn spawn_acdc(args: &[&str]) -> Proc {
    Proc(
        Command::new(env!("CARGO_BIN_EXE_acdc"))
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn acdc"),
    )
}

fn signal(pid: u32, sig: &str) {
    Command::new("kill")
        .args([sig, &pid.to_string()])
        .status()
        .expect("send signal");
}

/// Poll the `--addr-file` a child writes once its listener is bound.
fn wait_addr(path: &Path) -> SocketAddr {
    let deadline = Instant::now() + Duration::from_secs(20);
    while Instant::now() < deadline {
        if let Ok(s) = std::fs::read_to_string(path) {
            if let Ok(a) = s.trim().parse() {
                return a;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("no address appeared in {}", path.display());
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("acdc_chaos_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// `/v1/cluster` shard state: (healthy, breaker) for shard `i`.
fn shard_state(router: SocketAddr, i: usize) -> (bool, String) {
    let resp = one_shot(router, "GET", "/v1/cluster", &[], b"");
    assert_eq!(resp.status, 200);
    let v = Json::parse(resp.body_str()).unwrap();
    let shard = &v.get("shards").and_then(|s| s.as_arr()).unwrap()[i];
    (
        shard.get("healthy").and_then(|h| h.as_bool()).unwrap(),
        shard
            .get("breaker")
            .and_then(|b| b.as_str())
            .unwrap()
            .to_string(),
    )
}

#[test]
fn sigstopped_shard_trips_the_breaker_without_health_ever_flapping() {
    let n = 8;
    let dir = temp_dir("breaker");
    let ckpt = dir.join("m.ckpt");
    SellModel::Acdc(AcdcCascade {
        layers: vec![AcdcLayer::identity(n)],
        perms: None,
        relu: false,
        train_bias: false,
    })
    .to_checkpoint()
    .unwrap()
    .save(&ckpt)
    .unwrap();

    let shard_cfg = dir.join("shard.toml");
    std::fs::write(
        &shard_cfg,
        format!(
            "[serve]\nbuckets = [1, 8]\nmax_wait_us = 200\nworkers = 2\n\n\
             [gateway]\naddr = \"127.0.0.1:0\"\n\n\
             [registry]\nmodels = [\"m={}\"]\ndefault_model = \"m\"\n",
            ckpt.display()
        ),
    )
    .unwrap();
    let mut shards = Vec::new();
    let mut shard_addrs = Vec::new();
    for i in 0..2 {
        let addr_file = dir.join(format!("shard{i}.addr"));
        std::fs::remove_file(&addr_file).ok();
        shards.push(spawn_acdc(&[
            "shard",
            "--config",
            shard_cfg.to_str().unwrap(),
            "--no-demo",
            "--addr-file",
            addr_file.to_str().unwrap(),
        ]));
        shard_addrs.push(wait_addr(&addr_file));
    }

    // Router: probes effectively off (60s interval) and down_after far
    // out of reach, so /healthz can never mark the stopped shard down —
    // only the breaker reacts. Hedging is disabled (hedge_min_ms 60s) so
    // every stalled exchange burns its own budget.
    let router_cfg = dir.join("router.toml");
    let shard_list = shard_addrs
        .iter()
        .map(|a| format!("\"{a}\""))
        .collect::<Vec<_>>()
        .join(", ");
    std::fs::write(
        &router_cfg,
        format!(
            "[cluster]\nshards = [{shard_list}]\nreplication = 2\n\
             probe_interval_ms = 60000\ndown_after = 100\nup_after = 1\n\
             hedge_min_ms = 60000\nbreaker_window = 4\nbreaker_trip_ratio = 0.5\n\
             breaker_cooldown_ms = 300\n\n\
             [gateway]\naddr = \"127.0.0.1:0\"\n"
        ),
    )
    .unwrap();
    let router_addr_file = dir.join("router.addr");
    std::fs::remove_file(&router_addr_file).ok();
    let _router = spawn_acdc(&[
        "router",
        "--config",
        router_cfg.to_str().unwrap(),
        "--addr-file",
        router_addr_file.to_str().unwrap(),
    ]);
    let router_addr = wait_addr(&router_addr_file);

    let infer = |budget_ms: &str| {
        one_shot(
            router_addr,
            "POST",
            "/v1/models/m/infer",
            &[("content-type", "application/json"), ("x-acdc-deadline-ms", budget_ms)],
            &infer_body(&[1.0; 8]),
        )
    };

    // Which shard answers when everything is idle? That one is ring
    // primary; SIGSTOP it.
    let warm = infer("5000");
    assert_eq!(warm.status, 200);
    let primary: usize = warm
        .header("x-acdc-upstream")
        .and_then(|s| s.parse().ok())
        .expect("router tags the serving upstream");
    signal(shards[primary].0.id(), "-STOP");

    // Each 300ms budget burns out against the stopped shard and records
    // one breaker failure; window 4 @ ratio 0.5 trips within a handful
    // of requests. Health must never flap while this happens.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (healthy, breaker) = shard_state(router_addr, primary);
        assert!(healthy, "/healthz hysteresis must never mark the shard down");
        if breaker == "open" {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "breaker never opened; last state {breaker}"
        );
        let resp = infer("300");
        assert!(
            resp.status == 200 || resp.status == 504,
            "stall phase: got {}",
            resp.status
        );
    }

    // Open breaker: the stopped shard is skipped entirely — traffic is
    // fast and clean on the surviving replica.
    for _ in 0..5 {
        let resp = infer("2000");
        assert_eq!(resp.status, 200, "open breaker must route around the stall");
        let upstream: usize = resp
            .header("x-acdc-upstream")
            .and_then(|s| s.parse().ok())
            .unwrap();
        assert_ne!(upstream, primary, "request fired at an open breaker");
    }
    let trips = metric_value(&scrape(router_addr), "acdc_cluster_breaker_trips");
    assert!(trips >= 1.0);

    // Resume the shard; after the cooldown a half-open probe re-admits
    // it and the breaker closes — again without /healthz involvement.
    signal(shards[primary].0.id(), "-CONT");
    std::thread::sleep(Duration::from_millis(400));
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let resp = infer("2000");
        assert!(resp.status == 200 || resp.status == 504, "probe phase");
        let (healthy, breaker) = shard_state(router_addr, primary);
        assert!(healthy, "health must stay up through recovery");
        if breaker == "closed" {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "breaker never closed after resume; state {breaker}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // And the re-admitted shard actually serves again.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let resp = infer("2000");
        assert_eq!(resp.status, 200);
        let upstream: usize = resp
            .header("x-acdc-upstream")
            .and_then(|s| s.parse().ok())
            .unwrap();
        if upstream == primary {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "re-admitted shard never served a request"
        );
    }
}
