//! E8 — gateway hot path: HTTP framing, admission control, and loopback
//! end-to-end serving through the network gateway.
//!
//! Three sections:
//! 1. request-parse micro-bench (bytes → `Request`, ns/request);
//! 2. admission micro-bench (token bucket + in-flight permit, ns/admit);
//! 3. loopback end-to-end: native ACDC cascade behind the gateway, driven
//!    by the closed-loop load generator over real TCP connections.
//!
//! Run: `cargo bench --bench gateway_hotpath`
//! Env: `ACDC_BENCH_FAST=1` shrinks the end-to-end leg.

use acdc::config::{GatewayConfig, ServeConfig};
use acdc::gateway::admission::Admission;
use acdc::gateway::http::{self, ReadOutcome};
use acdc::gateway::loadgen::{self, ArrivalMode, LoadgenConfig};
use acdc::gateway::Gateway;
use acdc::metrics::Registry;
use acdc::serve::Server;
use acdc::util::bench::{black_box, fmt_ns, Bench};
use acdc::util::rng::Pcg32;
use std::io::Cursor;
use std::sync::Arc;
use std::time::Duration;

fn canned_infer_request(width: usize) -> Vec<u8> {
    let mut rng = Pcg32::seeded(9);
    let features: Vec<String> = rng
        .normal_vec(width, 0.0, 1.0)
        .iter()
        .map(|v| format!("{v}"))
        .collect();
    let body = format!("{{\"features\":[{}]}}", features.join(","));
    let mut wire = Vec::new();
    http::write_request(
        &mut wire,
        "POST",
        "/v1/infer",
        &[("content-type", "application/json")],
        body.as_bytes(),
    )
    .unwrap();
    wire
}

fn main() {
    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let bench = Bench::default();

    // 1. HTTP request parsing.
    let wire = canned_infer_request(256);
    let m = bench.run("http.read_request", || {
        let mut c = Cursor::new(&wire[..]);
        match http::read_request(&mut c, 1 << 20).unwrap() {
            ReadOutcome::Request(req) => {
                black_box(req.body.len());
            }
            other => panic!("{other:?}"),
        }
    });
    println!(
        "http request parse (256-wide row, {} bytes): {} median ({} iters)",
        wire.len(),
        fmt_ns(m.median_ns),
        m.iters
    );

    // 1b. Allocation-free request parsing (the keep-alive hot path).
    let mut scratch = http::RequestScratch::new();
    let m = bench.run("http.read_request_reusing", || {
        let mut c = Cursor::new(&wire[..]);
        match http::read_request_reusing(&mut c, 1 << 20, &mut scratch).unwrap() {
            http::ScratchOutcome::Request => {
                black_box(scratch.body.len());
            }
            other => panic!("{other:?}"),
        }
    });
    println!(
        "http request parse, reused scratch (zero-alloc): {} median ({} iters)",
        fmt_ns(m.median_ns),
        m.iters
    );

    // 2. Admission control (token bucket + permit lifecycle).
    let registry = Registry::new();
    let admission = Arc::new(Admission::new(
        &GatewayConfig {
            max_inflight: 1 << 20,
            rate_rps: 1e9, // effectively unlimited: measures mechanism cost
            rate_burst: 1e6,
            ..Default::default()
        },
        &registry,
    ));
    let m = bench.run("admission.try_admit", || {
        let permit = admission.try_admit().unwrap();
        black_box(&permit);
    });
    println!(
        "admission (bucket + in-flight permit): {} median ({} iters)\n",
        fmt_ns(m.median_ns),
        m.iters
    );

    // 3. Loopback end-to-end through real sockets.
    let n = 256;
    let mut rng = Pcg32::seeded(3);
    let cascade = acdc::sell::acdc::AcdcCascade::nonlinear(
        n,
        12,
        acdc::sell::init::DiagInit::CAFFENET,
        &mut rng,
    );
    let cfg = ServeConfig {
        buckets: vec![1, 8, 32, 128],
        max_wait_us: 1_000,
        workers: 2,
        queue_cap: 8_192,
        gateway: GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 4_096,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::start_native(&cfg, cascade);
    let gateway = Gateway::start(server, cfg.gateway.clone()).expect("gateway");
    let report = loadgen::run(&LoadgenConfig {
        addr: gateway.local_addr().to_string(),
        mode: ArrivalMode::Closed,
        concurrency: 8,
        duration: Duration::from_millis(if fast { 500 } else { 3_000 }),
        width: n,
        rows_mix: vec![1, 1, 1, 8],
        timeout: Duration::from_secs(30),
        seed: 7,
        binary: false,
        ..Default::default()
    })
    .expect("loadgen");
    println!("loopback closed-loop, native ACDC-12 (N=256), 8 workers, mix 3×1+1×8 rows:");
    print!("{}", report.render());
    println!("{}", gateway.metrics_report());
    gateway.shutdown();
}
