//! E7 — serving hot path: coordinator overhead and end-to-end
//! latency/throughput through the dynamic batcher.
//!
//! Three sections:
//! 1. batch-policy micro-bench (pure decision logic, ns/decision);
//! 2. native-executor serving (isolates coordinator overhead from PJRT);
//! 3. PJRT serving end-to-end across batcher deadlines.
//!
//! Run: `make artifacts && cargo bench --bench coordinator_hotpath`
//! Env: `ACDC_BENCH_FAST=1` shrinks request counts.

use acdc::config::ServeConfig;
use acdc::coordinator::batcher::BatchPolicy;
use acdc::serve::{ServeParams, Server};
use acdc::util::bench::{black_box, fmt_ns, percentile, Bench, Table};
use acdc::util::rng::Pcg32;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn drive(server: &Arc<Server>, n: usize, requests: usize, clients: usize) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let server = Arc::clone(server);
            std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(77 + ci as u64);
                let mut lats = Vec::with_capacity(requests / clients);
                for _ in 0..requests / clients {
                    let row = rng.normal_vec(n, 0.0, 1.0);
                    let t = Instant::now();
                    let rx = loop {
                        match server.submit(row.clone()) {
                            Ok(rx) => break rx,
                            Err(_) => std::thread::sleep(Duration::from_micros(50)),
                        }
                    };
                    rx.recv_timeout(Duration::from_secs(120))
                        .expect("response")
                        .output
                        .expect("ok");
                    lats.push(t.elapsed().as_nanos() as f64);
                }
                lats
            })
        })
        .collect();
    let mut lats = vec![];
    for h in handles {
        lats.extend(h.join().unwrap());
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (t0.elapsed().as_secs_f64(), lats)
}

fn main() {
    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let requests = if fast { 400 } else { 4_000 };

    // 1. policy micro-bench
    let policy = BatchPolicy::new(vec![1, 8, 32, 128], Duration::from_micros(2_000));
    let now = Instant::now();
    let bench = Bench::quick();
    let m = bench.run("policy.decide", || {
        black_box(policy.decide(black_box(17), Some(now), now));
    });
    println!(
        "batch-policy decision: {} median ({} iters) — pure coordinator logic\n",
        fmt_ns(m.median_ns),
        m.iters
    );

    // 2. native executor (coordinator overhead without PJRT)
    let n = 256;
    let mut rng = Pcg32::seeded(3);
    let cascade = acdc::sell::acdc::AcdcCascade::nonlinear(
        n,
        12,
        acdc::sell::init::DiagInit::CAFFENET,
        &mut rng,
    );
    let cfg = ServeConfig {
        buckets: vec![1, 8, 32, 128],
        max_wait_us: 1_000,
        workers: 2,
        queue_cap: 8_192,
        ..Default::default()
    };
    let server = Arc::new(Server::start_native(&cfg, cascade));
    let (wall, lats) = drive(&server, n, requests, 8);
    let mut t = Table::new(&["leg", "req/s", "p50", "p90", "p99"]);
    t.row(vec![
        "native ACDC-12 (N=256)".into(),
        format!("{:.0}", lats.len() as f64 / wall),
        fmt_ns(percentile(&lats, 50.0)),
        fmt_ns(percentile(&lats, 90.0)),
        fmt_ns(percentile(&lats, 99.0)),
    ]);
    println!("{}", server.metrics_report());
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }

    // 3. PJRT end-to-end at two batcher deadlines
    if let Ok(_probe) = acdc::runtime::Engine::open(Path::new("artifacts")) {
        for max_wait_us in [500u64, 4_000] {
            let cfg = ServeConfig {
                artifacts_dir: "artifacts".into(),
                buckets: vec![1, 8, 32, 128],
                max_wait_us,
                workers: 2,
                queue_cap: 8_192,
                ..Default::default()
            };
            let server = Arc::new(
                Server::start_pjrt(&cfg, ServeParams::random(n, 12, 10, 1), n).expect("server"),
            );
            // warmup compiles every bucket
            for _ in 0..8 {
                let mut rng = Pcg32::seeded(9);
                server
                    .infer(rng.normal_vec(n, 0.0, 1.0), Duration::from_secs(120))
                    .expect("warmup");
            }
            let (wall, lats) = drive(&server, n, requests, 8);
            t.row(vec![
                format!("pjrt ACDC-12, deadline {}µs", max_wait_us),
                format!("{:.0}", lats.len() as f64 / wall),
                fmt_ns(percentile(&lats, 50.0)),
                fmt_ns(percentile(&lats, 90.0)),
                fmt_ns(percentile(&lats, 99.0)),
            ]);
            if let Ok(s) = Arc::try_unwrap(server) {
                s.shutdown();
            }
        }
    } else {
        println!("(PJRT legs skipped — artifacts not built)");
    }

    println!("coordinator hot path (E7), {} requests, 8 client threads", requests);
    t.print();
}
