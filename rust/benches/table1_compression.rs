//! E3 — regenerates **Table 1**: the parameter/accuracy comparison.
//!
//! Analytic leg: exact parameter arithmetic at CaffeNet scale for every
//! published row (including the 165,888-parameter ACDC stack identity).
//! Measured leg: MiniCaffeNet on synthimg (substitution S2) — dense FC vs
//! ACDC-12 FC trained through the AOT artifacts, reporting the error
//! increase next to the parameter reduction.
//!
//! Run: `make artifacts && cargo bench --bench table1_compression`
//! Env: `ACDC_BENCH_FAST=1` shrinks the training runs.

use acdc::experiments::table1;
use acdc::runtime::Engine;
use std::path::Path;

fn main() {
    print!("{}", table1::render_analytic());
    println!();

    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let engine = match Engine::open(Path::new("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            println!("(measured leg skipped — {e})");
            return;
        }
    };
    let (train_rows, test_rows, steps) = if fast { (512, 512, 80) } else { (2_000, 1_024, 400) };
    println!("measured leg: MiniCaffeNet, {train_rows} train rows, {steps} steps per variant...");
    let t0 = std::time::Instant::now();
    let rows = table1::run_measured(&engine, train_rows, test_rows, steps, 0).expect("measured");
    print!("{}", table1::render_measured(&rows));
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());

    table1::check_audit_consistency(&rows).expect("audit consistency");
    match table1::check_paper_shape(&rows) {
        Ok(()) => println!(
            "paper-shape checks: OK — >5x parameter reduction at small accuracy cost"
        ),
        Err(e) => {
            println!("paper-shape checks: FAILED — {e}");
            std::process::exit(1);
        }
    }
}
