//! E4 — regenerates **Figure 4**: the parameter-reduction vs error-increase
//! trade-off scatter for train-time-applicable SELLs, as a text series
//! (published points + this repo's measured MiniCaffeNet point).
//!
//! Run: `make artifacts && cargo bench --bench fig4_tradeoff`
//! Env: `ACDC_BENCH_FAST=1` shrinks the measured leg.

use acdc::experiments::table1;
use acdc::runtime::Engine;
use std::path::Path;

fn main() {
    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let measured = Engine::open(Path::new("artifacts")).ok().and_then(|engine| {
        let (train_rows, test_rows, steps) = if fast {
            (512, 512, 80)
        } else {
            (1_500, 1_024, 300)
        };
        println!("training measured point ({steps} steps per variant)...");
        table1::run_measured(&engine, train_rows, test_rows, steps, 1).ok()
    });
    print!("{}", table1::render_fig4(measured.as_deref()));
    if let Some(rows) = &measured {
        match table1::check_paper_shape(rows) {
            Ok(()) => println!("paper-shape checks: OK"),
            Err(e) => {
                println!("paper-shape checks: FAILED — {e}");
                std::process::exit(1);
            }
        }
    } else {
        println!("(measured point skipped — artifacts not built)");
    }
}
