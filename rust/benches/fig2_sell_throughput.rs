//! E1 — regenerates **Figure 2** (and the §5 arithmetic-intensity table,
//! E5): dense GEMM vs fused ("single call") vs multipass ("multiple
//! call") ACDC across layer sizes at batch 128, with roofline peak curves
//! for the paper's Titan X and the measured host.
//!
//! Run: `cargo bench --bench fig2_sell_throughput`
//! Env: `ACDC_BENCH_FAST=1` shrinks the sweep for smoke runs.

use acdc::experiments::fig2;
use acdc::perfmodel::{self, Hardware};
use acdc::runtime::Engine;
use acdc::util::bench::{Bench, Table};
use std::path::Path;

fn main() {
    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let sizes: Vec<usize> = if fast {
        vec![128, 512, 1024]
    } else {
        vec![128, 256, 512, 1024, 2048, 4096, 8192]
    };
    let batch = 128;
    let bench = if fast { Bench::quick() } else { Bench::default() };

    // §5 arithmetic-intensity model (E5) — the paper's 4.9 → 9.3 range.
    println!("§5 arithmetic-intensity model (AI = (4 + 5·log2 N)/8, FLOPs/byte)");
    let mut ai = Table::new(&["N", "AI", "memory-bound on Titan X? (balance ≈ 20)"]);
    for &n in &[128usize, 1024, 4096, 16_384] {
        let v = perfmodel::acdc_arithmetic_intensity(n);
        ai.row(vec![
            n.to_string(),
            format!("{v:.2}"),
            (v < Hardware::TITAN_X.balance()).to_string(),
        ]);
    }
    ai.print();
    println!();

    let engine = Engine::open(Path::new("artifacts")).ok();
    if engine.is_none() {
        println!("(artifacts not built — skipping the PJRT-executed leg)\n");
    }
    let rows = fig2::run(&sizes, batch, &bench, engine.as_ref());
    print!("{}", fig2::render(&rows));

    println!();
    match fig2::check_paper_shape(&rows) {
        Ok(()) => println!(
            "paper-shape checks: OK — ACDC beats dense with growing margin; \
             Titan-X model reproduces the paper's ~10x at large N"
        ),
        Err(e) => {
            println!("paper-shape checks: FAILED — {e}");
            std::process::exit(1);
        }
    }
}
