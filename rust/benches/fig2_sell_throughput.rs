//! E1 — regenerates **Figure 2** (and the §5 arithmetic-intensity table,
//! E5): dense GEMM vs fused ("single call") vs batched-SoA vs multipass
//! ("multiple call") ACDC across layer sizes at batch 128, with roofline
//! peak curves for the paper's Titan X and the measured host. Ends with
//! the batched-engine acceptance comparison (E9) and writes its rows to
//! `BENCH_acdc_batch.json`.
//!
//! Run: `cargo bench --bench fig2_sell_throughput`
//! Env: `ACDC_BENCH_FAST=1` shrinks the sweep for smoke runs.

use acdc::experiments::{engine_bench, fig2};
use acdc::perfmodel::{self, Hardware};
use acdc::runtime::Engine;
use acdc::util::bench::{Bench, Table};
use std::path::Path;

fn main() {
    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let sizes: Vec<usize> = if fast {
        vec![128, 512, 1024]
    } else {
        vec![128, 256, 512, 1024, 2048, 4096, 8192]
    };
    let batch = 128;
    let bench = if fast { Bench::quick() } else { Bench::default() };

    // §5 arithmetic-intensity model (E5) — the paper's 4.9 → 9.3 range.
    println!("§5 arithmetic-intensity model (AI = (4 + 5·log2 N)/8, FLOPs/byte)");
    let mut ai = Table::new(&["N", "AI", "memory-bound on Titan X? (balance ≈ 20)"]);
    for &n in &[128usize, 1024, 4096, 16_384] {
        let v = perfmodel::acdc_arithmetic_intensity(n);
        ai.row(vec![
            n.to_string(),
            format!("{v:.2}"),
            (v < Hardware::TITAN_X.balance()).to_string(),
        ]);
    }
    ai.print();
    println!();

    let engine = Engine::open(Path::new("artifacts")).ok();
    if engine.is_none() {
        println!("(artifacts not built — skipping the PJRT-executed leg)\n");
    }
    let rows = fig2::run(&sizes, batch, &bench, engine.as_ref());
    print!("{}", fig2::render(&rows));

    println!();
    match fig2::check_paper_shape(&rows) {
        Ok(()) => println!(
            "paper-shape checks: OK — ACDC beats dense with growing margin; \
             Titan-X model reproduces the paper's ~10x at large N"
        ),
        Err(e) => {
            println!("paper-shape checks: FAILED — {e}");
            std::process::exit(1);
        }
    }

    // E9: batched-engine acceptance comparison (per-row vs SoA), written
    // out as the committed BENCH_acdc_batch.json report.
    println!();
    let cases: &[(usize, usize)] = if fast {
        &[(1024, 256)]
    } else {
        &[(256, 64), (256, 256), (1024, 64), (1024, 256), (4096, 256)]
    };
    let erows = engine_bench::run(cases, &bench);
    print!("{}", engine_bench::render(&erows));
    // Benches run with CWD = rust/; the committed report lives at the
    // repo root, so anchor on the manifest dir to actually update it.
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_acdc_batch.json");
    match engine_bench::write_json(&out, &erows, "cargo bench --bench fig2_sell_throughput") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => println!("could not write report: {e}"),
    }
    match engine_bench::check_acceptance(&erows) {
        Ok(()) => {
            println!("acceptance: OK — serial batched engine ≥ 1.2x per-row at N=1024, batch=256")
        }
        Err(e) => {
            println!("acceptance: FAILED — {e}");
            std::process::exit(1);
        }
    }
}
