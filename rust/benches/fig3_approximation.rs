//! E2 — regenerates **Figure 3**: training loss of ACDC_K cascades
//! (K ∈ {1,2,4,8,16,32}) approximating a dense 32×32 operator, under the
//! identity-plus-noise init (left panel) and the near-zero init (right
//! panel), plus the dense baseline — all through the AOT train-step
//! artifacts.
//!
//! Run: `make artifacts && cargo bench --bench fig3_approximation`
//! Env: `ACDC_BENCH_FAST=1` shrinks depths/steps for smoke runs.

use acdc::data::regression::RegressionTask;
use acdc::experiments::fig3;
use acdc::runtime::Engine;
use std::path::Path;

fn main() {
    let fast = std::env::var("ACDC_BENCH_FAST").is_ok();
    let engine = match Engine::open(Path::new("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            println!("artifacts required for this bench: {e}");
            std::process::exit(0);
        }
    };
    let ks: Vec<usize> = if fast {
        vec![1, 4, 16]
    } else {
        fig3::PAPER_KS.to_vec()
    };
    let steps = if fast { 120 } else { 400 };
    let rows = if fast { 2_000 } else { 10_000 };

    println!("workload: eq. (15) — X {rows}×32 uniform, W_true 32×32 uniform, ε ~ N(0, 1e-4)");
    let task = RegressionTask::generate(rows, 32, 1e-4, 0);
    let t0 = std::time::Instant::now();
    let cells = fig3::run(&engine, &task, &ks, steps, 0).expect("fig3 grid");
    print!("{}", fig3::render(&cells, &task));
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());

    match fig3::check_paper_shape(&cells) {
        Ok(()) => println!(
            "paper-shape checks: OK — identity init trains at all K; \
             near-zero init fails at depth; deeper ≥ shallower"
        ),
        Err(e) => {
            println!("paper-shape checks: FAILED — {e}");
            std::process::exit(1);
        }
    }
}
