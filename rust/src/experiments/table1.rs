//! E3/E4 — Table 1 and Figure 4: parameter/accuracy trade-off.
//!
//! Two legs (DESIGN.md substitution S2):
//! * **analytic** — exact parameter arithmetic at CaffeNet scale for every
//!   Table-1 row, printed `paper vs computed`;
//! * **measured** — the same architecture surgery (dense FC block → 12
//!   stacked ACDC+ReLU+perm SELLs) on MiniCaffeNet/synthimg, training both
//!   variants through the PJRT artifacts and reporting the error increase
//!   alongside the parameter reduction.

use crate::data::synthimg::ImageCorpus;
use crate::runtime::Engine;
use crate::sell::params::{self, mini, table1_rows};
use crate::trainer::{CnnTrainer, CnnVariant, StepDecay};
use crate::util::bench::Table;
use crate::util::fmt_params;

/// Render the analytic Table-1 audit (no training required).
pub fn render_analytic() -> String {
    let mut out = String::new();
    out.push_str("Table 1 — parameter audit (paper-published vs computed here)\n");
    let mut t = Table::new(&[
        "method",
        "err +%",
        "params (paper)",
        "reduction (paper)",
        "params (computed)",
        "notes",
    ]);
    for row in table1_rows() {
        t.row(vec![
            row.method.to_string(),
            format!("{:.2}", row.err_increase_pct),
            row.published_params
                .map(fmt_params)
                .unwrap_or_else(|| "-".into()),
            format!("x{:.1}", row.published_reduction),
            row.computed_params
                .map(fmt_params)
                .unwrap_or_else(|| "-".into()),
            match (row.vgg16, row.train_time) {
                (true, _) => "*VGG16",
                (false, true) => "train+test",
                (false, false) => "post-proc",
            }
            .to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nkey identities:\n  ACDC stack (paper): 12 layers x 3N at N=4608 = {} (paper reports 165,888)\n  \
         CaffeNet fc6+fc7: {} params (paper: 'more than 41 million')\n  \
         computed CaffeNet total: {} (paper reports 58.7M)\n",
        fmt_params(params::acdc_stack_params(4608, 12)),
        fmt_params({
            let (i6, o6) = params::caffenet::FC6;
            let (i7, o7) = params::caffenet::FC7;
            i6 * o6 + o6 + i7 * o7 + o7
        }),
        fmt_params(params::caffenet::total_params()),
    ));
    out
}

/// Result of the measured MiniCaffeNet leg.
#[derive(Debug, Clone)]
pub struct MeasuredRow {
    /// Variant label.
    pub variant: &'static str,
    /// Learnable parameter count.
    pub params: u64,
    /// Parameter reduction vs the dense reference.
    pub reduction: f64,
    /// Held-out top-1 error, percent.
    pub test_err_pct: f64,
    /// Error increase over the dense reference, points.
    pub err_increase_pct: f64,
    /// Final training loss.
    pub train_loss_final: f64,
}

/// Train both variants and report the Table-1 style measured rows.
pub fn run_measured(
    engine: &Engine,
    train_rows: usize,
    test_rows: usize,
    steps: usize,
    seed: u64,
) -> Result<Vec<MeasuredRow>, String> {
    let train = ImageCorpus::generate(train_rows, 0.15, seed);
    let test = ImageCorpus::generate(test_rows, 0.15, seed + 1);

    let mut dense_t = CnnTrainer::new(engine, CnnVariant::Dense, seed + 2)?;
    let (dense_curve, dense_eval) =
        dense_t.run(&train, &test, steps, &StepDecay::constant(0.05), 25)?;

    let mut acdc_t = CnnTrainer::new(engine, CnnVariant::Acdc, seed + 3)?;
    let (acdc_curve, acdc_eval) =
        acdc_t.run(&train, &test, steps, &StepDecay::constant(0.02), 25)?;

    let dense_params = dense_t.param_count() as u64;
    let acdc_params = acdc_t.param_count() as u64;
    let dense_err = (1.0 - dense_eval.accuracy) * 100.0;
    let acdc_err = (1.0 - acdc_eval.accuracy) * 100.0;
    Ok(vec![
        MeasuredRow {
            variant: "MiniCaffeNet dense FC (reference)",
            params: dense_params,
            reduction: 1.0,
            test_err_pct: dense_err,
            err_increase_pct: 0.0,
            train_loss_final: dense_curve.last().unwrap_or(f64::NAN),
        },
        MeasuredRow {
            variant: "MiniCaffeNet ACDC-12 FC",
            params: acdc_params,
            reduction: dense_params as f64 / acdc_params as f64,
            test_err_pct: acdc_err,
            err_increase_pct: acdc_err - dense_err,
            train_loss_final: acdc_curve.last().unwrap_or(f64::NAN),
        },
    ])
}

/// Render the measured MiniCaffeNet rows as a Table-1-style table.
pub fn render_measured(rows: &[MeasuredRow]) -> String {
    let mut t = Table::new(&[
        "model",
        "params",
        "reduction",
        "test err %",
        "err increase %",
        "final train loss",
    ]);
    for r in rows {
        t.row(vec![
            r.variant.to_string(),
            fmt_params(r.params),
            format!("x{:.1}", r.reduction),
            format!("{:.1}", r.test_err_pct),
            format!("{:+.1}", r.err_increase_pct),
            format!("{:.3}", r.train_loss_final),
        ]);
    }
    format!(
        "Table 1 (measured, MiniCaffeNet on synthimg — substitution S2)\n{}",
        t.render()
    )
}

/// Figure 4: the reduction-vs-error scatter, printed as a text series
/// (paper rows + our measured point).
pub fn render_fig4(measured: Option<&[MeasuredRow]>) -> String {
    let mut out = String::new();
    out.push_str("Figure 4 — parameter reduction vs top-1 error increase\n");
    let mut t = Table::new(&["method", "reduction (x)", "err increase (%)", "backbone"]);
    for row in table1_rows() {
        if !row.train_time && row.method != "CaffeNet Reference Model" {
            continue; // Fig 4 plots train-time-applicable SELLs
        }
        t.row(vec![
            row.method.to_string(),
            format!("{:.1}", row.published_reduction),
            format!("{:.2}", row.err_increase_pct),
            if row.vgg16 { "VGG16*" } else { "CaffeNet" }.to_string(),
        ]);
    }
    if let Some(rows) = measured {
        for r in rows.iter().filter(|r| r.reduction > 1.0) {
            t.row(vec![
                format!("{} [measured]", r.variant),
                format!("{:.1}", r.reduction),
                format!("{:.2}", r.err_increase_pct),
                "MiniCaffeNet".to_string(),
            ]);
        }
    }
    out.push_str(&t.render());
    out
}

/// Paper-shape checks for the measured leg: the ACDC swap keeps accuracy
/// within a few points of dense while cutting the FC parameters by >5×.
pub fn check_paper_shape(rows: &[MeasuredRow]) -> Result<(), String> {
    let dense = rows
        .iter()
        .find(|r| r.reduction == 1.0)
        .ok_or("missing dense row")?;
    let acdc = rows
        .iter()
        .find(|r| r.reduction > 1.0)
        .ok_or("missing acdc row")?;
    if acdc.reduction < 5.0 {
        return Err(format!("reduction only x{:.1}", acdc.reduction));
    }
    if dense.test_err_pct > 60.0 {
        return Err(format!(
            "dense reference failed to learn ({}% err)",
            dense.test_err_pct
        ));
    }
    // The paper reports +0.67% at ImageNet scale; at our scale allow a
    // wider band but the swap must stay within 15 points.
    if acdc.err_increase_pct > 15.0 {
        return Err(format!(
            "ACDC error increase too large: {:+.1}%",
            acdc.err_increase_pct
        ));
    }
    Ok(())
}

/// Consistency between the audit module and the measured parameter banks.
pub fn check_audit_consistency(rows: &[MeasuredRow]) -> Result<(), String> {
    let dense = rows.iter().find(|r| r.reduction == 1.0).unwrap();
    let acdc = rows.iter().find(|r| r.reduction > 1.0).unwrap();
    if dense.params != mini::dense_total() {
        return Err(format!(
            "dense params {} != audit {}",
            dense.params,
            mini::dense_total()
        ));
    }
    if acdc.params != mini::acdc_total() {
        return Err(format!(
            "acdc params {} != audit {}",
            acdc.params,
            mini::acdc_total()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_render_has_all_rows() {
        let s = render_analytic();
        assert!(s.contains("ACDC (this paper)"));
        assert!(s.contains("CaffeNet Reference Model"));
        assert!(s.contains("165,888"));
        assert!(s.contains("x6.0"));
    }

    #[test]
    fn fig4_render_without_measured() {
        let s = render_fig4(None);
        assert!(s.contains("Figure 4"));
        assert!(s.contains("Adaptive Fastfood"));
        // post-processing rows are excluded from fig 4
        assert!(!s.contains("Collins"));
    }

    #[test]
    fn fig4_render_with_measured_point() {
        let rows = vec![
            MeasuredRow {
                variant: "dense",
                params: 100,
                reduction: 1.0,
                test_err_pct: 10.0,
                err_increase_pct: 0.0,
                train_loss_final: 0.1,
            },
            MeasuredRow {
                variant: "acdc",
                params: 10,
                reduction: 10.0,
                test_err_pct: 12.0,
                err_increase_pct: 2.0,
                train_loss_final: 0.2,
            },
        ];
        let s = render_fig4(Some(&rows));
        assert!(s.contains("[measured]"));
        check_paper_shape(&rows).unwrap();
    }

    #[test]
    fn shape_check_rejects_broken_runs() {
        let rows = vec![
            MeasuredRow {
                variant: "dense",
                params: 100,
                reduction: 1.0,
                test_err_pct: 80.0, // failed to learn
                err_increase_pct: 0.0,
                train_loss_final: 2.3,
            },
            MeasuredRow {
                variant: "acdc",
                params: 10,
                reduction: 10.0,
                test_err_pct: 82.0,
                err_increase_pct: 2.0,
                train_loss_final: 2.3,
            },
        ];
        assert!(check_paper_shape(&rows).is_err());
    }
}
