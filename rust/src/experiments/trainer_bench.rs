//! E11 (throughput leg) — trainer-step bench: one full SGD step
//! (pooled batched `forward_train` + closed-form backward + momentum
//! update) over the eq.-(15) regression task, swept over layer width.
//!
//! Two strategies per `(N, batch, depth)` case:
//!
//! 1. **serial** — [`crate::sell::acdc::AcdcCascade::forward_train`] +
//!    backward on the serial batched SoA engine;
//! 2. **pooled** — [`crate::sell::acdc::AcdcCascade::forward_train_pooled`]
//!    with panels fanned across the process-wide thread pool (the
//!    [`crate::trainer::TrainerPool`] hot path; bit-identical to serial).
//!
//! `acdc bench-trainer` renders the table and writes
//! `BENCH_trainer_step.json` with provenance, so the training-throughput
//! trajectory is tracked the same way the engine bench (E9) is.

use crate::data::regression::RegressionTask;
use crate::data::BatchCursor;
use crate::sell::acdc::AcdcCascade;
use crate::sell::init::DiagInit;
use crate::trainer::{apply_momentum_update, Momentum};
use crate::util::bench::{black_box, fmt_ns, Bench, Table};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;

/// One measured (N, batch, depth) case.
#[derive(Debug, Clone)]
pub struct TrainerBenchRow {
    /// Layer width N.
    pub n: usize,
    /// Minibatch rows per step.
    pub batch: usize,
    /// Cascade depth K.
    pub depth: usize,
    /// Full SGD step on the serial engine, ns.
    pub serial_step_ns: f64,
    /// Full SGD step with pooled panels, ns.
    pub pooled_step_ns: f64,
}

impl TrainerBenchRow {
    /// Steps per second on the pooled (production) path.
    pub fn steps_per_s(&self) -> f64 {
        1e9 / self.pooled_step_ns
    }

    /// Training rows per second on the pooled path.
    pub fn rows_per_s(&self) -> f64 {
        self.batch as f64 * self.steps_per_s()
    }

    /// Pooled speedup over the serial engine.
    pub fn pooled_speedup(&self) -> f64 {
        self.serial_step_ns / self.pooled_step_ns
    }
}

/// Measure every `(n, batch, depth)` case. The learning rate is zero so
/// the parameters (and therefore the measured work) stay pinned at the
/// init across the whole measurement window; the update runs in full.
pub fn run(cases: &[(usize, usize, usize)], bench: &Bench) -> Vec<TrainerBenchRow> {
    let pool = crate::util::threadpool::global();
    let mut rows = Vec::with_capacity(cases.len());
    for &(n, batch, depth) in cases {
        let mut rng = Pcg32::seeded(99);
        let task = RegressionTask::generate(batch * 4, n, 1e-4, 7);
        let mut cascade = AcdcCascade::linear(n, depth, DiagInit::IDENTITY, &mut rng);
        let sizes = vec![n; 3 * depth];
        let mut momentum = Momentum::new(0.9, &sizes);
        let mut cursor = BatchCursor::new(task.rows(), batch);
        let mut step = |pooled: bool| {
            let idx = cursor.next_indices();
            let (bx, by) = task.gather(&idx);
            let (pred, cache) = if pooled {
                cascade.forward_train_pooled(&bx, pool)
            } else {
                cascade.forward_train(&bx)
            };
            let mut g = pred.sub(&by);
            g.scale(2.0 / batch as f32);
            let (_, mut grads) = cascade.backward(&cache, &g);
            apply_momentum_update(&mut cascade, &mut grads, &mut momentum, 0.0);
            black_box(grads[0].a[0]);
        };
        let m_serial = bench.run(&format!("train-step serial n={n} b={batch} k={depth}"), || {
            step(false)
        });
        let m_pooled = bench.run(&format!("train-step pooled n={n} b={batch} k={depth}"), || {
            step(true)
        });
        rows.push(TrainerBenchRow {
            n,
            batch,
            depth,
            serial_step_ns: m_serial.median_ns,
            pooled_step_ns: m_pooled.median_ns,
        });
    }
    rows
}

/// Text table of the sweep.
pub fn render(rows: &[TrainerBenchRow]) -> String {
    let mut t = Table::new(&[
        "N",
        "batch",
        "depth",
        "serial step",
        "pooled step",
        "pooled speedup",
        "steps/s",
        "rows/s",
    ]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.batch.to_string(),
            r.depth.to_string(),
            fmt_ns(r.serial_step_ns),
            fmt_ns(r.pooled_step_ns),
            format!("{:.2}x", r.pooled_speedup()),
            format!("{:.1}", r.steps_per_s()),
            format!("{:.0}", r.rows_per_s()),
        ]);
    }
    format!(
        "Trainer-step throughput (forward_train + backward + momentum update)\n{}",
        t.render()
    )
}

/// JSON report (the `BENCH_trainer_step.json` payload).
pub fn to_json(rows: &[TrainerBenchRow], provenance: &str) -> Json {
    obj(vec![
        ("bench", Json::Str("trainer_step".into())),
        ("provenance", Json::Str(provenance.to_string())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("n", Json::Num(r.n as f64)),
                            ("batch", Json::Num(r.batch as f64)),
                            ("depth", Json::Num(r.depth as f64)),
                            ("serial_step_ns", Json::Num(r.serial_step_ns)),
                            ("pooled_step_ns", Json::Num(r.pooled_step_ns)),
                            ("pooled_speedup", Json::Num(r.pooled_speedup())),
                            ("steps_per_s", Json::Num(r.steps_per_s())),
                            ("rows_per_s", Json::Num(r.rows_per_s())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report to `path`.
pub fn write_json(
    path: &std::path::Path,
    rows: &[TrainerBenchRow],
    provenance: &str,
) -> Result<(), String> {
    std::fs::write(path, to_json(rows, provenance).to_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn quick() -> Bench {
        Bench {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(15),
            min_iters: 2,
            max_iters: 10_000,
        }
    }

    #[test]
    fn runs_renders_and_serializes() {
        let rows = run(&[(16, 8, 2)], &quick());
        assert_eq!(rows.len(), 1);
        assert!(rows[0].serial_step_ns > 0.0 && rows[0].pooled_step_ns > 0.0);
        assert!(rows[0].steps_per_s() > 0.0);
        let s = render(&rows);
        assert!(s.contains("steps/s"), "{s}");
        let j = to_json(&rows, "unit test");
        let re = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(re.get("bench").unwrap().as_str(), Some("trainer_step"));
        assert_eq!(re.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
