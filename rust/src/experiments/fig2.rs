//! E1 — Figure 2: SELL vs dense runtime across layer sizes.
//!
//! Regenerates the paper's §5.3 comparison on this testbed: measured legs
//! for the dense GEMM baseline, the fused ("single call") ACDC and the
//! multipass ("multiple call") ACDC, the optional PJRT-executed ACDC
//! artifact, plus the roofline "peak" curves for both the paper's Titan X
//! and the measured host (DESIGN.md substitution S1). The paper's claims
//! checked here: ACDC ≪ dense at large N (up to ~10× vs even peak GEMM),
//! fused ≥ multipass, and ACDC staying memory-bound.

use crate::perfmodel::{self, Hardware};
use crate::sell::acdc::AcdcLayer;
use crate::sell::dense::DenseLayer;
use crate::sell::LinearOp;
use crate::tensor::Tensor;
use crate::util::bench::{black_box, Bench, Table};
use crate::util::rng::Pcg32;

/// One measured row of the Figure-2 sweep.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    /// Layer width N.
    pub n: usize,
    /// Batch size (rows per application).
    pub batch: usize,
    /// Measured medians, ns per layer application on the whole batch.
    pub dense_ns: f64,
    /// Scalar fused ("single call") ACDC, ns per batch.
    pub acdc_fused_ns: f64,
    /// Batched SoA-engine ACDC ([`crate::dct::batch`]), ns per batch.
    pub acdc_batch_ns: f64,
    /// Multipass ("multiple call") ACDC, ns per batch.
    pub acdc_multipass_ns: f64,
    /// PJRT-executed fused ACDC artifact (None without artifacts).
    pub pjrt_acdc_ns: Option<f64>,
    /// Roofline prediction for dense on the paper's Titan X.
    pub titan_dense_ns: f64,
    /// Roofline prediction for ACDC on the paper's Titan X.
    pub titan_acdc_ns: f64,
    /// Roofline predictions for the measured host bandwidth.
    pub host_acdc_ns: f64,
}

impl Fig2Row {
    /// Measured dense / best-ACDC speedup.
    pub fn measured_speedup(&self) -> f64 {
        self.dense_ns / self.acdc_fused_ns.min(self.acdc_batch_ns)
    }

    /// Batched-engine speedup over the scalar fused path.
    pub fn batch_speedup(&self) -> f64 {
        self.acdc_fused_ns / self.acdc_batch_ns
    }

    /// Titan-X-model dense / ACDC speedup (the paper's "up to 10×").
    pub fn modeled_speedup(&self) -> f64 {
        self.titan_dense_ns / self.titan_acdc_ns
    }
}

/// Run the sweep. `pjrt_sizes` lists the sizes with lowered artifacts.
pub fn run(
    sizes: &[usize],
    batch: usize,
    bench: &Bench,
    engine: Option<&crate::runtime::Engine>,
) -> Vec<Fig2Row> {
    let host = Hardware::measure_host(3);
    let titan = Hardware::TITAN_X;
    let mut rng = Pcg32::seeded(2024);
    let mut rows = Vec::new();
    for &n in sizes {
        let x = Tensor::from_vec(&[batch, n], rng.normal_vec(batch * n, 0.0, 1.0));
        let acdc = AcdcLayer::random(n, &mut rng, 1.0, 0.1);
        let dense = DenseLayer::random(n, &mut rng);

        let m_dense = bench.run(&format!("dense n={n}"), || {
            black_box(dense.forward(&x));
        });
        let m_fused = bench.run(&format!("acdc-fused n={n}"), || {
            black_box(acdc.forward_fused(&x));
        });
        let m_batch = bench.run(&format!("acdc-batch n={n}"), || {
            black_box(acdc.forward_batch(&x));
        });
        let m_multi = bench.run(&format!("acdc-multipass n={n}"), || {
            black_box(acdc.forward_multipass(&x));
        });

        let pjrt_acdc_ns = engine.and_then(|eng| {
            let name = format!("acdc_fwd_b{batch}_n{n}");
            let art = eng.load(&name).ok()?;
            let inputs = vec![
                crate::runtime::values::HostValue::from_tensor(&x),
                crate::runtime::values::HostValue::F32 {
                    shape: vec![n],
                    data: acdc.a.clone(),
                },
                crate::runtime::values::HostValue::F32 {
                    shape: vec![n],
                    data: acdc.d.clone(),
                },
                crate::runtime::values::HostValue::F32 {
                    shape: vec![n],
                    data: acdc.bias.clone(),
                },
            ];
            let m = bench.run(&format!("acdc-pjrt n={n}"), || {
                black_box(art.call(&inputs).expect("pjrt exec"));
            });
            Some(m.median_ns)
        });

        rows.push(Fig2Row {
            n,
            batch,
            dense_ns: m_dense.median_ns,
            acdc_fused_ns: m_fused.median_ns,
            acdc_batch_ns: m_batch.median_ns,
            acdc_multipass_ns: m_multi.median_ns,
            pjrt_acdc_ns,
            titan_dense_ns: titan.predict_seconds(
                perfmodel::dense_flops(n, batch),
                perfmodel::dense_bytes(n, batch),
            ) * 1e9,
            titan_acdc_ns: titan.predict_seconds(
                perfmodel::acdc_flops(n, batch),
                perfmodel::acdc_bytes_batched(n, batch),
            ) * 1e9,
            host_acdc_ns: host.predict_seconds(
                perfmodel::acdc_flops(n, batch),
                perfmodel::acdc_bytes_batched(n, batch),
            ) * 1e9,
        });
    }
    rows
}

/// Render the paper-style series table.
pub fn render(rows: &[Fig2Row]) -> String {
    let mut t = Table::new(&[
        "N",
        "AI(f/B)",
        "dense",
        "acdc-fused",
        "acdc-batch",
        "acdc-multi",
        "acdc-pjrt",
        "titanX dense*",
        "titanX acdc*",
        "speedup(meas)",
        "speedup(model)",
    ]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            format!("{:.1}", perfmodel::acdc_arithmetic_intensity(r.n)),
            crate::util::bench::fmt_ns(r.dense_ns),
            crate::util::bench::fmt_ns(r.acdc_fused_ns),
            crate::util::bench::fmt_ns(r.acdc_batch_ns),
            crate::util::bench::fmt_ns(r.acdc_multipass_ns),
            r.pjrt_acdc_ns
                .map(crate::util::bench::fmt_ns)
                .unwrap_or_else(|| "-".into()),
            crate::util::bench::fmt_ns(r.titan_dense_ns),
            crate::util::bench::fmt_ns(r.titan_acdc_ns),
            format!("{:.1}x", r.measured_speedup()),
            format!("{:.1}x", r.modeled_speedup()),
        ]);
    }
    format!(
        "Figure 2 — ACDC vs dense, batch={} (*roofline model, not measured)\n{}",
        rows.first().map(|r| r.batch).unwrap_or(0),
        t.render()
    )
}

/// The paper-shape assertions the bench harness checks after a sweep.
pub fn check_paper_shape(rows: &[Fig2Row]) -> Result<(), String> {
    for r in rows {
        if r.n >= 1024 && r.measured_speedup() < 1.0 {
            return Err(format!(
                "n={}: dense faster than ACDC ({}x)",
                r.n,
                r.measured_speedup()
            ));
        }
    }
    // speedup grows with N (compare first and last rows)
    if rows.len() >= 2 {
        let first = rows.first().unwrap().measured_speedup();
        let last = rows.last().unwrap().measured_speedup();
        if last <= first {
            return Err(format!(
                "speedup not growing with N: {first:.1}x -> {last:.1}x"
            ));
        }
    }
    // modeled titan-x speedup must reach the paper's ~10× at 16384
    let model_16k = Hardware::TITAN_X.predict_seconds(
        perfmodel::dense_flops(16_384, 128),
        perfmodel::dense_bytes(16_384, 128),
    ) / Hardware::TITAN_X.predict_seconds(
        perfmodel::acdc_flops(16_384, 128),
        perfmodel::acdc_bytes_batched(16_384, 128),
    );
    if model_16k < 10.0 {
        return Err(format!("titan-x model speedup at 16384 = {model_16k:.1}x < 10x"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn quick_bench() -> Bench {
        Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_iters: 2,
            max_iters: 10_000,
        }
    }

    #[test]
    fn sweep_produces_rows_and_speedup_grows() {
        let rows = run(&[128, 512, 1024], 32, &quick_bench(), None);
        assert_eq!(rows.len(), 3);
        check_paper_shape(&rows).unwrap();
    }

    #[test]
    fn render_contains_all_sizes() {
        let rows = run(&[64, 128], 16, &quick_bench(), None);
        let s = render(&rows);
        assert!(s.contains("64"));
        assert!(s.contains("128"));
        assert!(s.contains("speedup"));
    }

    #[test]
    fn fused_not_slower_than_multipass_at_scale() {
        let rows = run(&[1024], 64, &quick_bench(), None);
        let r = &rows[0];
        // Allow 10% noise: fused must not be meaningfully slower.
        assert!(
            r.acdc_fused_ns <= r.acdc_multipass_ns * 1.10,
            "fused {} vs multipass {}",
            r.acdc_fused_ns,
            r.acdc_multipass_ns
        );
    }
}
