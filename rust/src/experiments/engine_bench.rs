//! E9 — batched-engine acceptance bench: per-row vs SoA ACDC throughput.
//!
//! Measures one full `ACDC⁻¹` layer application over a `[batch, N]` panel
//! through four execution strategies:
//!
//! 1. **per-row** — `forward_row_fused` looped over rows: the §5.1
//!    single-call kernel with no batch-level reuse (the pre-batched
//!    serving baseline);
//! 2. **pair** — `forward_fused`: two rows share each complex FFT (the
//!    2-for-1 real-transform packing);
//! 3. **soa** — the batched structure-of-arrays engine
//!    ([`crate::dct::batch::BatchEngine::acdc_rows`]), 8 lanes per pass;
//! 4. **soa-pooled** — the same engine with panels fanned out across the
//!    process-wide thread pool (the serving executors' path).
//!
//! The acceptance gate for the batched engine is `soa ≥ 1.2× per-row`
//! rows/s at N=1024, batch=256 — re-based from the original 2× when the
//! per-row baseline itself adopted the real-FFT Makhoul path (both legs
//! halved their FFT work, so the SoA's remaining edge is lane-level SIMD
//! + twiddle amortization, not flop count). The *absolute* acceptance —
//! new engine ≥ 1.5× the previously committed per-row numbers — is
//! carried in `BENCH_acdc_batch.json`'s provenance. `acdc bench` and the
//! `fig2_sell_throughput` bench target both emit these rows as
//! `BENCH_acdc_batch.json`.

use crate::sell::acdc::AcdcLayer;
use crate::tensor::Tensor;
use crate::util::bench::{black_box, fmt_ns, Bench, Table};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;

/// One measured (N, batch) case.
#[derive(Debug, Clone)]
pub struct EngineBenchRow {
    /// Layer width N.
    pub n: usize,
    /// Rows per application.
    pub batch: usize,
    /// Per-row scalar kernel, ns per batch.
    pub per_row_ns: f64,
    /// Pair-packed scalar kernel, ns per batch.
    pub pair_ns: f64,
    /// Batched SoA engine (serial panels), ns per batch.
    pub soa_ns: f64,
    /// Batched SoA engine across the global pool, ns per batch.
    pub pooled_ns: f64,
}

impl EngineBenchRow {
    /// Serial SoA-engine speedup over the per-row baseline.
    pub fn soa_speedup(&self) -> f64 {
        self.per_row_ns / self.soa_ns
    }

    /// Pooled SoA-engine speedup over the per-row baseline.
    pub fn pooled_speedup(&self) -> f64 {
        self.per_row_ns / self.pooled_ns
    }

    /// Rows per second through the serial SoA engine.
    pub fn soa_rows_per_s(&self) -> f64 {
        self.batch as f64 / (self.soa_ns * 1e-9)
    }

    /// Effective main-memory bandwidth of the serial SoA engine against
    /// the §5 traffic model: one fused `ACDC⁻¹` layer moves 8N bytes per
    /// row (4N in + 4N out, f32) once the diagonals are cache-resident.
    pub fn soa_gbps(&self) -> f64 {
        (self.batch * 8 * self.n) as f64 / self.soa_ns
    }
}

/// Measure every `(n, batch)` case.
pub fn run(cases: &[(usize, usize)], bench: &Bench) -> Vec<EngineBenchRow> {
    let mut rng = Pcg32::seeded(4242);
    let pool = crate::util::threadpool::global();
    let mut rows = Vec::with_capacity(cases.len());
    for &(n, batch) in cases {
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.1);
        layer.bias = rng.normal_vec(n, 0.0, 0.1);
        let x = Tensor::from_vec(&[batch, n], rng.normal_vec(batch * n, 0.0, 1.0));
        let mut out = Tensor::zeros(&[batch, n]);

        let mut scratch = vec![0.0f32; 3 * n];
        let m_row = bench.run(&format!("per-row n={n} b={batch}"), || {
            for r in 0..batch {
                let dst = &mut out.data_mut()[r * n..(r + 1) * n];
                layer.forward_row_fused(x.row(r), dst, &mut scratch);
            }
            black_box(out.data()[0]);
        });
        let m_pair = bench.run(&format!("pair n={n} b={batch}"), || {
            black_box(layer.forward_fused(&x));
        });
        let m_soa = bench.run(&format!("soa n={n} b={batch}"), || {
            black_box(layer.forward_batch(&x));
        });
        let m_pooled = bench.run(&format!("soa-pooled n={n} b={batch}"), || {
            black_box(layer.forward_batch_pooled(&x, pool));
        });
        rows.push(EngineBenchRow {
            n,
            batch,
            per_row_ns: m_row.median_ns,
            pair_ns: m_pair.median_ns,
            soa_ns: m_soa.median_ns,
            pooled_ns: m_pooled.median_ns,
        });
    }
    rows
}

/// Paper-style text table of the comparison.
pub fn render(rows: &[EngineBenchRow]) -> String {
    let mut t = Table::new(&[
        "N",
        "batch",
        "per-row",
        "pair",
        "soa",
        "soa-pooled",
        "soa speedup",
        "pooled speedup",
        "soa rows/s",
        "soa GB/s",
    ]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.batch.to_string(),
            fmt_ns(r.per_row_ns),
            fmt_ns(r.pair_ns),
            fmt_ns(r.soa_ns),
            fmt_ns(r.pooled_ns),
            format!("{:.2}x", r.soa_speedup()),
            format!("{:.2}x", r.pooled_speedup()),
            format!("{:.0}", r.soa_rows_per_s()),
            format!("{:.2}", r.soa_gbps()),
        ]);
    }
    format!(
        "ACDC batched-engine comparison (one ACDC⁻¹ layer per application)\n{}",
        t.render()
    )
}

/// JSON report (the `BENCH_acdc_batch.json` payload): the measured rows
/// plus an `acceptance` record mirroring [`check_acceptance`].
pub fn to_json(rows: &[EngineBenchRow], provenance: &str) -> Json {
    let target = rows.iter().find(|r| r.n == 1024 && r.batch == 256);
    obj(vec![
        ("bench", Json::Str("acdc_batch_engine".into())),
        ("provenance", Json::Str(provenance.to_string())),
        ("lanes", Json::Num(crate::dct::LANES as f64)),
        (
            "simd_dispatch",
            Json::Str(crate::dct::simd::active().name().to_string()),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("n", Json::Num(r.n as f64)),
                            ("batch", Json::Num(r.batch as f64)),
                            ("per_row_ns", Json::Num(r.per_row_ns)),
                            ("pair_ns", Json::Num(r.pair_ns)),
                            ("soa_ns", Json::Num(r.soa_ns)),
                            ("pooled_ns", Json::Num(r.pooled_ns)),
                            ("soa_speedup", Json::Num(r.soa_speedup())),
                            ("pooled_speedup", Json::Num(r.pooled_speedup())),
                            ("soa_rows_per_s", Json::Num(r.soa_rows_per_s())),
                            ("soa_gbps", Json::Num(r.soa_gbps())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "acceptance",
            obj(vec![
                (
                    "criterion",
                    Json::Str(
                        "serial batched SoA engine >= 1.2x per-row throughput at N=1024, \
                         batch=256 (both legs on the real-FFT path)"
                            .into(),
                    ),
                ),
                (
                    "measured_speedup",
                    target.map_or(Json::Null, |t| Json::Num(t.soa_speedup())),
                ),
                (
                    "pass",
                    target.map_or(Json::Null, |t| Json::Bool(t.soa_speedup() >= 1.2)),
                ),
            ]),
        ),
    ])
}

/// Write the JSON report to `path`.
pub fn write_json(
    path: &std::path::Path,
    rows: &[EngineBenchRow],
    provenance: &str,
) -> Result<(), String> {
    std::fs::write(path, to_json(rows, provenance).to_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// The acceptance gate: the *serial* SoA engine must be ≥ 1.2× per-row
/// at the target shape (see the module docs for the 2× → 1.2× re-base
/// when the per-row baseline adopted the real FFT). The pooled number is
/// reported but deliberately not consulted — multi-core fan-out against
/// a single-threaded baseline would make the gate vacuous.
pub fn check_acceptance(rows: &[EngineBenchRow]) -> Result<(), String> {
    let target = rows
        .iter()
        .find(|r| r.n == 1024 && r.batch == 256)
        .ok_or("no N=1024, batch=256 row measured")?;
    if target.soa_speedup() < 1.2 {
        return Err(format!(
            "serial batched engine below 1.2x per-row at N=1024 b=256: soa {:.2}x (pooled {:.2}x)",
            target.soa_speedup(),
            target.pooled_speedup()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn quick() -> Bench {
        Bench {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(15),
            min_iters: 2,
            max_iters: 10_000,
        }
    }

    #[test]
    fn runs_and_renders() {
        let rows = run(&[(64, 8), (128, 16)], &quick());
        assert_eq!(rows.len(), 2);
        let s = render(&rows);
        assert!(s.contains("soa speedup"));
        assert!(s.contains("128"));
        for r in &rows {
            assert!(r.per_row_ns > 0.0 && r.soa_ns > 0.0);
        }
    }

    #[test]
    fn json_roundtrips() {
        let rows = run(&[(32, 8)], &quick());
        let j = to_json(&rows, "unit test");
        let re = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(re.get("bench").unwrap().as_str(), Some("acdc_batch_engine"));
        assert_eq!(re.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn acceptance_check_requires_target_shape() {
        let rows = run(&[(32, 8)], &quick());
        assert!(check_acceptance(&rows).is_err()); // no 1024×256 row
    }
}
