//! E12 — unified end-to-end inference bench: engine bandwidth + loopback
//! gateway latency, emitted as one provenance-stamped report
//! (`BENCH_e2e_infer.json`, the `acdc bench --all` output).
//!
//! Two legs:
//!
//! 1. **engine** — the E9 per-row vs SoA comparison
//!    ([`crate::experiments::engine_bench`]) including the §5 traffic-model
//!    GB/s of the real-FFT SoA path;
//! 2. **gateway** — a closed-loop load-generator run against a loopback
//!    gateway serving a native ACDC cascade (real sockets, keep-alive,
//!    the zero-allocation request path): p50/p95/p99/mean latency and
//!    goodput.
//!
//! Every report stamps provenance (host, OS/arch, thread count, SIMD
//! dispatch, method string) so committed numbers are auditable and
//! reproducible: regenerate with `acdc bench --all`.

use std::time::Duration;

use super::engine_bench::{self, EngineBenchRow};
use crate::config::{GatewayConfig, ServeConfig};
use crate::gateway::loadgen::{self, ArrivalMode, LoadReport, LoadgenConfig};
use crate::gateway::Gateway;
use crate::registry::{ModelRegistry, SellModel};
use crate::sell::acdc::AcdcCascade;
use crate::sell::init::DiagInit;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;

/// Knobs of the gateway loopback leg.
#[derive(Debug, Clone)]
pub struct LoopbackSpec {
    /// Model width N.
    pub n: usize,
    /// Cascade depth K.
    pub depth: usize,
    /// Closed-loop client connections.
    pub concurrency: usize,
    /// Run length.
    pub duration: Duration,
    /// Rows-per-request mix.
    pub rows_mix: Vec<usize>,
}

impl Default for LoopbackSpec {
    fn default() -> Self {
        LoopbackSpec {
            n: 256,
            depth: 12,
            concurrency: 8,
            duration: Duration::from_secs(3),
            rows_mix: vec![1, 1, 1, 8],
        }
    }
}

/// Start an ephemeral loopback gateway over a native ACDC cascade and
/// drive it with the closed-loop load generator.
pub fn gateway_loopback(spec: &LoopbackSpec) -> Result<LoadReport, String> {
    let mut rng = Pcg32::seeded(1);
    let cascade = AcdcCascade::nonlinear(spec.n, spec.depth, DiagInit::CAFFENET, &mut rng);
    let cfg = ServeConfig {
        buckets: vec![1, 8, 32, 128],
        max_wait_us: 1_000,
        workers: 2,
        queue_cap: 8_192,
        ..Default::default()
    };
    let metrics = std::sync::Arc::new(crate::metrics::Registry::new());
    let registry = std::sync::Arc::new(ModelRegistry::new(cfg, metrics));
    registry
        .load("bench", SellModel::Acdc(cascade), None)
        .map_err(|e| e.to_string())?;
    let gateway = Gateway::start_registry(
        registry,
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 4_096,
            rate_rps: 0.0,
            ..Default::default()
        },
    )?;
    let report = loadgen::run(&LoadgenConfig {
        addr: gateway.local_addr().to_string(),
        mode: ArrivalMode::Closed,
        concurrency: spec.concurrency,
        duration: spec.duration,
        width: spec.n,
        rows_mix: spec.rows_mix.clone(),
        timeout: Duration::from_secs(30),
        seed: 7,
        binary: false,
        ..Default::default()
    })?;
    gateway.shutdown();
    Ok(report)
}

/// Provenance block: where these numbers came from (host identity, SIMD
/// arm, method). `method` should name the exact command or mirror used.
pub fn provenance(method: &str) -> Json {
    obj(vec![
        (
            "host",
            Json::Str(std::env::var("HOSTNAME").unwrap_or_else(|_| "unknown".into())),
        ),
        ("os", Json::Str(std::env::consts::OS.to_string())),
        ("arch", Json::Str(std::env::consts::ARCH.to_string())),
        (
            "threads",
            Json::Num(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(0) as f64,
            ),
        ),
        (
            "simd_dispatch",
            Json::Str(crate::dct::simd::active().name().to_string()),
        ),
        ("method", Json::Str(method.to_string())),
    ])
}

/// The unified report (the `BENCH_e2e_infer.json` payload).
pub fn to_json(
    engine_rows: &[EngineBenchRow],
    gateway: Option<&LoadReport>,
    spec: &LoopbackSpec,
    method: &str,
) -> Json {
    let gw = match gateway {
        Some(r) => obj(vec![
            ("mode", Json::Str("closed-loop loopback".into())),
            ("width", Json::Num(spec.n as f64)),
            ("depth", Json::Num(spec.depth as f64)),
            ("concurrency", Json::Num(spec.concurrency as f64)),
            (
                "rows_mix",
                Json::Arr(spec.rows_mix.iter().map(|&r| Json::Num(r as f64)).collect()),
            ),
            ("report", r.to_json()),
        ]),
        None => Json::Null,
    };
    obj(vec![
        ("bench", Json::Str("e2e_infer".into())),
        ("provenance", provenance(method)),
        ("engine", engine_bench::to_json(engine_rows, method)),
        ("gateway", gw),
    ])
}

/// Write the unified report to `path`.
pub fn write_json(
    path: &std::path::Path,
    engine_rows: &[EngineBenchRow],
    gateway: Option<&LoadReport>,
    spec: &LoopbackSpec,
    method: &str,
) -> Result<(), String> {
    std::fs::write(path, to_json(engine_rows, gateway, spec, method).to_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::Bench;

    #[test]
    fn unified_report_shape() {
        let rows = engine_bench::run(
            &[(32, 8)],
            &Bench {
                warmup: Duration::from_millis(2),
                measure: Duration::from_millis(10),
                min_iters: 2,
                max_iters: 10_000,
            },
        );
        let spec = LoopbackSpec {
            n: 32,
            depth: 2,
            concurrency: 2,
            duration: Duration::from_millis(200),
            rows_mix: vec![1],
        };
        let j = to_json(&rows, None, &spec, "unit test");
        let re = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(re.get("bench").unwrap().as_str(), Some("e2e_infer"));
        assert!(re.get("provenance").unwrap().get("method").is_some());
        assert!(re.get("engine").unwrap().get("rows").is_some());
        assert_eq!(re.get("gateway").unwrap(), &Json::Null);
    }

    #[test]
    fn loopback_leg_produces_traffic() {
        let spec = LoopbackSpec {
            n: 16,
            depth: 2,
            concurrency: 2,
            duration: Duration::from_millis(300),
            rows_mix: vec![1, 4],
        };
        let report = gateway_loopback(&spec).expect("loopback");
        assert!(report.ok > 0, "no successful requests: {report:?}");
        assert_eq!(report.errors, 0, "{report:?}");
    }
}
