//! Experiment drivers regenerating the paper's tables and figures.
//!
//! Each submodule owns one artifact of the evaluation (DESIGN.md §3) and
//! exposes `run`/`render`/`check_paper_shape` so the bench targets, the
//! examples and the CLI all share one implementation:
//!
//! * [`fig2`] — E1: SELL vs dense runtime sweep (+roofline model);
//! * [`fig3`] — E2: operator approximation under two inits;
//! * [`table1`] — E3/E4: parameter/accuracy trade-off (analytic + measured);
//! * [`engine_bench`] — E9: per-row vs batched-SoA ACDC engine comparison
//!   (the `BENCH_acdc_batch.json` source, see DESIGN.md §4);
//! * [`trainer_bench`] — E11 throughput leg: full-SGD-step sweep over
//!   layer width (the `BENCH_trainer_step.json` source, DESIGN.md §6);
//! * [`e2e_bench`] — E12: unified engine GB/s + loopback gateway latency
//!   report (the `BENCH_e2e_infer.json` source, `acdc bench --all`);
//! * [`families_bench`] — E13: params × final MSE × inference rows/s for
//!   every trainable SELL family at matched parameter budgets (the
//!   `BENCH_families.json` source, `acdc bench-families`).

pub mod e2e_bench;
pub mod engine_bench;
pub mod families_bench;
pub mod fig2;
pub mod fig3;
pub mod table1;
pub mod trainer_bench;
