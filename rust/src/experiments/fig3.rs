//! E2 — Figure 3: recovering a dense operator with ACDC_K under two
//! initializations.
//!
//! The paper's §6.1 experiment: fit `Y = X·W_true + ε` (eq. 15, X
//! 10000×32) with cascades of K ∈ {1,2,4,8,16,32} ACDC layers, once with
//! the identity-plus-noise init N(1, 1e-1) (left panel — trains, deeper is
//! better) and once with the "standard" near-zero init N(0, 1e-3) (right
//! panel — optimization fails as K grows). A dense layer is the reference
//! curve.

use crate::data::regression::RegressionTask;
use crate::runtime::Engine;
use crate::sell::init::DiagInit;
use crate::trainer::{Fig3Trainer, LossCurve, StepDecay};
use crate::util::bench::Table;

/// The cascade depths swept in the paper's Figure 3.
pub const PAPER_KS: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// One (K, init) cell of the figure.
#[derive(Debug, Clone)]
pub struct Fig3Cell {
    /// Cascade depth (0 = dense baseline).
    pub k: usize,
    /// Diagonal initialization used.
    pub init: DiagInit,
    /// The recorded training curve.
    pub curve: LossCurve,
}

/// Learning rate per depth: deeper cascades need smaller steps for
/// stability (the product of K layers amplifies update noise). The paper
/// does not report its Fig-3 learning rates; these were tuned once so the
/// identity-init runs are stable through K=32.
pub fn lr_for_k(k: usize) -> f64 {
    match k {
        0 => 0.02,      // dense
        1..=2 => 2e-4,
        3..=8 => 1.5e-4,
        9..=16 => 5e-5,
        _ => 2e-5,
    }
}

/// Run the full grid over the PJRT artifacts.
pub fn run(
    engine: &Engine,
    task: &RegressionTask,
    ks: &[usize],
    steps: usize,
    seed: u64,
) -> Result<Vec<Fig3Cell>, String> {
    let mut cells = Vec::new();
    // dense reference
    let dense = Fig3Trainer::new(engine, 0)?;
    cells.push(Fig3Cell {
        k: 0,
        init: DiagInit::IDENTITY,
        curve: dense.run(task, DiagInit::IDENTITY, steps, &StepDecay::constant(lr_for_k(0)), seed)?,
    });
    for &init in &[DiagInit::IDENTITY, DiagInit::STANDARD] {
        for &k in ks {
            let t = Fig3Trainer::new(engine, k)?;
            // Deep cascades: decay the step size over the run — constant-lr
            // minibatch SGD oscillates once near the optimum because the
            // K-layer product amplifies gradient noise.
            let schedule = if k > 8 {
                StepDecay::new(lr_for_k(k), 0.5, (steps / 4).max(1))
            } else {
                StepDecay::constant(lr_for_k(k))
            };
            let curve = t.run(task, init, steps, &schedule, seed + k as u64)?;
            cells.push(Fig3Cell { k, init, curve });
        }
    }
    Ok(cells)
}

/// Render both panels as text tables (final-loss summaries + curves).
pub fn render(cells: &[Fig3Cell], task: &RegressionTask) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 3 — ACDC_K approximating a dense 32×32 operator (eq. 15)\n\
         bayes floor (loss of W_true): {:.4e}\n\n",
        task.bayes_loss()
    ));
    for (panel, init) in [
        ("LEFT (init N(1, 1e-1)) — trains; deeper approximates better", DiagInit::IDENTITY),
        ("RIGHT (init N(0, 1e-3)) — optimization fails with depth", DiagInit::STANDARD),
    ] {
        out.push_str(&format!("{panel}\n"));
        let mut t = Table::new(&["K", "first loss", "final loss", "best", "ratio"]);
        let dense = cells.iter().find(|c| c.k == 0);
        if let Some(d) = dense {
            t.row(vec![
                "dense".into(),
                format!("{:.3e}", d.curve.first().unwrap_or(f64::NAN)),
                format!("{:.3e}", d.curve.last().unwrap_or(f64::NAN)),
                format!("{:.3e}", d.curve.best().unwrap_or(f64::NAN)),
                format!("{:.3}", d.curve.improvement_ratio().unwrap_or(f64::NAN)),
            ]);
        }
        for c in cells.iter().filter(|c| c.k > 0 && c.init == init) {
            t.row(vec![
                c.k.to_string(),
                format!("{:.3e}", c.curve.first().unwrap_or(f64::NAN)),
                format!("{:.3e}", c.curve.last().unwrap_or(f64::NAN)),
                format!("{:.3e}", c.curve.best().unwrap_or(f64::NAN)),
                format!("{:.3}", c.curve.improvement_ratio().unwrap_or(f64::NAN)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// The paper-shape checks: identity init trains at every K; standard init
/// is clearly worse at depth; deeper identity cascades approximate at
/// least as well as K=1.
pub fn check_paper_shape(cells: &[Fig3Cell]) -> Result<(), String> {
    let best = |k: usize, init: DiagInit| -> Option<f64> {
        cells
            .iter()
            .find(|c| c.k == k && (k == 0 || c.init == init))
            .and_then(|c| c.curve.best())
    };
    // identity init improves for all K
    for c in cells.iter().filter(|c| c.init == DiagInit::IDENTITY && c.k > 0) {
        let r = c.curve.improvement_ratio().unwrap_or(f64::NAN);
        if !(r < 0.9) {
            return Err(format!("identity K={} did not train (ratio {r})", c.k));
        }
    }
    // deep standard-init is much worse than deep identity-init
    for k in [16usize, 32] {
        if let (Some(id), Some(std)) = (best(k, DiagInit::IDENTITY), best(k, DiagInit::STANDARD)) {
            if !(std > id * 2.0 || !std.is_finite()) {
                return Err(format!(
                    "K={k}: standard init unexpectedly competitive ({std:.3e} vs {id:.3e})"
                ));
            }
        }
    }
    // deeper identity cascades beat K=1 (more degrees of freedom)
    if let (Some(b1), Some(b16)) = (best(1, DiagInit::IDENTITY), best(16, DiagInit::IDENTITY)) {
        if b16 > b1 {
            return Err(format!("K=16 ({b16:.3e}) worse than K=1 ({b1:.3e})"));
        }
    }
    Ok(())
}
