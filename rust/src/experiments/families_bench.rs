//! E13 — SELL-family comparison grid: params × final MSE × inference
//! rows/s for every trainable family (`acdc`, `fastfood`, `lowrank`,
//! `circulant`) at matched parameter budgets, Table-1 style.
//!
//! Each family trains on the same eq.-(15) regression task through the
//! family-generic [`FamilyTrainer`] with its mirror-validated
//! [`FamilyTuning`] knobs, then serves its trained snapshot through the
//! same [`SellModel::forward`] path the registry uses. At width N the
//! shapes are chosen so the budgets land within ~2× of each other
//! (N = 64: acdc 384, fastfood 192, lowrank 256, circulant 256 params)
//! — the regime where the paper's structured-vs-dense trade-off is
//! interesting. The default grid runs at N = 16, where the
//! [`FamilyTuning`] presets are mirror-validated; the per-parameter
//! gradient scale grows with width, so larger widths need retuned
//! learning rates (pass `--n`/`--steps` to override).
//!
//! `acdc bench-families` renders the table and writes
//! `BENCH_families.json` with provenance, like the engine (E9) and
//! trainer (E11) benches.

use crate::config::TrainerConfig;
use crate::data::regression::RegressionTask;
use crate::sell::ModelKind;
use crate::tensor::Tensor;
use crate::trainer::{FamilyTrainer, FamilyTuning, JobSpec, StepDecay};
use crate::util::bench::{black_box, Bench, Table};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;

/// One family's measured row.
#[derive(Debug, Clone)]
pub struct FamilyBenchRow {
    /// Which family.
    pub kind: ModelKind,
    /// Operator width N.
    pub n: usize,
    /// Learnable parameter count (the Table-1 quantity).
    pub params: usize,
    /// First-step minibatch MSE (the convergence baseline).
    pub first_mse: f64,
    /// Final-step minibatch MSE after the family's step budget.
    pub final_mse: f64,
    /// Single-row inference on the trained snapshot, ns.
    pub infer_row_ns: f64,
}

impl FamilyBenchRow {
    /// final / first MSE (lower is better; the trainer's convergence
    /// ratio).
    pub fn ratio(&self) -> f64 {
        if self.first_mse > 0.0 {
            self.final_mse / self.first_mse
        } else {
            f64::NAN
        }
    }

    /// Inference throughput on the trained snapshot, rows/s.
    pub fn rows_per_s(&self) -> f64 {
        1e9 / self.infer_row_ns
    }
}

/// The bench's JobSpec for one family at width `n`: matched-budget
/// shapes (depth 2 for the cascade families, rank 2 for low-rank) with
/// the family's [`FamilyTuning`] SGD knobs.
pub fn family_spec(kind: ModelKind, n: usize) -> JobSpec {
    let t = FamilyTuning::for_kind(kind);
    JobSpec {
        model_kind: kind,
        width: n,
        depth: 2,
        rank: 2,
        steps: t.steps,
        batch: 32,
        dataset_rows: 512,
        lr: t.lr,
        momentum: t.momentum,
        seed: 11,
        checkpoint_every: 0,
        target_ratio: t.target_ratio,
        ..JobSpec::from_config(&TrainerConfig::default())
    }
}

/// Train and measure every family at width `n`. `steps` overrides each
/// family's step budget when `Some` (the quick-test path); `None` runs
/// the full [`FamilyTuning`] budgets.
pub fn run(n: usize, steps: Option<usize>, bench: &Bench) -> Vec<FamilyBenchRow> {
    let mut rows = Vec::with_capacity(ModelKind::ALL.len());
    for kind in ModelKind::ALL {
        let spec = family_spec(kind, n);
        let task = RegressionTask::generate(
            spec.dataset_rows,
            spec.width,
            spec.dataset_noise,
            spec.seed,
        );
        let mut trainer = FamilyTrainer::new(&spec);
        let budget = steps.unwrap_or(spec.steps);
        let curve = trainer.run(&task, budget, spec.batch, &StepDecay::constant(spec.lr));
        let model = trainer.snapshot();
        let mut rng = Pcg32::seeded(23);
        let x = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let m = bench.run(&format!("infer {kind} n={n}"), || {
            black_box(model.forward(&x).data()[0]);
        });
        rows.push(FamilyBenchRow {
            kind,
            n,
            params: trainer.param_count(),
            first_mse: curve.first().unwrap_or(f64::NAN),
            final_mse: curve.last().unwrap_or(f64::NAN),
            infer_row_ns: m.median_ns,
        });
    }
    rows
}

/// Text table of the grid.
pub fn render(rows: &[FamilyBenchRow]) -> String {
    let mut t = Table::new(&[
        "family",
        "N",
        "params",
        "first MSE",
        "final MSE",
        "ratio",
        "infer row",
        "rows/s",
    ]);
    for r in rows {
        t.row(vec![
            r.kind.to_string(),
            r.n.to_string(),
            r.params.to_string(),
            format!("{:.3e}", r.first_mse),
            format!("{:.3e}", r.final_mse),
            format!("{:.3}", r.ratio()),
            crate::util::bench::fmt_ns(r.infer_row_ns),
            format!("{:.0}", r.rows_per_s()),
        ]);
    }
    format!(
        "SELL-family grid (matched parameter budgets, eq.-(15) task)\n{}",
        t.render()
    )
}

/// JSON report (the `BENCH_families.json` payload).
pub fn to_json(rows: &[FamilyBenchRow], provenance: &str) -> Json {
    obj(vec![
        ("bench", Json::Str("families".into())),
        ("provenance", Json::Str(provenance.to_string())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("family", Json::Str(r.kind.to_string())),
                            ("n", Json::Num(r.n as f64)),
                            ("params", Json::Num(r.params as f64)),
                            ("first_mse", Json::Num(r.first_mse)),
                            ("final_mse", Json::Num(r.final_mse)),
                            ("ratio", Json::Num(r.ratio())),
                            ("infer_row_ns", Json::Num(r.infer_row_ns)),
                            ("rows_per_s", Json::Num(r.rows_per_s())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the JSON report to `path`.
pub fn write_json(
    path: &std::path::Path,
    rows: &[FamilyBenchRow],
    provenance: &str,
) -> Result<(), String> {
    std::fs::write(path, to_json(rows, provenance).to_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn quick() -> Bench {
        Bench {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(15),
            min_iters: 2,
            max_iters: 10_000,
        }
    }

    #[test]
    fn matched_budgets_within_2x_at_n64() {
        let params: Vec<usize> = ModelKind::ALL
            .iter()
            .map(|&k| {
                let spec = family_spec(k, 64);
                let mut rng = Pcg32::seeded(1);
                let model = crate::trainer::build_trainable(&spec, &mut rng);
                model.param_sizes().iter().sum()
            })
            .collect();
        assert_eq!(params, vec![384, 192, 256, 256]);
        let (min, max) = (params.iter().min().unwrap(), params.iter().max().unwrap());
        assert!(*max <= 2 * *min, "budgets not matched: {params:?}");
    }

    #[test]
    fn runs_renders_and_serializes() {
        // 40 steps per family: enough to move the loss, fast enough for CI.
        let rows = run(16, Some(40), &quick());
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.params > 0 && r.infer_row_ns > 0.0, "{:?}", r.kind);
            assert!(r.first_mse.is_finite() && r.final_mse.is_finite(), "{:?}", r.kind);
        }
        let s = render(&rows);
        assert!(s.contains("rows/s") && s.contains("circulant"), "{s}");
        let j = to_json(&rows, "unit test");
        let re = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(re.get("bench").unwrap().as_str(), Some("families"));
        assert_eq!(re.get("rows").unwrap().as_arr().unwrap().len(), 4);
    }
}
