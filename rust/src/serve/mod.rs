//! Serving front-end: ties the coordinator to PJRT-backed executors.
//!
//! [`PjrtCascadeExecutor`] wraps one `serve_cascade_b{B}_*` artifact per
//! batch bucket (the AOT programs are compiled for static shapes, so the
//! bucket choice selects the executable). [`Server`] owns the coordinator
//! and exposes a blocking `infer` plus a latency report.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::checkpoint::Checkpoint;
use crate::config::ServeConfig;
use crate::coordinator::worker::{BatchExecutor, ExecutorFactory};
use crate::coordinator::{Coordinator, SubmitError};
use crate::metrics::Registry;
#[cfg(not(feature = "pjrt"))]
use crate::runtime::stub as xla;
use crate::runtime::values::HostValue;
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Classifier parameters fed to every serve executable (matches the
/// `serve_cascade_*` manifest inputs, minus the feature batch).
#[derive(Debug, Clone)]
pub struct ServeParams {
    /// Stacked `A` diagonals, `[K, N]`.
    pub a_stack: Tensor,
    /// Stacked `D` diagonals, `[K, N]`.
    pub d_stack: Tensor,
    /// Stacked spectral biases, `[K, N]`.
    pub bias_stack: Tensor,
    /// Classifier weights, `[N, classes]`.
    pub cls_w: Tensor,
    /// Classifier bias, `[classes]`.
    pub cls_b: Tensor,
}

impl ServeParams {
    /// Identity-noise-initialized parameters (for demos/benches without a
    /// trained checkpoint).
    pub fn random(n: usize, k: usize, classes: usize, seed: u64) -> ServeParams {
        let mut rng = crate::util::rng::Pcg32::seeded(seed);
        let init = crate::sell::init::DiagInit::CAFFENET;
        ServeParams {
            a_stack: Tensor::from_vec(&[k, n], init.sample(k * n, &mut rng)),
            d_stack: Tensor::from_vec(&[k, n], init.sample(k * n, &mut rng)),
            bias_stack: Tensor::zeros(&[k, n]),
            cls_w: Tensor::from_vec(&[n, classes], rng.normal_vec(n * classes, 0.0, 0.05)),
            cls_b: Tensor::zeros(&[classes]),
        }
    }

    /// Load from a training checkpoint (names as written by the trainer).
    pub fn from_checkpoint(ckpt: &Checkpoint) -> Result<ServeParams, String> {
        let need = |name: &str| {
            ckpt.get(name)
                .cloned()
                .ok_or_else(|| format!("checkpoint missing '{name}'"))
        };
        Ok(ServeParams {
            a_stack: need("a_stack")?,
            d_stack: need("d_stack")?,
            bias_stack: need("bias_stack")?,
            cls_w: need("cls_w")?,
            cls_b: need("cls_b")?,
        })
    }

    fn as_host_values(&self) -> Vec<HostValue> {
        vec![
            HostValue::from_tensor(&self.a_stack),
            HostValue::from_tensor(&self.d_stack),
            HostValue::from_tensor(&self.bias_stack),
            HostValue::from_tensor(&self.cls_w),
            HostValue::from_tensor(&self.cls_b),
        ]
    }
}

/// PJRT executor over the per-bucket serve artifacts. Constructed on the
/// worker thread (owns a thread-local `Engine`).
///
/// All bucket executables are compiled eagerly at construction and held
/// as owned handles, so the per-batch hot path is literal-in → execute →
/// literal-out with no cache locks, name lookups or lazy-compile stalls
/// (perf pass L3-1: lazy compilation showed up as ~300ms p99 spikes).
pub struct PjrtCascadeExecutor {
    /// Keeps the PJRT client (and manifest) alive for the executables.
    _engine: Engine,
    /// bucket → (manifest contract, compiled executable).
    compiled: HashMap<
        usize,
        (
            crate::runtime::manifest::ArtifactMeta,
            Arc<xla::PjRtLoadedExecutable>,
        ),
    >,
    /// Model parameters, pre-packed as host values (first 5 inputs).
    param_values: Vec<HostValue>,
    n: usize,
    classes: usize,
}

impl PjrtCascadeExecutor {
    /// Open the artifacts dir and eagerly compile every serve bucket.
    pub fn new(artifacts_dir: &PathBuf, params: ServeParams) -> Result<Self, String> {
        let engine = Engine::open(artifacts_dir)?;
        let mut compiled = HashMap::new();
        let mut n = 0;
        let mut classes = 0;
        let serve_names: Vec<(usize, String)> = engine
            .manifest()
            .by_experiment("serve")
            .into_iter()
            .map(|art| {
                let b = art.tag_usize("batch").ok_or("serve artifact missing batch tag")?;
                n = art.tag_usize("n").ok_or("serve artifact missing n tag")?;
                let out = &art.outputs[0];
                classes = *out.shape.last().ok_or("scalar serve output?")?;
                Ok((b, art.name.clone()))
            })
            .collect::<Result<_, String>>()?;
        if serve_names.is_empty() {
            return Err("no serve artifacts in manifest".into());
        }
        if params.a_stack.cols() != n {
            return Err(format!(
                "params width {} != artifact width {n}",
                params.a_stack.cols()
            ));
        }
        // Eager compile of every bucket (warmup).
        for (b, name) in serve_names {
            compiled.insert(b, engine.load_owned(&name)?);
        }
        Ok(PjrtCascadeExecutor {
            _engine: engine,
            compiled,
            param_values: params.as_host_values(),
            n,
            classes,
        })
    }

    /// Compiled batch buckets, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.compiled.keys().copied().collect();
        b.sort_unstable();
        b
    }
}

impl BatchExecutor for PjrtCascadeExecutor {
    fn width(&self) -> usize {
        self.n
    }

    fn out_width(&self) -> usize {
        self.classes
    }

    fn execute_into(
        &mut self,
        bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        let (meta, exe) = self
            .compiled
            .get(&bucket)
            .ok_or_else(|| format!("no compiled artifact for bucket {bucket}"))?;
        let mut inputs = self.param_values.clone();
        inputs.push(HostValue::F32 {
            shape: vec![bucket, self.n],
            data: padded.to_vec(),
        });
        let result = crate::runtime::execute_artifact(meta, exe, &inputs)?;
        let vals = result[0].as_f32();
        if vals.len() != out.len() {
            return Err(format!(
                "artifact returned {} values, expected {}",
                vals.len(),
                out.len()
            ));
        }
        out.copy_from_slice(vals);
        Ok(())
    }
}

/// The serving server: coordinator + metrics + blocking client API.
pub struct Server {
    coordinator: Coordinator,
    metrics: Arc<Registry>,
}

impl Server {
    /// Start with PJRT-backed workers over `artifacts_dir`.
    pub fn start_pjrt(
        cfg: &ServeConfig,
        params: ServeParams,
        n: usize,
    ) -> Result<Server, String> {
        Server::start_pjrt_with_metrics(cfg, params, n, Arc::new(Registry::new()))
    }

    /// [`Server::start_pjrt`] recording into a caller-supplied registry
    /// (see [`Server::start_custom_with_metrics`]) — used when the server
    /// joins a multi-model gateway whose `GET /metrics` must include the
    /// coordinator/worker series.
    pub fn start_pjrt_with_metrics(
        cfg: &ServeConfig,
        params: ServeParams,
        n: usize,
        metrics: Arc<Registry>,
    ) -> Result<Server, String> {
        let dir = PathBuf::from(cfg.artifacts_dir.clone());
        let factory: ExecutorFactory = Arc::new(move || {
            let exe = PjrtCascadeExecutor::new(&dir, params.clone())?;
            Ok(Box::new(exe) as Box<dyn BatchExecutor>)
        });
        Ok(Server {
            coordinator: Coordinator::start(cfg, n, factory, Arc::clone(&metrics)),
            metrics,
        })
    }

    /// Start with native (pure-rust reference) workers — no artifacts
    /// needed; used by tests and the `--native` CLI mode.
    pub fn start_native(cfg: &ServeConfig, cascade: crate::sell::acdc::AcdcCascade) -> Server {
        let n = cascade.n();
        let factory: ExecutorFactory = Arc::new(move || {
            Ok(
                Box::new(crate::coordinator::worker::NativeCascadeExecutor::new(
                    cascade.clone(),
                )) as Box<dyn BatchExecutor>,
            )
        });
        Server::start_custom(cfg, n, factory)
    }

    /// Start over an arbitrary executor factory (custom backends and tests
    /// that need to control execution latency, e.g. gateway saturation).
    pub fn start_custom(cfg: &ServeConfig, width: usize, factory: ExecutorFactory) -> Server {
        Server::start_custom_with_metrics(cfg, width, factory, Arc::new(Registry::new()))
    }

    /// [`Server::start_custom`] recording into a caller-supplied registry
    /// — the model registry hands every per-model coordinator the
    /// gateway's shared registry, so coordinator/worker instruments
    /// aggregate fleet-wide in one `GET /metrics` exposition (per-model
    /// series live under `model.{name}.*`).
    pub fn start_custom_with_metrics(
        cfg: &ServeConfig,
        width: usize,
        factory: ExecutorFactory,
        metrics: Arc<Registry>,
    ) -> Server {
        Server {
            coordinator: Coordinator::start(cfg, width, factory, Arc::clone(&metrics)),
            metrics,
        }
    }

    /// Model input width N (feature count per request row).
    pub fn width(&self) -> usize {
        self.coordinator.width()
    }

    /// Submit one row and block for its output.
    pub fn infer(&self, features: Vec<f32>, timeout: Duration) -> Result<Vec<f32>, String> {
        let resp = self.coordinator.infer(features, timeout)?;
        resp.output
    }

    /// Submit one row; returns the response receiver (see
    /// [`Coordinator::submit`]).
    pub fn submit(
        &self,
        features: Vec<f32>,
    ) -> Result<std::sync::mpsc::Receiver<crate::coordinator::request::InferResponse>, SubmitError>
    {
        self.coordinator.submit(features)
    }

    /// Submit one arena row on the zero-allocation slot path (see
    /// [`Coordinator::submit_slot`]). `trace` is the request's trace ID
    /// (0 = untraced); `deadline` is the admission-minted deadline past
    /// which the coordinator reaps instead of executing.
    pub fn submit_slot(
        &self,
        row: crate::coordinator::request::RowRef,
        slot: &Arc<crate::coordinator::request::ResponseSlot>,
        trace: u64,
        deadline: Option<std::time::Instant>,
    ) -> Result<(), SubmitError> {
        self.coordinator.submit_slot(row, slot, trace, deadline)
    }

    /// Text metrics report.
    pub fn metrics_report(&self) -> String {
        self.metrics.report()
    }

    /// The shared metrics registry.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Graceful shutdown: stop intake, drain, join workers.
    pub fn shutdown(self) {
        self.coordinator.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sell::acdc::AcdcCascade;
    use crate::sell::init::DiagInit;
    use crate::util::rng::Pcg32;

    #[test]
    fn native_server_roundtrip_matches_reference() {
        let mut rng = Pcg32::seeded(5);
        let cascade = AcdcCascade::nonlinear(32, 4, DiagInit::CAFFENET, &mut rng);
        let cfg = ServeConfig {
            buckets: vec![1, 4],
            max_wait_us: 200,
            workers: 2,
            queue_cap: 128,
            ..Default::default()
        };
        let server = Server::start_native(&cfg, cascade.clone());
        let x = rng.normal_vec(32, 0.0, 1.0);
        let out = server.infer(x.clone(), Duration::from_secs(5)).unwrap();
        let want = cascade.forward(&Tensor::from_vec(&[1, 32], x));
        for (o, w) in out.iter().zip(want.data()) {
            assert!((o - w).abs() < 1e-4);
        }
        server.shutdown();
    }

    #[test]
    fn serve_params_random_shapes() {
        let p = ServeParams::random(64, 4, 10, 1);
        assert_eq!(p.a_stack.shape(), &[4, 64]);
        assert_eq!(p.cls_w.shape(), &[64, 10]);
    }

    #[test]
    fn serve_params_checkpoint_roundtrip() {
        let p = ServeParams::random(16, 2, 10, 2);
        let mut ckpt = Checkpoint::new();
        ckpt.insert("a_stack", p.a_stack.clone());
        ckpt.insert("d_stack", p.d_stack.clone());
        ckpt.insert("bias_stack", p.bias_stack.clone());
        ckpt.insert("cls_w", p.cls_w.clone());
        ckpt.insert("cls_b", p.cls_b.clone());
        let re = ServeParams::from_checkpoint(&ckpt).unwrap();
        assert_eq!(re.a_stack, p.a_stack);
        // missing key errors
        let mut bad = ckpt.clone();
        bad.entries.remove("cls_b");
        assert!(ServeParams::from_checkpoint(&bad).is_err());
    }

    #[test]
    fn metrics_report_after_traffic() {
        let mut rng = Pcg32::seeded(6);
        let cascade = AcdcCascade::nonlinear(8, 2, DiagInit::CAFFENET, &mut rng);
        let cfg = ServeConfig {
            buckets: vec![1, 4],
            max_wait_us: 100,
            workers: 1,
            queue_cap: 64,
            ..Default::default()
        };
        let server = Server::start_native(&cfg, cascade);
        for _ in 0..10 {
            server
                .infer(rng.normal_vec(8, 0.0, 1.0), Duration::from_secs(5))
                .unwrap();
        }
        let report = server.metrics_report();
        assert!(report.contains("coordinator.accepted 10"), "{report}");
        assert!(report.contains("worker.rows"));
        server.shutdown();
    }
}
