//! Minimal row-major f32 tensor used by the reference SELLs, the data
//! generators and the runtime's host-side buffers.
//!
//! Deliberately small: dense nd storage, shape bookkeeping, the handful of
//! BLAS-1/2/3 kernels the reproduction needs (axpy, matmul with blocking,
//! transpose), and conversion helpers. The heavy math on the request path
//! happens either in the PJRT executable or in `sell::*`'s hand-fused
//! loops; this type is the glue.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    /// Build from shape + data (length must match product of dims).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        let numel: usize = shape.iter().product();
        assert_eq!(numel, data.len(), "shape {shape:?} vs {} elems", data.len());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// All-ones tensor of the given shape.
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// The shape (dimension sizes).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat row-major element view.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major element view.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat element vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    /// Number of columns for a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    /// Immutable row view of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row view of a 2-D tensor.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        assert_eq!(numel, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Element (i, j) of a 2-D tensor.
    pub fn get2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    /// Set element (i, j) of a 2-D tensor.
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let c = self.cols();
        self.data[i * c + j] = v;
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// self += alpha * other (elementwise, shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// self *= alpha.
    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Elementwise sum into a new tensor.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Elementwise difference into a new tensor.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Matrix multiply `self[r,k] @ other[k,c]`, cache-blocked ikj loop.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (r, k) = (self.shape[0], self.shape[1]);
        let (k2, c) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
        let mut out = Tensor::zeros(&[r, c]);
        matmul_into(&self.data, &other.data, &mut out.data, r, k, c);
        out
    }
}

/// Blocked ikj matmul kernel: out[r,c] += a[r,k] @ b[k,c].
/// Exposed for the dense baseline's hot path; `out` must be zeroed by the
/// caller if a fresh product is wanted.
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], r: usize, k: usize, c: usize) {
    debug_assert_eq!(a.len(), r * k);
    debug_assert_eq!(b.len(), k * c);
    debug_assert_eq!(out.len(), r * c);
    // ikj ordering: innermost loop is a contiguous axpy over b/out rows,
    // which autovectorizes well.
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        for i in 0..r {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * c..(i + 1) * c];
            for kk in k0..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * c..(kk + 1) * c];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        }
    }
}

/// y = x @ w for a single row vector x[k], w[k,c].
pub fn matvec_row(x: &[f32], w: &[f32], out: &mut [f32], k: usize, c: usize) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * c);
    debug_assert_eq!(out.len(), c);
    out.fill(0.0);
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[kk * c..(kk + 1) * c];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.get2(0, 2), 3.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_mismatch() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn eye_and_matmul_identity() {
        let x = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let i = Tensor::eye(2);
        assert_eq!(x.matmul(&i), x);
        assert_eq!(i.matmul(&x), x);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_on_random() {
        let mut rng = crate::util::rng::Pcg32::seeded(1);
        let (r, k, c) = (17, 33, 9);
        let a = Tensor::from_vec(&[r, k], rng.normal_vec(r * k, 0.0, 1.0));
        let b = Tensor::from_vec(&[k, c], rng.normal_vec(k * c, 0.0, 1.0));
        let fast = a.matmul(&b);
        // naive
        let mut naive = Tensor::zeros(&[r, c]);
        for i in 0..r {
            for j in 0..c {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.get2(i, kk) * b.get2(kk, j);
                }
                naive.set2(i, j, s);
            }
        }
        assert!(fast.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn transpose_involutive() {
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        let t = Tensor::from_vec(&[5, 7], rng.normal_vec(35, 0.0, 1.0));
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn transpose_known() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn axpy_scale_norm() {
        let mut a = Tensor::ones(&[4]);
        let b = Tensor::full(&[4], 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[2.0; 4]);
        a.scale(0.5);
        assert_eq!(a.data(), &[1.0; 4]);
        assert!((a.norm() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn add_sub_map() {
        let a = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let b = Tensor::from_vec(&[3], vec![4., 5., 6.]);
        assert_eq!(a.add(&b).data(), &[5., 7., 9.]);
        assert_eq!(b.sub(&a).data(), &[3., 3., 3.]);
        assert_eq!(a.map(|v| v * 2.0).data(), &[2., 4., 6.]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }

    #[test]
    fn matvec_row_matches_matmul() {
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let (k, c) = (16, 8);
        let x = rng.normal_vec(k, 0.0, 1.0);
        let w = Tensor::from_vec(&[k, c], rng.normal_vec(k * c, 0.0, 1.0));
        let mut out = vec![0.0; c];
        matvec_row(&x, w.data(), &mut out, k, c);
        let xm = Tensor::from_vec(&[1, k], x);
        let want = xm.matmul(&w);
        for (o, w) in out.iter().zip(want.data()) {
            assert!((o - w).abs() < 1e-4);
        }
    }

    #[test]
    fn max_abs_diff_zero_for_identical() {
        let t = Tensor::ones(&[2, 2]);
        assert_eq!(t.max_abs_diff(&t.clone()), 0.0);
    }
}
