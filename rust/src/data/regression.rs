//! The paper's §6.1 synthetic regression: `Y = X·W_true + ε` (eq. 15).
//!
//! `X` (10000×32) and `W_true` (32×32) have entries uniform in [0, 1);
//! `ε ~ N(0, 1e-4)` is added to the targets. Sizes are parameters so tests
//! can shrink the problem.

use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// A generated regression problem.
#[derive(Debug, Clone)]
pub struct RegressionTask {
    /// Inputs, `[rows, n]`.
    pub x: Tensor,
    /// Noisy targets, `[rows, n]`.
    pub y: Tensor,
    /// The generating dense operator, `[n, n]`.
    pub w_true: Tensor,
    /// Variance of the additive target noise.
    pub noise_var: f64,
}

impl RegressionTask {
    /// Generate with the paper's construction.
    pub fn generate(rows: usize, n: usize, noise_var: f64, seed: u64) -> RegressionTask {
        let mut rng = Pcg32::seeded(seed);
        let x = Tensor::from_vec(&[rows, n], rng.uniform_vec(rows * n, 0.0, 1.0));
        let w_true = Tensor::from_vec(&[n, n], rng.uniform_vec(n * n, 0.0, 1.0));
        let mut y = x.matmul(&w_true);
        let std = noise_var.sqrt();
        for v in y.data_mut() {
            *v += rng.normal_with(0.0, std) as f32;
        }
        RegressionTask {
            x,
            y,
            w_true,
            noise_var,
        }
    }

    /// The paper's exact configuration: X 10000×32, noise N(0, 1e-4).
    pub fn paper(seed: u64) -> RegressionTask {
        Self::generate(10_000, 32, 1e-4, seed)
    }

    /// Number of examples.
    pub fn rows(&self) -> usize {
        self.x.rows()
    }

    /// Operator width N.
    pub fn n(&self) -> usize {
        self.x.cols()
    }

    /// Copy out a batch (x, y) at the given row indices.
    pub fn gather(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let n = self.n();
        let mut bx = Tensor::zeros(&[idx.len(), n]);
        let mut by = Tensor::zeros(&[idx.len(), n]);
        for (bi, &ri) in idx.iter().enumerate() {
            bx.row_mut(bi).copy_from_slice(self.x.row(ri));
            by.row_mut(bi).copy_from_slice(self.y.row(ri));
        }
        (bx, by)
    }

    /// Mean squared error (summed over output dims, averaged over rows —
    /// the Fig. 3 loss) of a prediction matrix against the targets.
    pub fn mse(&self, pred: &Tensor) -> f64 {
        assert_eq!(pred.shape(), self.y.shape());
        let rows = self.rows() as f64;
        pred.data()
            .iter()
            .zip(self.y.data())
            .map(|(p, t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / rows
    }

    /// Loss of the optimal linear predictor (W_true itself) — the noise
    /// floor the dense curve converges to.
    pub fn bayes_loss(&self) -> f64 {
        self.mse(&self.x.matmul(&self.w_true))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let t = RegressionTask::generate(100, 32, 1e-4, 1);
        assert_eq!(t.x.shape(), &[100, 32]);
        assert_eq!(t.y.shape(), &[100, 32]);
        assert_eq!(t.w_true.shape(), &[32, 32]);
    }

    #[test]
    fn entries_in_unit_interval() {
        let t = RegressionTask::generate(50, 8, 0.0, 2);
        assert!(t.x.data().iter().all(|&v| (0.0..1.0).contains(&v)));
        assert!(t.w_true.data().iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn zero_noise_targets_exact() {
        let t = RegressionTask::generate(20, 4, 0.0, 3);
        let clean = t.x.matmul(&t.w_true);
        assert!(t.y.max_abs_diff(&clean) < 1e-6);
    }

    #[test]
    fn bayes_loss_scales_with_noise() {
        let t = RegressionTask::generate(2000, 8, 1e-2, 4);
        // E[loss of W_true] = n_out · noise_var = 8 × 1e-2
        let want = 8.0 * 1e-2;
        let got = t.bayes_loss();
        assert!((got - want).abs() / want < 0.2, "got={got} want={want}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = RegressionTask::generate(10, 4, 1e-4, 7);
        let b = RegressionTask::generate(10, 4, 1e-4, 7);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y.data(), b.y.data());
    }

    #[test]
    fn gather_selects_rows() {
        let t = RegressionTask::generate(10, 4, 0.0, 8);
        let (bx, by) = t.gather(&[3, 7]);
        assert_eq!(bx.row(0), t.x.row(3));
        assert_eq!(bx.row(1), t.x.row(7));
        assert_eq!(by.row(0), t.y.row(3));
    }

    #[test]
    fn mse_zero_for_perfect_prediction() {
        let t = RegressionTask::generate(10, 4, 0.0, 9);
        assert!(t.mse(&t.y) < 1e-12);
    }
}
