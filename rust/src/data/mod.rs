//! Synthetic workloads: the paper's eq. (15) regression and a small image
//! corpus for the MiniCaffeNet experiments (DESIGN.md substitution S2).

pub mod regression;
pub mod synthimg;

/// Iterate fixed-size minibatches over a dataset of `rows` examples,
/// cycling deterministically (wraps around; no shuffle — the generators
/// already sample i.i.d.).
#[derive(Debug, Clone)]
pub struct BatchCursor {
    rows: usize,
    batch: usize,
    pos: usize,
}

impl BatchCursor {
    /// Cursor over `rows` examples in fixed `batch`-sized steps.
    pub fn new(rows: usize, batch: usize) -> BatchCursor {
        assert!(batch > 0 && batch <= rows, "batch {batch} vs rows {rows}");
        BatchCursor {
            rows,
            batch,
            pos: 0,
        }
    }

    /// Next batch's row indices (contiguous, wrapping).
    pub fn next_indices(&mut self) -> Vec<usize> {
        let mut idx = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            idx.push(self.pos);
            self.pos = (self.pos + 1) % self.rows;
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cursor_wraps() {
        let mut c = BatchCursor::new(5, 2);
        assert_eq!(c.next_indices(), vec![0, 1]);
        assert_eq!(c.next_indices(), vec![2, 3]);
        assert_eq!(c.next_indices(), vec![4, 0]);
    }

    #[test]
    #[should_panic]
    fn cursor_rejects_oversized_batch() {
        BatchCursor::new(3, 4);
    }
}
