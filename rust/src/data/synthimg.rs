//! Synthetic 10-class 16×16 image corpus ("synthimg") — the MiniCaffeNet
//! workload standing in for ImageNet (DESIGN.md substitution S2).
//!
//! Each class is a parametric texture family (oriented stripes, rings,
//! blobs, checkerboards, gradients) with per-sample jitter in phase,
//! position and scale plus additive Gaussian noise, so the task requires a
//! real (conv) feature extractor but is learnable at this scale in a few
//! hundred SGD steps.

use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Image side length (images are IMG×IMG single-channel).
pub const IMG: usize = 16;
/// Number of texture classes.
pub const N_CLASSES: usize = 10;

/// A generated labelled corpus. Images are [rows, IMG, IMG, 1] f32 in
/// roughly [-1, 1]; labels are class ids.
#[derive(Debug, Clone)]
pub struct ImageCorpus {
    /// Image tensor, `[rows, IMG, IMG, 1]`.
    pub images: Tensor,
    /// Class id per image.
    pub labels: Vec<i32>,
    /// Additive Gaussian noise stddev used at generation.
    pub noise: f64,
}

impl ImageCorpus {
    /// Generate `rows` images with balanced random classes.
    pub fn generate(rows: usize, noise: f64, seed: u64) -> ImageCorpus {
        let mut rng = Pcg32::seeded(seed);
        let mut images = Tensor::zeros(&[rows, IMG, IMG, 1]);
        let mut labels = Vec::with_capacity(rows);
        let stride = IMG * IMG;
        for r in 0..rows {
            let class = (r % N_CLASSES) as i32; // balanced
            let start = r * stride;
            let img = &mut images.data_mut()[start..start + stride];
            render_class(class as usize, img, &mut rng);
            for v in img.iter_mut() {
                *v += rng.normal_with(0.0, noise) as f32;
            }
            labels.push(class);
        }
        // Shuffle example order so batches mix classes.
        let perm = rng.permutation(rows);
        let mut shuffled = Tensor::zeros(&[rows, IMG, IMG, 1]);
        let mut shuffled_labels = vec![0i32; rows];
        for (dst, &src) in perm.iter().enumerate() {
            let s = src as usize;
            shuffled.data_mut()[dst * stride..(dst + 1) * stride]
                .copy_from_slice(&images.data()[s * stride..(s + 1) * stride]);
            shuffled_labels[dst] = labels[s];
        }
        ImageCorpus {
            images: shuffled,
            labels: shuffled_labels,
            noise,
        }
    }

    /// Number of images.
    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    /// Copy out a batch (images, labels) at the given indices.
    pub fn gather(&self, idx: &[usize]) -> (Tensor, Vec<i32>) {
        let stride = IMG * IMG;
        let mut out = Tensor::zeros(&[idx.len(), IMG, IMG, 1]);
        let mut labels = Vec::with_capacity(idx.len());
        for (bi, &ri) in idx.iter().enumerate() {
            out.data_mut()[bi * stride..(bi + 1) * stride]
                .copy_from_slice(&self.images.data()[ri * stride..(ri + 1) * stride]);
            labels.push(self.labels[ri]);
        }
        (out, labels)
    }
}

/// Render one jittered exemplar of `class` into a 16×16 buffer.
fn render_class(class: usize, img: &mut [f32], rng: &mut Pcg32) {
    debug_assert_eq!(img.len(), IMG * IMG);
    let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
    let jx = rng.uniform_in(-2.0, 2.0);
    let jy = rng.uniform_in(-2.0, 2.0);
    let freq = rng.uniform_in(0.8, 1.2);
    for yy in 0..IMG {
        for xx in 0..IMG {
            let x = xx as f64 - (IMG as f64 - 1.0) / 2.0 - jx;
            let y = yy as f64 - (IMG as f64 - 1.0) / 2.0 - jy;
            let v = match class {
                // 0: horizontal stripes
                0 => (freq * y * 0.9 + phase).sin(),
                // 1: vertical stripes
                1 => (freq * x * 0.9 + phase).sin(),
                // 2: 45° diagonal stripes
                2 => (freq * (x + y) * 0.7 + phase).sin(),
                // 3: -45° diagonal stripes
                3 => (freq * (x - y) * 0.7 + phase).sin(),
                // 4: concentric rings
                4 => (freq * (x * x + y * y).sqrt() * 1.2 + phase).sin(),
                // 5: centered Gaussian blob
                5 => 2.0 * (-(x * x + y * y) / (10.0 * freq)).exp() - 0.5,
                // 6: checkerboard
                6 => {
                    let c = ((xx / 4) + (yy / 4)) % 2;
                    if c == 0 {
                        0.8
                    } else {
                        -0.8
                    }
                }
                // 7: horizontal gradient
                7 => (x / (IMG as f64 / 2.0)) * freq,
                // 8: bright corner quadrant (position jittered by sign)
                8 => {
                    let sx = if phase < std::f64::consts::PI { 1.0 } else { -1.0 };
                    if sx * x > 0.0 && y > 0.0 {
                        0.9
                    } else {
                        -0.4
                    }
                }
                // 9: X cross
                9 => {
                    if (x.abs() - y.abs()).abs() < 1.8 {
                        0.9
                    } else {
                        -0.4
                    }
                }
                _ => unreachable!("class out of range"),
            };
            img[yy * IMG + xx] = v as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes_and_balance() {
        let c = ImageCorpus::generate(200, 0.1, 1);
        assert_eq!(c.images.shape(), &[200, IMG, IMG, 1]);
        assert_eq!(c.labels.len(), 200);
        for class in 0..N_CLASSES as i32 {
            let count = c.labels.iter().filter(|&&l| l == class).count();
            assert_eq!(count, 20, "class {class}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = ImageCorpus::generate(30, 0.1, 5);
        let b = ImageCorpus::generate(30, 0.1, 5);
        assert_eq!(a.images.data(), b.images.data());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn values_bounded() {
        let c = ImageCorpus::generate(100, 0.05, 2);
        for &v in c.images.data() {
            assert!(v.is_finite());
            assert!(v.abs() < 3.0, "v={v}");
        }
    }

    #[test]
    fn classes_are_distinguishable_by_template_matching() {
        // Nearest-class-mean on clean templates must beat chance easily —
        // guards against degenerate/duplicate class renderings.
        let train = ImageCorpus::generate(400, 0.05, 3);
        let test = ImageCorpus::generate(100, 0.05, 4);
        let stride = IMG * IMG;
        // class means
        let mut means = vec![vec![0.0f64; stride]; N_CLASSES];
        let mut counts = vec![0usize; N_CLASSES];
        for r in 0..train.rows() {
            let c = train.labels[r] as usize;
            counts[c] += 1;
            for i in 0..stride {
                means[c][i] += train.images.data()[r * stride + i] as f64;
            }
        }
        for c in 0..N_CLASSES {
            for v in means[c].iter_mut() {
                *v /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for r in 0..test.rows() {
            let img = &test.images.data()[r * stride..(r + 1) * stride];
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..N_CLASSES {
                let d: f64 = img
                    .iter()
                    .zip(&means[c])
                    .map(|(&a, &m)| (a as f64 - m).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 as i32 == test.labels[r] {
                correct += 1;
            }
        }
        // chance = 10%; template matching should be far above.
        assert!(correct > 50, "correct={correct}/100");
    }

    #[test]
    fn gather_matches_source() {
        let c = ImageCorpus::generate(20, 0.1, 6);
        let (imgs, labels) = c.gather(&[4, 9]);
        let stride = IMG * IMG;
        assert_eq!(
            &imgs.data()[0..stride],
            &c.images.data()[4 * stride..5 * stride]
        );
        assert_eq!(labels, vec![c.labels[4], c.labels[9]]);
    }
}
