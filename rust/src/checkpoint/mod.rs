//! Binary checkpoints: named f32 arrays with shapes, written atomically.
//!
//! Format (little-endian):
//! ```text
//! magic "ACDC" | u32 version | u32 n_entries
//! per entry: u32 name_len | name bytes | u32 rank | u64 dims[rank]
//!            | u64 data_len | f32 data[data_len]
//! trailer: u64 fnv1a of everything before the trailer
//! ```
//! Used by the training orchestrator to persist parameter banks and by the
//! serving launcher to load them back.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"ACDC";
const VERSION: u32 = 1;

/// An in-memory checkpoint: ordered name → tensor map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    /// Named parameter banks, sorted by name.
    pub entries: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    /// Empty checkpoint.
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    /// Insert (or replace) a named tensor.
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.entries.insert(name.to_string(), t);
    }

    /// Tensor by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.get(name)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the checkpoint holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, t) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
            for &d in t.shape() {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            out.extend_from_slice(&(t.numel() as u64).to_le_bytes());
            for &v in t.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let digest = fnv1a(&out);
        out.extend_from_slice(&digest.to_le_bytes());
        out
    }

    /// Deserialize, verifying magic/version/checksum.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint, String> {
        if bytes.len() < 12 + 8 {
            return Err("checkpoint too short".into());
        }
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(trailer.try_into().unwrap());
        if fnv1a(body) != want {
            return Err("checksum mismatch (corrupt checkpoint)".into());
        }
        let mut r = Cursor { buf: body, pos: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            return Err("bad magic".into());
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!("unsupported version {version}"));
        }
        let n = r.u32()? as usize;
        let mut ckpt = Checkpoint::new();
        for _ in 0..n {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .map_err(|_| "invalid name utf8".to_string())?;
            let rank = r.u32()? as usize;
            if rank > 8 {
                return Err(format!("implausible rank {rank}"));
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u64()? as usize);
            }
            let data_len = r.u64()? as usize;
            if data_len != shape.iter().product::<usize>() {
                return Err(format!("shape/data mismatch for '{name}'"));
            }
            let raw = r.take(data_len * 4)?;
            let mut data = Vec::with_capacity(data_len);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            ckpt.insert(&name, Tensor::from_vec(&shape, data));
        }
        if r.pos != body.len() {
            return Err("trailing bytes in checkpoint".into());
        }
        Ok(ckpt)
    }

    /// Write atomically (tmp file + rename).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| format!("create {}: {e}", tmp.display()))?;
            f.write_all(&self.to_bytes())
                .map_err(|e| format!("write: {e}"))?;
            f.sync_all().map_err(|e| format!("sync: {e}"))?;
        }
        std::fs::rename(&tmp, path).map_err(|e| format!("rename: {e}"))
    }

    /// Read and verify a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint, String> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes).map_err(|e| format!("read: {e}"))?;
        Checkpoint::from_bytes(&bytes)
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err("unexpected end of checkpoint".into());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// FNV-1a 64-bit hash.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample() -> Checkpoint {
        let mut rng = Pcg32::seeded(1);
        let mut c = Checkpoint::new();
        c.insert("a_stack", Tensor::from_vec(&[4, 8], rng.normal_vec(32, 1.0, 0.1)));
        c.insert("d_stack", Tensor::from_vec(&[4, 8], rng.normal_vec(32, 1.0, 0.1)));
        c.insert("scalar", Tensor::from_vec(&[], vec![3.25]));
        c
    }

    #[test]
    fn roundtrip_bytes() {
        let c = sample();
        let re = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, re);
    }

    #[test]
    fn roundtrip_file() {
        let c = sample();
        let dir = std::env::temp_dir().join(format!("acdc_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        c.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(c, re);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let c = sample();
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn detects_truncation() {
        let c = sample();
        let bytes = c.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 5]).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let c = sample();
        let mut bytes = c.to_bytes();
        bytes[0] = b'X';
        // checksum also fails, but even with a fixed checksum magic must fail
        let body_len = bytes.len() - 8;
        let digest = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&digest.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.contains("magic"));
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let c = Checkpoint::new();
        let re = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert!(re.is_empty());
    }

    #[test]
    fn get_by_name() {
        let c = sample();
        assert_eq!(c.get("scalar").unwrap().data(), &[3.25]);
        assert!(c.get("missing").is_none());
    }
}
