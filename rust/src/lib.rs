//! # acdc — ACDC: A Structured Efficient Linear Layer (ICLR 2016)
//!
//! Rust + JAX + Pallas reproduction of Moczulski et al., ICLR 2016.
//!
//! Three layers (see DESIGN.md):
//! * **L1** (`python/compile/kernels/`): fused Pallas ACDC kernel;
//! * **L2** (`python/compile/model.py`): jax models lowered AOT to HLO text;
//! * **L3** (this crate): the deployment substrate — PJRT runtime, serving
//!   coordinator with dynamic batching, the network gateway (HTTP front-end
//!   with admission control and a load generator, [`gateway`]), training
//!   orchestrator, reference SELL implementations and the paper's
//!   experiment harnesses.
//!
//! The L3 request path, outside-in:
//!
//! ```text
//!   TCP clients → gateway (HTTP/1.1, token bucket, in-flight caps,
//!                 load shedding with Retry-After, graceful drain)
//!              → model registry ([`registry`]: named, versioned models,
//!                 Arc-epoch hot swap under live traffic)
//!              → per-(model, version) coordinator (bounded queue →
//!                 bucketed dynamic batcher → worker pool, backpressure
//!                 end to end)
//!              → executors (PJRT artifacts with the `pjrt` feature;
//!                 otherwise the pure-Rust batched SoA ACDC engine,
//!                 [`dct::batch`] — 8-row lane panels, fused A/D/bias,
//!                 panels fanned across the shared thread pool)
//! ```
//!
//! Off the request path, background training jobs ([`trainer`]) run SGD
//! on the same batched engine and feed the registry: checkpoint every K
//! steps, promote by the same Arc-epoch hot swap — the train → checkpoint
//! → load → swap loop is closed in-process (see `OPERATIONS.md` for the
//! end-to-end tutorial).
//!
//! Python never runs on the request path: `make artifacts` lowers once,
//! and this crate loads/executes the artifacts via the PJRT C API. The
//! default build has no PJRT dependency at all — `--features pjrt` swaps
//! the runtime stubs for the real bindings.

#![warn(missing_docs)]

pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dct;
pub mod experiments;
pub mod gateway;
pub mod metrics;
pub mod perfmodel;
pub mod registry;
pub mod runtime;
pub mod sell;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod trainer;
pub mod util;
