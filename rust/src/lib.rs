//! # acdc — ACDC: A Structured Efficient Linear Layer (ICLR 2016)
//!
//! Rust + JAX + Pallas reproduction of Moczulski et al., ICLR 2016.
//!
//! Three layers (see DESIGN.md):
//! * **L1** (`python/compile/kernels/`): fused Pallas ACDC kernel;
//! * **L2** (`python/compile/model.py`): jax models lowered AOT to HLO text;
//! * **L3** (this crate): the deployment substrate — PJRT runtime, serving
//!   coordinator with dynamic batching, training orchestrator, reference
//!   SELL implementations and the paper's experiment harnesses.
//!
//! Python never runs on the request path: `make artifacts` lowers once,
//! and this crate loads/executes the artifacts via the PJRT C API.

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dct;
pub mod experiments;
pub mod metrics;
pub mod perfmodel;
pub mod runtime;
pub mod sell;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
