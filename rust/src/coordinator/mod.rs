//! Serving coordinator: router → bucketed dynamic batcher → worker pool.
//!
//! The paper's system-level pitch is efficiency at serving time; this
//! module is the deployment substrate around the AOT-compiled SELL
//! programs. Shape: requests enter through [`Coordinator::submit`]
//! (bounded queue → backpressure), a batcher thread forms size-bucketed
//! batches under a latency deadline, and a worker pool executes them on
//! thread-local executors (PJRT or native reference).
//!
//! Both stages are bounded: the request queue at `queue_cap` and the
//! formed-batch channel at `2 × workers`. Slow executors therefore
//! backpressure the batcher, the batcher backpressures the request queue,
//! and saturation surfaces deterministically as [`SubmitError::QueueFull`]
//! at the submit edge — which the network gateway maps to HTTP 503.

pub mod batcher;
pub mod faults;
pub mod request;
pub mod worker;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::metrics::Registry;
use batcher::BatchPolicy;
use request::{Features, InferRequest, InferResponse, Reply, ResponseSlot, RowRef};
use worker::{ExecutorFactory, WorkerPool};

/// Submission error (backpressure or shutdown).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue is full — caller should retry/shed load.
    QueueFull,
    /// Coordinator is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "request queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator closed"),
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    req_tx: Option<SyncSender<InferRequest>>,
    batcher: Option<JoinHandle<()>>,
    pool: Option<WorkerPool>,
    next_id: AtomicU64,
    metrics: Arc<Registry>,
    width: usize,
    accepted: Arc<crate::metrics::Counter>,
    rejected: Arc<crate::metrics::Counter>,
}

impl Coordinator {
    /// Start batcher + workers for one model of input width `width`.
    pub fn start(
        cfg: &ServeConfig,
        width: usize,
        factory: ExecutorFactory,
        metrics: Arc<Registry>,
    ) -> Coordinator {
        cfg.validate().expect("invalid serve config");
        // Deterministic fault injection (chaos tests): when the [faults]
        // config (or the ACDC_FAULTS env var) is active, every worker's
        // executor is wrapped in a seeded delay/error/stall injector.
        let faults = cfg
            .faults
            .with_env_overrides()
            .expect("invalid ACDC_FAULTS");
        let factory = if faults.active() {
            faults::wrap_factory(factory, faults)
        } else {
            factory
        };
        let (req_tx, req_rx) = sync_channel::<InferRequest>(cfg.queue_cap);
        // Bounded so a slow worker pool backpressures batch formation
        // instead of letting formed batches pile up unboundedly; 2× the
        // pool keeps every worker busy while one batch is in flight.
        let (batch_tx, batch_rx) = sync_channel(cfg.workers.saturating_mul(2).max(1));
        // Emptied request buffers flow back from the workers so batch
        // formation reuses a fixed pool instead of allocating per batch
        // (bounded array channel: the handoff itself never allocates).
        let (recycle_tx, recycle_rx) =
            sync_channel(cfg.workers.saturating_mul(2).saturating_add(2));
        let policy = BatchPolicy::new(
            cfg.buckets.clone(),
            Duration::from_micros(cfg.max_wait_us),
        );
        // Live queue length on /metrics — the direct observable for
        // "is latency queueing or compute" when reading a slow trace.
        let depth = metrics.gauge("coordinator.queue_depth");
        // Shared by name with the worker pool's reap point: one
        // gateway.deadline_reaped series covers both.
        let reaped = metrics.counter("gateway.deadline_reaped");
        let batcher = std::thread::Builder::new()
            .name("acdc-batcher".into())
            .spawn(move || {
                batcher::run_batcher(
                    policy,
                    req_rx,
                    batch_tx,
                    recycle_rx,
                    Some(depth),
                    Some(reaped),
                )
            })
            .expect("spawn batcher");
        let pool = WorkerPool::spawn(
            cfg.workers,
            factory,
            batch_rx,
            Arc::clone(&metrics),
            Some(recycle_tx),
        );
        let accepted = metrics.counter("coordinator.accepted");
        let rejected = metrics.counter("coordinator.rejected");
        Coordinator {
            req_tx: Some(req_tx),
            batcher: Some(batcher),
            pool: Some(pool),
            next_id: AtomicU64::new(1),
            metrics,
            width,
            accepted,
            rejected,
        }
    }

    /// Model input width N (features per request row).
    pub fn width(&self) -> usize {
        self.width
    }

    /// The shared metrics registry.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Submit one feature row; returns the response receiver. Requests on
    /// this convenience path are untraced (`trace` 0) — the gateway's slot
    /// path is where trace IDs travel.
    pub fn submit(&self, features: Vec<f32>) -> Result<Receiver<InferResponse>, SubmitError> {
        assert_eq!(features.len(), self.width, "feature width mismatch");
        let (tx, rx) = std::sync::mpsc::channel();
        self.enqueue(InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            trace: 0,
            features: Features::Owned(features),
            enqueued_at: Instant::now(),
            deadline: None,
            reply: Reply::Channel(tx),
        })
        .map(|()| rx)
    }

    /// Submit one arena row on the zero-allocation path: the worker copies
    /// the input out of — and the output back into — the buffers behind
    /// `row`, and signals `slot` (whose current sequence `row` must carry,
    /// see [`ResponseSlot::issue`]). `trace` is the request's trace ID
    /// (0 = untraced), carried so worker-side log events can name the
    /// request. `deadline` is the request's admission-minted deadline:
    /// past it, the batcher/worker reap the request
    /// ([`request::SlotError::Expired`]) instead of executing it. No
    /// allocation on success.
    pub fn submit_slot(
        &self,
        row: RowRef,
        slot: &Arc<ResponseSlot>,
        trace: u64,
        deadline: Option<Instant>,
    ) -> Result<(), SubmitError> {
        assert_eq!(row.len(), self.width, "feature width mismatch");
        self.enqueue(InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            trace,
            features: Features::Borrowed(row),
            enqueued_at: Instant::now(),
            deadline,
            reply: Reply::Slot(Arc::clone(slot)),
        })
    }

    fn enqueue(&self, req: InferRequest) -> Result<(), SubmitError> {
        let Some(req_tx) = &self.req_tx else {
            return Err(SubmitError::Closed);
        };
        match req_tx.try_send(req) {
            Ok(()) => {
                self.accepted.inc();
                Ok(())
            }
            Err(TrySendError::Full(_)) => {
                self.rejected.inc();
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Convenience: submit and block for the answer.
    pub fn infer(&self, features: Vec<f32>, timeout: Duration) -> Result<InferResponse, String> {
        let rx = self.submit(features).map_err(|e| e.to_string())?;
        rx.recv_timeout(timeout)
            .map_err(|e| format!("response wait: {e}"))
    }

    /// Graceful shutdown: stop intake, drain, join threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.req_tx.take(); // close intake → batcher flushes and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        if let Some(p) = self.pool.take() {
            p.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::BatchExecutor;

    struct EchoExecutor {
        n: usize,
    }

    impl BatchExecutor for EchoExecutor {
        fn width(&self) -> usize {
            self.n
        }
        fn out_width(&self) -> usize {
            self.n
        }
        fn execute_into(
            &mut self,
            _bucket: usize,
            padded: &[f32],
            out: &mut [f32],
        ) -> Result<(), String> {
            out.copy_from_slice(padded);
            Ok(())
        }
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            buckets: vec![1, 4, 16],
            max_wait_us: 500,
            workers: 2,
            queue_cap: 64,
            ..Default::default()
        }
    }

    fn echo_coordinator(n: usize) -> Coordinator {
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(move || Ok(Box::new(EchoExecutor { n }) as Box<dyn BatchExecutor>));
        Coordinator::start(&cfg(), n, factory, metrics)
    }

    #[test]
    fn single_request_roundtrip() {
        let c = echo_coordinator(4);
        let resp = c
            .infer(vec![1.0, 2.0, 3.0, 4.0], Duration::from_secs(2))
            .unwrap();
        assert_eq!(resp.output.unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let c = Arc::new(echo_coordinator(2));
        let mut rxs = vec![];
        for i in 0..50 {
            rxs.push(c.submit(vec![i as f32, -(i as f32)]).unwrap());
        }
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(resp.output.unwrap(), vec![i as f32, -(i as f32)]);
        }
        assert_eq!(c.metrics().counter("coordinator.accepted").get(), 50);
    }

    #[test]
    #[should_panic]
    fn submit_rejects_wrong_width() {
        let c = echo_coordinator(4);
        let _ = c.submit(vec![1.0]);
    }

    #[test]
    fn responses_preserve_request_identity() {
        // Batches mix rows; each caller must get *its* row back.
        let c = echo_coordinator(1);
        let mut pairs = vec![];
        for i in 0..20 {
            pairs.push((i, c.submit(vec![i as f32 * 10.0]).unwrap()));
        }
        for (i, rx) in pairs {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(resp.output.unwrap(), vec![i as f32 * 10.0]);
        }
    }

    #[test]
    fn shutdown_is_clean_with_inflight_work() {
        let c = echo_coordinator(2);
        let mut rxs = vec![];
        for i in 0..10 {
            rxs.push(c.submit(vec![i as f32, 0.0]).unwrap());
        }
        c.shutdown(); // must flush, not hang
        let mut answered = 0;
        for rx in rxs {
            if rx.recv_timeout(Duration::from_millis(100)).is_ok() {
                answered += 1;
            }
        }
        assert_eq!(answered, 10, "all in-flight requests answered on shutdown");
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        // 1 worker blocked by slow executor + tiny queue ⇒ QueueFull.
        struct SlowExecutor;
        impl BatchExecutor for SlowExecutor {
            fn width(&self) -> usize {
                1
            }
            fn out_width(&self) -> usize {
                1
            }
            fn execute_into(
                &mut self,
                _b: usize,
                p: &[f32],
                out: &mut [f32],
            ) -> Result<(), String> {
                std::thread::sleep(Duration::from_millis(50));
                out.copy_from_slice(p);
                Ok(())
            }
        }
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(SlowExecutor) as Box<dyn BatchExecutor>));
        let c = Coordinator::start(
            &ServeConfig {
                buckets: vec![1],
                max_wait_us: 1,
                workers: 1,
                queue_cap: 2,
                ..Default::default()
            },
            1,
            factory,
            metrics,
        );
        let mut keep = vec![];
        let mut saw_full = false;
        for i in 0..200 {
            match c.submit(vec![i as f32]) {
                Ok(rx) => keep.push(rx),
                Err(SubmitError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_full, "expected backpressure rejection");
        assert!(c.metrics().counter("coordinator.rejected").get() >= 1);
    }

    #[test]
    fn saturation_is_deterministic_and_drain_answers_inflight() {
        // Bounded pipeline capacity with buckets [1], 1 worker, queue_cap 2:
        //   1 executing + 2 batch-channel slots + 1 held by the blocked
        //   batcher + 2 request-queue slots = 6 requests absorbed.
        // The 7th submit must fail with QueueFull while the worker is still
        // on the first batch, and shutdown must drain all 6.
        struct SlowExecutor;
        impl BatchExecutor for SlowExecutor {
            fn width(&self) -> usize {
                1
            }
            fn out_width(&self) -> usize {
                1
            }
            fn execute_into(
                &mut self,
                _b: usize,
                p: &[f32],
                out: &mut [f32],
            ) -> Result<(), String> {
                std::thread::sleep(Duration::from_millis(300));
                out.copy_from_slice(p);
                Ok(())
            }
        }
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(SlowExecutor) as Box<dyn BatchExecutor>));
        let c = Coordinator::start(
            &ServeConfig {
                buckets: vec![1],
                max_wait_us: 1,
                workers: 1,
                queue_cap: 2,
                ..Default::default()
            },
            1,
            factory,
            metrics,
        );
        let mut rxs = vec![];
        rxs.push(c.submit(vec![0.0]).unwrap());
        // Let the worker pick up request 0 before filling the pipeline.
        std::thread::sleep(Duration::from_millis(50));
        for i in 1..6 {
            rxs.push(c.submit(vec![i as f32]).unwrap());
            // Paced so the batcher (not the request queue) absorbs each
            // submit until every stage is full.
            std::thread::sleep(Duration::from_millis(10));
        }
        // Let the batcher settle (blocked on the full batch channel).
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(
            c.submit(vec![6.0]).unwrap_err(),
            SubmitError::QueueFull,
            "7th request must be shed while the pipeline is saturated"
        );
        assert_eq!(c.metrics().counter("coordinator.rejected").get(), 1);
        c.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx
                .recv_timeout(Duration::from_secs(5))
                .unwrap_or_else(|e| panic!("request {i} unanswered after drain: {e}"));
            assert_eq!(resp.output.unwrap(), vec![i as f32]);
        }
    }
}
