//! Request/response types flowing through the serving coordinator.
//!
//! Two request shapes share the pipeline:
//!
//! * **Owned + channel** — the legacy [`Features::Owned`] /
//!   [`Reply::Channel`] pair: the row is a heap `Vec<f32>` and the answer
//!   arrives on a per-request mpsc channel (`Coordinator::submit`).
//! * **Borrowed + slot** — the zero-allocation gateway path
//!   ([`Features::Borrowed`] / [`Reply::Slot`]): the row lives in a
//!   connection-owned arena, referenced by a raw [`RowRef`]; the worker
//!   copies the input out of — and writes the output back into — that
//!   arena **under the slot's lock**, and completion is signalled through
//!   a reusable [`ResponseSlot`] (condvar, no channel, no allocation).
//!
//! The slot protocol that makes the raw pointers sound: every use of a
//! slot gets a fresh sequence number ([`ResponseSlot::issue`]); the worker
//! touches the arena only while holding the slot lock *and* only if the
//! sequence still matches and the use was not abandoned. The connection
//! abandons outstanding uses ([`ResponseSlot::abandon`]) before reusing or
//! growing its arena (timeout, shed, connection teardown), so a stale
//! worker can never dereference a dangling pointer.

use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Unique request id.
pub type RequestId = u64;

/// The feature payload of one request row.
#[derive(Debug)]
pub enum Features {
    /// Heap-owned row (the legacy `submit` path).
    Owned(Vec<f32>),
    /// Zero-copy view into a connection-owned arena; only dereferenced
    /// under the paired [`ResponseSlot`]'s lock.
    Borrowed(RowRef),
}

impl Features {
    /// Row width.
    pub fn len(&self) -> usize {
        match self {
            Features::Owned(v) => v.len(),
            Features::Borrowed(r) => r.len,
        }
    }

    /// Whether the row is empty (width 0 never occurs in practice).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Where a request's answer goes.
#[derive(Debug)]
pub enum Reply {
    /// Per-request mpsc channel (legacy path).
    Channel(Sender<InferResponse>),
    /// Reusable completion slot (zero-allocation path).
    Slot(Arc<ResponseSlot>),
}

/// Raw view of one arena row: input features plus the output destination.
///
/// Constructed only by [`RowRef::new`] (unsafe): the creator promises the
/// pointed-to buffers stay valid and unaliased until the paired slot use
/// is completed or abandoned.
#[derive(Debug)]
pub struct RowRef {
    ptr: *const f32,
    out: *mut f32,
    len: usize,
    /// Capacity of the output destination (an output row wider than this
    /// is answered with an error instead of written).
    out_cap: usize,
    /// The slot sequence number this use was issued under.
    seq: u64,
}

// SAFETY: the pointers are only dereferenced while holding the paired
// slot's lock with a matching sequence number (see the module docs); the
// issuing connection keeps the buffers alive until then.
unsafe impl Send for RowRef {}

impl RowRef {
    /// Build a row view over caller-owned buffers.
    ///
    /// # Safety
    /// `ptr[..len]` and `out[..out_cap]` must stay valid, disjoint, and
    /// unwritten (resp. unread) by the caller until the slot use `seq`
    /// (from [`ResponseSlot::issue`]) is observed done or abandoned.
    pub unsafe fn new(
        ptr: *const f32,
        len: usize,
        out: *mut f32,
        out_cap: usize,
        seq: u64,
    ) -> RowRef {
        RowRef {
            ptr,
            out,
            len,
            out_cap,
            seq,
        }
    }

    /// Row width.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the row is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// How one slot use ended.
#[derive(Debug)]
enum SlotOutcome {
    /// Not completed yet.
    Pending,
    /// Output row of this length written into the arena.
    Ok(usize),
    /// Executor (or pipeline) error.
    Err(String),
    /// Deadline passed before execution; the request was reaped.
    Expired,
}

/// Typed failure of one slot use, so the gateway can answer a reaped
/// request with 504 (deadline exceeded) instead of a generic 500.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SlotError {
    /// The request's deadline passed before execution; the coordinator
    /// reaped it without computing anything.
    Expired,
    /// Executor (or pipeline) error.
    Exec(String),
}

impl std::fmt::Display for SlotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SlotError::Expired => write!(f, "deadline exceeded before execution"),
            SlotError::Exec(e) => write!(f, "{e}"),
        }
    }
}

#[derive(Debug)]
struct SlotState {
    seq: u64,
    done: bool,
    abandoned: bool,
    queue_us: u64,
    form_us: u64,
    execute_us: u64,
    batch_size: usize,
    outcome: SlotOutcome,
}

/// A reusable completion cell for the zero-allocation request path: one
/// mutex + condvar reused for every request a connection serves (via
/// [`ResponseSlot::issue`]'s sequence numbers), instead of a fresh mpsc
/// channel per request.
#[derive(Debug)]
pub struct ResponseSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Answer metadata read back from a completed slot; the output row itself
/// is already in the issuing connection's arena.
#[derive(Debug)]
pub struct SlotReply {
    /// Time spent queued before batch formation (µs).
    pub queue_us: u64,
    /// Batch handoff time — formation until the worker started executing
    /// (channel transit + input gather/padding, µs).
    pub form_us: u64,
    /// Batch execution wall time (µs).
    pub execute_us: u64,
    /// Bucket size this row was served in.
    pub batch_size: usize,
    /// Output row length written into the arena, or the typed error.
    pub output: Result<usize, SlotError>,
}

impl Default for ResponseSlot {
    fn default() -> Self {
        ResponseSlot {
            state: Mutex::new(SlotState {
                seq: 0,
                done: true,
                abandoned: false,
                queue_us: 0,
                form_us: 0,
                execute_us: 0,
                batch_size: 0,
                outcome: SlotOutcome::Pending,
            }),
            cv: Condvar::new(),
        }
    }
}

impl ResponseSlot {
    /// Fresh slot (idle until the first [`ResponseSlot::issue`]).
    pub fn new() -> ResponseSlot {
        Self::default()
    }

    /// Begin a new use: resets the slot and returns the sequence number
    /// the paired [`RowRef`] must carry. Stale completions from earlier
    /// sequences are ignored.
    pub fn issue(&self) -> u64 {
        let mut s = self.state.lock().unwrap();
        s.seq += 1;
        s.done = false;
        s.abandoned = false;
        s.queue_us = 0;
        s.form_us = 0;
        s.execute_us = 0;
        s.batch_size = 0;
        s.outcome = SlotOutcome::Pending;
        s.seq
    }

    /// Abandon use `seq`: after this returns, the worker will never touch
    /// the arena for that use, so the issuing connection may reuse or
    /// free its buffers. No-op if the use already completed.
    pub fn abandon(&self, seq: u64) {
        let mut s = self.state.lock().unwrap();
        if s.seq == seq && !s.done {
            s.abandoned = true;
        }
    }

    /// Block until use `seq` completes or `deadline` passes. `None` on
    /// timeout (the caller must then [`ResponseSlot::abandon`] before
    /// reusing its arena).
    pub fn wait(&self, seq: u64, deadline: Instant) -> Option<SlotReply> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.seq == seq && s.done {
                let output = match std::mem::replace(&mut s.outcome, SlotOutcome::Pending) {
                    SlotOutcome::Ok(len) => Ok(len),
                    SlotOutcome::Err(e) => Err(SlotError::Exec(e)),
                    SlotOutcome::Expired => Err(SlotError::Expired),
                    SlotOutcome::Pending => {
                        Err(SlotError::Exec("slot completed without outcome".to_string()))
                    }
                };
                return Some(SlotReply {
                    queue_us: s.queue_us,
                    form_us: s.form_us,
                    execute_us: s.execute_us,
                    batch_size: s.batch_size,
                    output,
                });
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(s, deadline.saturating_duration_since(now))
                .unwrap();
            s = guard;
        }
    }

    /// Worker side: copy the input row out of the arena into `dst`.
    /// Returns false (leaving `dst` untouched beyond zeros the caller put
    /// there) when the use was abandoned or superseded.
    pub fn copy_input(&self, row: &RowRef, dst: &mut [f32]) -> bool {
        let s = self.state.lock().unwrap();
        if s.seq != row.seq || s.abandoned {
            return false;
        }
        debug_assert_eq!(dst.len(), row.len);
        // SAFETY: seq matches and the use is not abandoned, so the issuer
        // is still keeping `ptr[..len]` alive (module-docs protocol), and
        // it never writes the buffer while the use is outstanding.
        unsafe {
            std::ptr::copy_nonoverlapping(row.ptr, dst.as_mut_ptr(), row.len.min(dst.len()));
        }
        true
    }

    /// Worker side: finish use `row.seq` — write the output row into the
    /// arena (when it fits; a wider row becomes an error) and publish the
    /// metadata. Stale or abandoned uses are dropped silently.
    pub fn complete(
        &self,
        row: &RowRef,
        output: Result<&[f32], &str>,
        queue_us: u64,
        form_us: u64,
        execute_us: u64,
        batch_size: usize,
    ) {
        let mut s = self.state.lock().unwrap();
        if s.seq != row.seq || s.abandoned {
            return;
        }
        s.outcome = match output {
            Ok(vals) => {
                if vals.len() <= row.out_cap {
                    // SAFETY: seq matches and the use is not abandoned, so
                    // `out[..out_cap]` is alive and exclusively ours (the
                    // issuer neither reads nor writes it until `done`).
                    unsafe {
                        std::ptr::copy_nonoverlapping(vals.as_ptr(), row.out, vals.len());
                    }
                    SlotOutcome::Ok(vals.len())
                } else {
                    SlotOutcome::Err(format!(
                        "output row ({} values) exceeds the request arena ({})",
                        vals.len(),
                        row.out_cap
                    ))
                }
            }
            Err(e) => SlotOutcome::Err(e.to_string()),
        };
        s.queue_us = queue_us;
        s.form_us = form_us;
        s.execute_us = execute_us;
        s.batch_size = batch_size;
        s.done = true;
        drop(s);
        self.cv.notify_all();
    }

    /// Coordinator side: finish use `row.seq` with the typed
    /// deadline-exceeded outcome without touching the arena (there is no
    /// output to write — the request was reaped, not computed). Stale or
    /// abandoned uses are dropped silently, exactly like
    /// [`ResponseSlot::complete`].
    pub fn expire(&self, row: &RowRef, queue_us: u64) {
        let mut s = self.state.lock().unwrap();
        if s.seq != row.seq || s.abandoned {
            return;
        }
        s.outcome = SlotOutcome::Expired;
        s.queue_us = queue_us;
        s.form_us = 0;
        s.execute_us = 0;
        s.batch_size = 0;
        s.done = true;
        drop(s);
        self.cv.notify_all();
    }
}

/// An inference request: a feature row destined for a SELL classifier.
#[derive(Debug)]
pub struct InferRequest {
    /// Unique id assigned at submit time.
    pub id: RequestId,
    /// Trace ID minted at admission (0 = untraced); rides the request
    /// through batcher and worker so log events on those threads stay
    /// correlated with the originating HTTP request.
    pub trace: u64,
    /// Feature vector (length = model width N).
    pub features: Features,
    /// Enqueue timestamp for latency accounting.
    pub enqueued_at: Instant,
    /// Absolute deadline minted at admission (`None` = no deadline; the
    /// legacy `submit` path). The batcher reaps expired requests at batch
    /// formation and the worker re-checks before execute, so past this
    /// instant the row is answered [`SlotError::Expired`] instead of
    /// computed.
    pub deadline: Option<Instant>,
    /// Where the response is delivered.
    pub reply: Reply,
}

impl InferRequest {
    /// True when the request carries a deadline that `now` has passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Answer the request with the typed deadline-exceeded outcome and
    /// drop it (cooperative cancellation: the work is reaped, never
    /// computed). Slot-path requests signal [`SlotError::Expired`]
    /// through their slot; channel-path requests get an error response.
    pub fn reap(self, now: Instant) {
        let queue_us = now.saturating_duration_since(self.enqueued_at).as_micros() as u64;
        match (self.reply, self.features) {
            (Reply::Slot(slot), Features::Borrowed(row)) => slot.expire(&row, queue_us),
            (Reply::Channel(tx), _) => {
                let _ = tx.send(InferResponse {
                    id: self.id,
                    output: Err(SlotError::Expired.to_string()),
                    queue_us,
                    form_us: 0,
                    execute_us: 0,
                    batch_size: 0,
                });
            }
            // Slot reply without an arena row cannot be signalled; the
            // waiter's own timeout covers it. Does not occur in practice.
            (Reply::Slot(_), Features::Owned(_)) => {}
        }
    }
}

/// The coordinator's answer (legacy channel path).
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// The request this answers.
    pub id: RequestId,
    /// Model output row (e.g. class log-probabilities).
    pub output: Result<Vec<f32>, String>,
    /// Time spent queued before batch formation.
    pub queue_us: u64,
    /// Batch handoff time (formation → execution start).
    pub form_us: u64,
    /// Batch execution wall time.
    pub execute_us: u64,
    /// Bucket size this request was served in.
    pub batch_size: usize,
}

/// A batch formed by the batcher, ready for a worker. The `requests`
/// vector is drawn from — and recycled back into — the coordinator's
/// buffer pool, so steady-state batch formation allocates nothing.
#[derive(Debug)]
pub struct FormedBatch {
    /// Bucket capacity chosen (rows are padded up to this).
    pub bucket: usize,
    /// The actual requests (len ≤ bucket).
    pub requests: Vec<InferRequest>,
    /// When the batcher dispatched this batch.
    pub formed_at: Instant,
}

impl FormedBatch {
    /// Occupancy in [0, 1] — 1.0 means no padding waste.
    pub fn occupancy(&self) -> f64 {
        self.requests.len() as f64 / self.bucket as f64
    }
}

/// Convenience: wait with a relative timeout (tests).
pub fn wait_slot(slot: &ResponseSlot, seq: u64, timeout: Duration) -> Option<SlotReply> {
    slot.wait(seq, Instant::now() + timeout)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy() {
        let batch = FormedBatch {
            bucket: 4,
            requests: vec![
                InferRequest {
                    id: 1,
                    trace: 0,
                    features: Features::Owned(vec![1.0, 2.0]),
                    enqueued_at: Instant::now(),
                    deadline: None,
                    reply: Reply::Channel(std::sync::mpsc::channel().0),
                },
                InferRequest {
                    id: 2,
                    trace: 0,
                    features: Features::Owned(vec![3.0, 4.0]),
                    enqueued_at: Instant::now(),
                    deadline: None,
                    reply: Reply::Channel(std::sync::mpsc::channel().0),
                },
            ],
            formed_at: Instant::now(),
        };
        assert_eq!(batch.occupancy(), 0.5);
        assert_eq!(batch.requests[0].features.len(), 2);
    }

    #[test]
    fn slot_roundtrip_copies_through_arena() {
        let slot = Arc::new(ResponseSlot::new());
        let input = [1.0f32, 2.0, 3.0];
        let mut output = [0.0f32; 3];
        let seq = slot.issue();
        // SAFETY: buffers outlive the completed use below.
        let row = unsafe { RowRef::new(input.as_ptr(), 3, output.as_mut_ptr(), 3, seq) };
        let mut dst = [0.0f32; 3];
        assert!(slot.copy_input(&row, &mut dst));
        assert_eq!(dst, input);
        slot.complete(&row, Ok(&[9.0, 8.0, 7.0]), 5, 7, 11, 4);
        let reply = wait_slot(&slot, seq, Duration::from_secs(1)).unwrap();
        assert_eq!(reply.output.unwrap(), 3);
        assert_eq!(
            (reply.queue_us, reply.form_us, reply.execute_us, reply.batch_size),
            (5, 7, 11, 4)
        );
        assert_eq!(output, [9.0, 8.0, 7.0]);
    }

    #[test]
    fn abandoned_use_blocks_arena_access() {
        let slot = Arc::new(ResponseSlot::new());
        let input = [1.0f32];
        let mut output = [0.0f32];
        let seq = slot.issue();
        let row = unsafe { RowRef::new(input.as_ptr(), 1, output.as_mut_ptr(), 1, seq) };
        slot.abandon(seq);
        let mut dst = [0.0f32];
        assert!(!slot.copy_input(&row, &mut dst), "abandoned input must not be read");
        slot.complete(&row, Ok(&[5.0]), 0, 0, 0, 1);
        assert_eq!(output, [0.0], "abandoned output must not be written");
        assert!(wait_slot(&slot, seq, Duration::from_millis(20)).is_none());
    }

    #[test]
    fn stale_sequence_is_ignored() {
        let slot = Arc::new(ResponseSlot::new());
        let input = [1.0f32];
        let mut output = [0.0f32];
        let old_seq = slot.issue();
        let row = unsafe { RowRef::new(input.as_ptr(), 1, output.as_mut_ptr(), 1, old_seq) };
        let new_seq = slot.issue(); // reuse supersedes the old use
        slot.complete(&row, Ok(&[5.0]), 0, 0, 0, 1);
        assert_eq!(output, [0.0], "stale completion must not touch the arena");
        assert!(wait_slot(&slot, new_seq, Duration::from_millis(20)).is_none());
    }

    #[test]
    fn oversized_output_becomes_error_not_overflow() {
        let slot = Arc::new(ResponseSlot::new());
        let input = [1.0f32];
        let mut output = [0.0f32; 2];
        let seq = slot.issue();
        let row = unsafe { RowRef::new(input.as_ptr(), 1, output.as_mut_ptr(), 2, seq) };
        slot.complete(&row, Ok(&[1.0, 2.0, 3.0]), 0, 0, 0, 1);
        let reply = wait_slot(&slot, seq, Duration::from_secs(1)).unwrap();
        assert!(reply.output.unwrap_err().to_string().contains("exceeds"));
        assert_eq!(output, [0.0, 0.0]);
    }

    #[test]
    fn expired_slot_reports_typed_error_without_touching_arena() {
        let slot = Arc::new(ResponseSlot::new());
        let input = [1.0f32];
        let mut output = [0.0f32];
        let seq = slot.issue();
        let row = unsafe { RowRef::new(input.as_ptr(), 1, output.as_mut_ptr(), 1, seq) };
        slot.expire(&row, 42);
        let reply = wait_slot(&slot, seq, Duration::from_secs(1)).unwrap();
        assert_eq!(reply.output, Err(SlotError::Expired));
        assert_eq!(reply.queue_us, 42);
        assert_eq!(output, [0.0], "reaped request must not write output");
        // A stale expire is dropped like a stale complete.
        let new_seq = slot.issue();
        slot.expire(&row, 0);
        assert!(wait_slot(&slot, new_seq, Duration::from_millis(20)).is_none());
    }

    #[test]
    fn deadline_expiry_and_reap() {
        let now = Instant::now();
        let (tx, rx) = std::sync::mpsc::channel();
        let req = InferRequest {
            id: 7,
            trace: 0,
            features: Features::Owned(vec![1.0]),
            enqueued_at: now,
            deadline: Some(now + Duration::from_millis(10)),
            reply: Reply::Channel(tx),
        };
        assert!(!req.expired(now));
        let late = now + Duration::from_millis(11);
        assert!(req.expired(late));
        req.reap(late);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert!(resp.output.unwrap_err().contains("deadline"));
        // No deadline → never expires.
        let req = InferRequest {
            id: 8,
            trace: 0,
            features: Features::Owned(vec![1.0]),
            enqueued_at: now,
            deadline: None,
            reply: Reply::Channel(std::sync::mpsc::channel().0),
        };
        assert!(!req.expired(late + Duration::from_secs(3600)));
    }

    #[test]
    fn wait_wakes_from_another_thread() {
        let slot = Arc::new(ResponseSlot::new());
        let seq = slot.issue();
        let input = vec![2.0f32];
        let mut output = vec![0.0f32];
        let row = unsafe { RowRef::new(input.as_ptr(), 1, output.as_mut_ptr(), 1, seq) };
        let slot2 = Arc::clone(&slot);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            slot2.complete(&row, Ok(&[4.0]), 1, 1, 2, 1);
        });
        let reply = wait_slot(&slot, seq, Duration::from_secs(5)).unwrap();
        assert_eq!(reply.output.unwrap(), 1);
        t.join().unwrap();
        assert_eq!(output[0], 4.0);
    }
}
