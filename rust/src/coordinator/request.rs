//! Request/response types flowing through the serving coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// An inference request: a feature row destined for a SELL classifier.
#[derive(Debug)]
pub struct InferRequest {
    /// Unique id assigned at submit time.
    pub id: RequestId,
    /// Feature vector (length = model width N).
    pub features: Vec<f32>,
    /// Enqueue timestamp for latency accounting.
    pub enqueued_at: Instant,
    /// Where the response is delivered.
    pub reply: Sender<InferResponse>,
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// The request this answers.
    pub id: RequestId,
    /// Model output row (e.g. class log-probabilities).
    pub output: Result<Vec<f32>, String>,
    /// Time spent queued before batch formation.
    pub queue_us: u64,
    /// Batch execution wall time.
    pub execute_us: u64,
    /// Bucket size this request was served in.
    pub batch_size: usize,
}

/// A batch formed by the batcher, ready for a worker.
#[derive(Debug)]
pub struct FormedBatch {
    /// Bucket capacity chosen (rows are padded up to this).
    pub bucket: usize,
    /// The actual requests (len ≤ bucket).
    pub requests: Vec<InferRequest>,
    /// When the batcher dispatched this batch.
    pub formed_at: Instant,
}

impl FormedBatch {
    /// Occupancy in [0, 1] — 1.0 means no padding waste.
    pub fn occupancy(&self) -> f64 {
        self.requests.len() as f64 / self.bucket as f64
    }

    /// Flatten request rows into a padded [bucket, n] row-major buffer.
    pub fn padded_features(&self, n: usize) -> Vec<f32> {
        let mut buf = vec![0.0f32; self.bucket * n];
        for (i, req) in self.requests.iter().enumerate() {
            assert_eq!(req.features.len(), n, "request width mismatch");
            buf[i * n..(i + 1) * n].copy_from_slice(&req.features);
        }
        buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    type RespRx = std::sync::mpsc::Receiver<InferResponse>;

    fn req(id: u64, features: Vec<f32>) -> (InferRequest, RespRx) {
        let (tx, rx) = channel();
        (
            InferRequest {
                id,
                features,
                enqueued_at: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn occupancy_and_padding() {
        let (r1, _rx1) = req(1, vec![1.0, 2.0]);
        let (r2, _rx2) = req(2, vec![3.0, 4.0]);
        let batch = FormedBatch {
            bucket: 4,
            requests: vec![r1, r2],
            formed_at: Instant::now(),
        };
        assert_eq!(batch.occupancy(), 0.5);
        let padded = batch.padded_features(2);
        assert_eq!(padded, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn padded_features_rejects_wrong_width() {
        let (r1, _rx) = req(1, vec![1.0, 2.0, 3.0]);
        let batch = FormedBatch {
            bucket: 1,
            requests: vec![r1],
            formed_at: Instant::now(),
        };
        batch.padded_features(2);
    }
}
