//! Worker pool executing formed batches.
//!
//! PJRT objects are not `Send` (raw C pointers), so each worker thread
//! constructs its *own* executor via a factory closure invoked on the
//! worker's thread — channels only ever carry plain data. This is the
//! one-client-per-worker pattern; with the CPU plugin each client shares
//! the host's cores, and the pool size bounds concurrent executions.
//!
//! The per-batch hot loop is allocation-free in steady state: the padded
//! input and the output live in worker-thread buffers that are grown once
//! and reused, executors write into the caller-provided output slice
//! ([`BatchExecutor::execute_into`]), zero-alloc requests get their rows
//! copied in/out of the connection arena under their slot locks, and the
//! emptied `requests` vector is recycled back to the batcher.

use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::request::{Features, FormedBatch, InferRequest, InferResponse, Reply, SlotError};
use crate::metrics::Registry;
use crate::trace::log::{self, Field, Level};

/// Executes one padded batch: input is the padded [bucket, n] row-major
/// feature buffer; the executor writes `bucket × out_width` outputs into
/// `out` (sized by the caller).
pub trait BatchExecutor {
    /// Model input width N.
    fn width(&self) -> usize;
    /// Output width per row.
    fn out_width(&self) -> usize;
    /// Run the bucket-sized program, writing into `out`
    /// (`bucket × out_width` f32, pre-zeroed by the caller).
    fn execute_into(
        &mut self,
        bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String>;
}

/// Factory invoked on each worker thread to build its thread-local
/// executor (PJRT clients are not Send, so construction happens in-thread).
pub type ExecutorFactory = Arc<dyn Fn() -> Result<Box<dyn BatchExecutor>, String> + Send + Sync>;

/// Pool of worker threads draining a shared batch channel.
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers. Each calls `factory()` locally; a factory error
    /// makes the worker answer every batch with that error (the system
    /// degrades loudly rather than hanging). `recycle` hands emptied
    /// request buffers back to the batcher (None in tests that drive the
    /// batch channel directly).
    pub fn spawn(
        n: usize,
        factory: ExecutorFactory,
        rx: Receiver<FormedBatch>,
        metrics: Arc<Registry>,
        recycle: Option<SyncSender<Vec<InferRequest>>>,
    ) -> WorkerPool {
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n.max(1))
            .map(|wi| {
                let rx = Arc::clone(&rx);
                let factory = Arc::clone(&factory);
                let metrics = Arc::clone(&metrics);
                let recycle = recycle.clone();
                std::thread::Builder::new()
                    .name(format!("acdc-serve-{wi}"))
                    .spawn(move || worker_loop(factory, rx, metrics, recycle))
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Block until all workers exit (the batch channel must be closed).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    factory: ExecutorFactory,
    rx: Arc<Mutex<Receiver<FormedBatch>>>,
    metrics: Arc<Registry>,
    recycle: Option<SyncSender<Vec<InferRequest>>>,
) {
    let mut executor = factory();
    let batches = metrics.counter("worker.batches");
    let rows = metrics.counter("worker.rows");
    let padded_rows = metrics.counter("worker.padded_rows");
    let errors = metrics.counter("worker.errors");
    // Shared with the batcher by registry name: every reap point feeds
    // the one gateway.deadline_reaped series.
    let reaped_c = metrics.counter("gateway.deadline_reaped");
    // Batches the worker dropped whole because every row had expired by
    // the time it reached the executor (formed-but-stale).
    let dropped = metrics.counter("worker.batches_dropped");
    let exec_hist = metrics.histogram("worker.execute_ns");
    let queue_hist = metrics.histogram("worker.queue_wait_ns");
    // Live (un-padded) rows per executed batch — the occupancy series
    // that tells whether the batcher is filling its buckets.
    let occupancy = metrics.histogram("worker.batch_occupancy_rows");
    // Thread-persistent batch buffers: grown to the largest bucket seen,
    // then reused forever — no per-batch allocation.
    let mut padded: Vec<f32> = Vec::new();
    let mut outbuf: Vec<f32> = Vec::new();
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        let FormedBatch {
            bucket,
            mut requests,
            formed_at,
        } = batch;
        batches.inc();
        padded_rows.add((bucket - requests.len()) as u64);

        let t0 = Instant::now();
        // Deadline re-check before execute: rows that expired while the
        // batch sat in the worker channel are reaped here (left zero in
        // the padded buffer, answered SlotError::Expired below) — and a
        // batch with no live rows left is dropped whole rather than
        // computed. Both loops classify against the same `t0`, so a row
        // is consistently live or expired throughout this batch.
        let live = requests.iter().filter(|r| !r.expired(t0)).count();
        rows.add(live as u64);
        occupancy.record_ns(live as u64);
        // Batch-form handoff: formation to the moment this worker started
        // executing (time spent in the bounded worker channel).
        let form_us = t0.saturating_duration_since(formed_at).as_micros() as u64;
        let mut out_w = 0;
        let result: Result<(), String> = match &mut executor {
            Ok(exe) if live == 0 && !requests.is_empty() => {
                dropped.inc();
                out_w = exe.out_width();
                Ok(())
            }
            Ok(exe) => {
                let n = exe.width();
                out_w = exe.out_width();
                padded.clear();
                padded.resize(bucket * n, 0.0);
                let mut width_err = None;
                for (i, req) in requests.iter().enumerate() {
                    if req.expired(t0) {
                        continue; // reaped below; its lane stays zero
                    }
                    let dst = &mut padded[i * n..(i + 1) * n];
                    match &req.features {
                        Features::Owned(v) => {
                            if v.len() == n {
                                dst.copy_from_slice(v);
                            } else {
                                width_err =
                                    Some(format!("request width {} != model width {n}", v.len()));
                            }
                        }
                        Features::Borrowed(r) => {
                            if r.len() == n {
                                if let Reply::Slot(slot) = &req.reply {
                                    // Abandoned rows stay zero — their
                                    // issuer is gone and never reads back.
                                    let _ = slot.copy_input(r, dst);
                                }
                            } else {
                                width_err =
                                    Some(format!("request width {} != model width {n}", r.len()));
                            }
                        }
                    }
                }
                match width_err {
                    Some(e) => Err(e),
                    None => {
                        outbuf.clear();
                        outbuf.resize(bucket * out_w, 0.0);
                        exe.execute_into(bucket, &padded, &mut outbuf)
                    }
                }
            }
            Err(e) => Err(format!("executor init failed: {e}")),
        };
        let execute_us = t0.elapsed().as_micros() as u64;
        exec_hist.record_ns(t0.elapsed().as_nanos() as u64);
        if let Err(e) = &result {
            errors.inc();
            log::event(
                Level::Error,
                "worker",
                "batch_failed",
                requests.first().map(|r| r.trace).unwrap_or(0),
                &[
                    ("error", Field::Str(e)),
                    ("bucket", Field::U64(bucket as u64)),
                    ("rows", Field::U64(requests.len() as u64)),
                ],
            );
        } else if log::enabled(Level::Debug) {
            log::event(
                Level::Debug,
                "worker",
                "batch_executed",
                requests.first().map(|r| r.trace).unwrap_or(0),
                &[
                    ("bucket", Field::U64(bucket as u64)),
                    ("rows", Field::U64(requests.len() as u64)),
                    ("execute_us", Field::U64(execute_us)),
                    ("form_us", Field::U64(form_us)),
                ],
            );
        }

        for (i, req) in requests.iter().enumerate() {
            let queue_us = formed_at
                .saturating_duration_since(req.enqueued_at)
                .as_micros() as u64;
            queue_hist.record_ns(queue_us * 1_000);
            if req.expired(t0) {
                reaped_c.inc();
                match &req.reply {
                    Reply::Channel(tx) => {
                        let _ = tx.send(InferResponse {
                            id: req.id,
                            output: Err(SlotError::Expired.to_string()),
                            queue_us,
                            form_us,
                            execute_us: 0,
                            batch_size: 0,
                        });
                    }
                    Reply::Slot(slot) => {
                        if let Features::Borrowed(r) = &req.features {
                            slot.expire(r, queue_us);
                        }
                    }
                }
                continue;
            }
            let row_out: Result<&[f32], &str> = match &result {
                Ok(()) => {
                    let start = i * out_w;
                    if start + out_w <= outbuf.len() {
                        Ok(&outbuf[start..start + out_w])
                    } else {
                        Err("executor returned short output")
                    }
                }
                Err(e) => Err(e.as_str()),
            };
            match &req.reply {
                Reply::Channel(tx) => {
                    let output = match row_out {
                        Ok(vals) => Ok(vals.to_vec()),
                        Err(e) => Err(e.to_string()),
                    };
                    let _ = tx.send(InferResponse {
                        id: req.id,
                        output,
                        queue_us,
                        form_us,
                        execute_us,
                        batch_size: bucket,
                    });
                }
                Reply::Slot(slot) => {
                    if let Features::Borrowed(r) = &req.features {
                        slot.complete(r, row_out, queue_us, form_us, execute_us, bucket);
                    }
                }
            }
        }
        // Recycle the emptied buffer to the batcher; if its pool is full
        // the Vec simply drops (a dealloc, never an alloc).
        requests.clear();
        if let Some(recycle) = &recycle {
            let _ = recycle.try_send(requests);
        }
    }
}

/// A pure-rust executor over the reference SELL cascade — used by tests
/// and as a PJRT-free fallback path (`--native` serving mode).
///
/// Buckets run through the batched SoA ACDC engine
/// ([`crate::dct::batch`]); large buckets additionally fan panels out
/// across the process-wide [`crate::util::threadpool::global`] pool, so
/// every serving worker shares one set of compute threads. Small buckets
/// run serially through the worker-local [`crate::sell::acdc::CascadeScratch`]
/// — the steady-state path performs no allocation at all.
pub struct NativeCascadeExecutor {
    /// The cascade evaluated for each batch (cheap to clone per worker —
    /// all layers share one cached plan).
    pub cascade: crate::sell::acdc::AcdcCascade,
    /// Worker-local reusable forward buffers.
    scratch: crate::sell::acdc::CascadeScratch,
}

impl NativeCascadeExecutor {
    /// Executor over `cascade` with fresh (lazily grown) scratch.
    pub fn new(cascade: crate::sell::acdc::AcdcCascade) -> NativeCascadeExecutor {
        let n = cascade.n();
        NativeCascadeExecutor {
            cascade,
            scratch: crate::sell::acdc::CascadeScratch::new(n, 1),
        }
    }
}

impl BatchExecutor for NativeCascadeExecutor {
    fn width(&self) -> usize {
        self.cascade.n()
    }

    fn out_width(&self) -> usize {
        self.cascade.n()
    }

    fn execute_into(
        &mut self,
        bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        let n = self.width();
        if padded.len() != bucket * n {
            return Err(format!(
                "padded buffer {} != bucket {bucket} × n {n}",
                padded.len()
            ));
        }
        if out.len() != bucket * n {
            return Err(format!(
                "output buffer {} != bucket {bucket} × n {n}",
                out.len()
            ));
        }
        // Large buckets amortize pool dispatch; small ones stay serial
        // (and allocation-free through the worker-local scratch).
        if bucket >= 32 {
            let pool = crate::util::threadpool::global();
            let x = crate::tensor::Tensor::from_vec(&[bucket, n], padded.to_vec());
            out.copy_from_slice(self.cascade.forward_pooled(&x, pool).data());
        } else {
            self.cascade
                .forward_rows_into(padded, bucket, out, &mut self.scratch);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferRequest;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    struct DoubleExecutor {
        n: usize,
    }

    impl BatchExecutor for DoubleExecutor {
        fn width(&self) -> usize {
            self.n
        }
        fn out_width(&self) -> usize {
            self.n
        }
        fn execute_into(
            &mut self,
            bucket: usize,
            padded: &[f32],
            out: &mut [f32],
        ) -> Result<(), String> {
            assert_eq!(padded.len(), bucket * self.n);
            for (o, v) in out.iter_mut().zip(padded) {
                *o = v * 2.0;
            }
            Ok(())
        }
    }

    fn submit(
        tx: &std::sync::mpsc::Sender<FormedBatch>,
        ids: &[u64],
        bucket: usize,
        n: usize,
    ) -> Vec<std::sync::mpsc::Receiver<InferResponse>> {
        let mut rxs = vec![];
        let mut requests = vec![];
        for &id in ids {
            let (rtx, rrx) = channel();
            requests.push(InferRequest {
                id,
                trace: 0,
                features: Features::Owned(vec![id as f32; n]),
                enqueued_at: Instant::now(),
                deadline: None,
                reply: Reply::Channel(rtx),
            });
            rxs.push(rrx);
        }
        tx.send(FormedBatch {
            bucket,
            requests,
            formed_at: Instant::now(),
        })
        .unwrap();
        rxs
    }

    #[test]
    fn pool_executes_and_replies_per_request() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(DoubleExecutor { n: 3 }) as Box<dyn BatchExecutor>));
        let pool = WorkerPool::spawn(2, factory, brx, Arc::clone(&metrics), None);
        let rxs = submit(&btx, &[1, 2, 3], 4, 3);
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            let want = vec![(i as f32 + 1.0) * 2.0; 3];
            assert_eq!(resp.output.unwrap(), want);
            assert_eq!(resp.batch_size, 4);
        }
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("worker.batches").get(), 1);
        assert_eq!(metrics.counter("worker.rows").get(), 3);
        assert_eq!(metrics.counter("worker.padded_rows").get(), 1);
    }

    #[test]
    fn factory_failure_degrades_loudly() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory = Arc::new(|| Err("no artifacts".to_string()));
        let pool = WorkerPool::spawn(1, factory, brx, Arc::clone(&metrics), None);
        let rxs = submit(&btx, &[9], 1, 2);
        let resp = rxs[0].recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.output.unwrap_err().contains("no artifacts"));
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("worker.errors").get(), 1);
    }

    #[test]
    fn slot_requests_complete_through_the_arena() {
        use crate::coordinator::request::{ResponseSlot, RowRef};
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let (rec_tx, rec_rx) = std::sync::mpsc::sync_channel(2);
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(DoubleExecutor { n: 2 }) as Box<dyn BatchExecutor>));
        let pool = WorkerPool::spawn(1, factory, brx, Arc::clone(&metrics), Some(rec_tx));
        let slot = Arc::new(ResponseSlot::new());
        let input = vec![3.0f32, 4.0];
        let mut output = vec![0.0f32; 2];
        let seq = slot.issue();
        // SAFETY: input/output outlive the wait below.
        let row = unsafe { RowRef::new(input.as_ptr(), 2, output.as_mut_ptr(), 2, seq) };
        btx.send(FormedBatch {
            bucket: 1,
            requests: vec![InferRequest {
                id: 7,
                trace: 0,
                features: Features::Borrowed(row),
                enqueued_at: Instant::now(),
                deadline: None,
                reply: Reply::Slot(Arc::clone(&slot)),
            }],
            formed_at: Instant::now(),
        })
        .unwrap();
        let reply = slot
            .wait(seq, Instant::now() + Duration::from_secs(2))
            .expect("slot answered");
        assert_eq!(reply.output.unwrap(), 2);
        assert_eq!(reply.batch_size, 1);
        assert_eq!(output, vec![6.0, 8.0]);
        // The emptied request buffer came back for recycling.
        let recycled = rec_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(recycled.is_empty());
        drop(btx);
        pool.join();
    }

    #[test]
    fn native_cascade_executor_matches_direct_forward() {
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let cascade = crate::sell::acdc::AcdcCascade::nonlinear(
            16,
            3,
            crate::sell::init::DiagInit::CAFFENET,
            &mut rng,
        );
        let mut exe = NativeCascadeExecutor::new(cascade.clone());
        let x = crate::tensor::Tensor::from_vec(&[4, 16], rng.normal_vec(64, 0.0, 1.0));
        let mut out = vec![0.0f32; 64];
        exe.execute_into(4, x.data(), &mut out).unwrap();
        let want = cascade.forward(&x);
        assert_eq!(out, want.data());
    }

    #[test]
    fn expired_rows_reaped_before_execute_and_stale_batch_dropped() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(DoubleExecutor { n: 2 }) as Box<dyn BatchExecutor>));
        let pool = WorkerPool::spawn(1, factory, brx, Arc::clone(&metrics), None);
        // A batch whose every row expired between formation and execute.
        let past = Instant::now() - Duration::from_millis(5);
        let mut rxs = vec![];
        let mut requests = vec![];
        for id in 0..2u64 {
            let (rtx, rrx) = channel();
            requests.push(InferRequest {
                id,
                trace: 0,
                features: Features::Owned(vec![1.0; 2]),
                enqueued_at: past,
                deadline: Some(past),
                reply: Reply::Channel(rtx),
            });
            rxs.push(rrx);
        }
        btx.send(FormedBatch {
            bucket: 2,
            requests,
            formed_at: past,
        })
        .unwrap();
        for rx in &rxs {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert!(resp.output.unwrap_err().contains("deadline"));
        }
        // A live batch afterwards still executes normally.
        let live_rxs = submit(&btx, &[5], 1, 2);
        let resp = live_rxs[0].recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(resp.output.unwrap(), vec![10.0, 10.0]);
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("gateway.deadline_reaped").get(), 2);
        assert_eq!(metrics.counter("worker.batches_dropped").get(), 1);
        // Only the live row was counted as executed work.
        assert_eq!(metrics.counter("worker.rows").get(), 1);
    }

    #[test]
    fn multiple_batches_across_workers() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(DoubleExecutor { n: 2 }) as Box<dyn BatchExecutor>));
        let pool = WorkerPool::spawn(3, factory, brx, Arc::clone(&metrics), None);
        let mut all = vec![];
        for b in 0..10u64 {
            all.extend(submit(&btx, &[b * 10, b * 10 + 1], 2, 2));
        }
        for rx in &all {
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("worker.batches").get(), 10);
    }
}
