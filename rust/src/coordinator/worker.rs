//! Worker pool executing formed batches.
//!
//! PJRT objects are not `Send` (raw C pointers), so each worker thread
//! constructs its *own* executor via a factory closure invoked on the
//! worker's thread — channels only ever carry plain data. This is the
//! one-client-per-worker pattern; with the CPU plugin each client shares
//! the host's cores, and the pool size bounds concurrent executions.

use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::request::{FormedBatch, InferResponse};
use crate::metrics::Registry;

/// Executes one padded batch: input is the padded [bucket, n] row-major
/// feature buffer; output must be `bucket` rows of model output.
pub trait BatchExecutor {
    /// Model input width N.
    fn width(&self) -> usize;
    /// Output width per row.
    fn out_width(&self) -> usize;
    /// Run the bucket-sized program.
    fn execute(&mut self, bucket: usize, padded: &[f32]) -> Result<Vec<f32>, String>;
}

/// Factory invoked on each worker thread to build its thread-local
/// executor (PJRT clients are not Send, so construction happens in-thread).
pub type ExecutorFactory = Arc<dyn Fn() -> Result<Box<dyn BatchExecutor>, String> + Send + Sync>;

/// Pool of worker threads draining a shared batch channel.
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers. Each calls `factory()` locally; a factory error
    /// makes the worker answer every batch with that error (the system
    /// degrades loudly rather than hanging).
    pub fn spawn(
        n: usize,
        factory: ExecutorFactory,
        rx: Receiver<FormedBatch>,
        metrics: Arc<Registry>,
    ) -> WorkerPool {
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n.max(1))
            .map(|wi| {
                let rx = Arc::clone(&rx);
                let factory = Arc::clone(&factory);
                let metrics = Arc::clone(&metrics);
                std::thread::Builder::new()
                    .name(format!("acdc-serve-{wi}"))
                    .spawn(move || worker_loop(factory, rx, metrics))
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Block until all workers exit (the batch channel must be closed).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    factory: ExecutorFactory,
    rx: Arc<Mutex<Receiver<FormedBatch>>>,
    metrics: Arc<Registry>,
) {
    let mut executor = factory();
    let batches = metrics.counter("worker.batches");
    let rows = metrics.counter("worker.rows");
    let padded_rows = metrics.counter("worker.padded_rows");
    let errors = metrics.counter("worker.errors");
    let exec_hist = metrics.histogram("worker.execute_ns");
    let queue_hist = metrics.histogram("worker.queue_wait_ns");
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        batches.inc();
        rows.add(batch.requests.len() as u64);
        padded_rows.add((batch.bucket - batch.requests.len()) as u64);

        let t0 = Instant::now();
        let result: Result<Vec<f32>, String> = match &mut executor {
            Ok(exe) => {
                let n = exe.width();
                let padded = batch.padded_features(n);
                exe.execute(batch.bucket, &padded)
            }
            Err(e) => Err(format!("executor init failed: {e}")),
        };
        let execute_us = t0.elapsed().as_micros() as u64;
        exec_hist.record_ns(t0.elapsed().as_nanos() as u64);
        if result.is_err() {
            errors.inc();
        }

        let out_w = executor.as_ref().map(|e| e.out_width()).unwrap_or(0);
        for (i, req) in batch.requests.iter().enumerate() {
            let queue_us = batch
                .formed_at
                .saturating_duration_since(req.enqueued_at)
                .as_micros() as u64;
            queue_hist.record_ns(queue_us * 1_000);
            let output = match &result {
                Ok(all) => {
                    let start = i * out_w;
                    if start + out_w <= all.len() {
                        Ok(all[start..start + out_w].to_vec())
                    } else {
                        Err("executor returned short output".to_string())
                    }
                }
                Err(e) => Err(e.clone()),
            };
            let _ = req.reply.send(InferResponse {
                id: req.id,
                output,
                queue_us,
                execute_us,
                batch_size: batch.bucket,
            });
        }
    }
}

/// A pure-rust executor over the reference SELL cascade — used by tests
/// and as a PJRT-free fallback path (`--native` serving mode).
///
/// Buckets run through the batched SoA ACDC engine
/// ([`crate::dct::batch`]); large buckets additionally fan panels out
/// across the process-wide [`crate::util::threadpool::global`] pool, so
/// every serving worker shares one set of compute threads.
pub struct NativeCascadeExecutor {
    /// The cascade evaluated for each batch (cheap to clone per worker —
    /// all layers share one cached plan).
    pub cascade: crate::sell::acdc::AcdcCascade,
}

impl BatchExecutor for NativeCascadeExecutor {
    fn width(&self) -> usize {
        self.cascade.n()
    }

    fn out_width(&self) -> usize {
        self.cascade.n()
    }

    fn execute(&mut self, bucket: usize, padded: &[f32]) -> Result<Vec<f32>, String> {
        let n = self.width();
        if padded.len() != bucket * n {
            return Err(format!(
                "padded buffer {} != bucket {bucket} × n {n}",
                padded.len()
            ));
        }
        let x = crate::tensor::Tensor::from_vec(&[bucket, n], padded.to_vec());
        // Large buckets amortize pool dispatch; small ones stay serial.
        if bucket >= 32 {
            let pool = crate::util::threadpool::global();
            Ok(self.cascade.forward_pooled(&x, pool).into_vec())
        } else {
            Ok(self.cascade.forward(&x).into_vec())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferRequest;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    struct DoubleExecutor {
        n: usize,
    }

    impl BatchExecutor for DoubleExecutor {
        fn width(&self) -> usize {
            self.n
        }
        fn out_width(&self) -> usize {
            self.n
        }
        fn execute(&mut self, bucket: usize, padded: &[f32]) -> Result<Vec<f32>, String> {
            assert_eq!(padded.len(), bucket * self.n);
            Ok(padded.iter().map(|v| v * 2.0).collect())
        }
    }

    fn submit(
        tx: &std::sync::mpsc::Sender<FormedBatch>,
        ids: &[u64],
        bucket: usize,
        n: usize,
    ) -> Vec<std::sync::mpsc::Receiver<InferResponse>> {
        let mut rxs = vec![];
        let mut requests = vec![];
        for &id in ids {
            let (rtx, rrx) = channel();
            requests.push(InferRequest {
                id,
                features: vec![id as f32; n],
                enqueued_at: Instant::now(),
                reply: rtx,
            });
            rxs.push(rrx);
        }
        tx.send(FormedBatch {
            bucket,
            requests,
            formed_at: Instant::now(),
        })
        .unwrap();
        rxs
    }

    #[test]
    fn pool_executes_and_replies_per_request() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(DoubleExecutor { n: 3 }) as Box<dyn BatchExecutor>));
        let pool = WorkerPool::spawn(2, factory, brx, Arc::clone(&metrics));
        let rxs = submit(&btx, &[1, 2, 3], 4, 3);
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            let want = vec![(i as f32 + 1.0) * 2.0; 3];
            assert_eq!(resp.output.unwrap(), want);
            assert_eq!(resp.batch_size, 4);
        }
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("worker.batches").get(), 1);
        assert_eq!(metrics.counter("worker.rows").get(), 3);
        assert_eq!(metrics.counter("worker.padded_rows").get(), 1);
    }

    #[test]
    fn factory_failure_degrades_loudly() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory = Arc::new(|| Err("no artifacts".to_string()));
        let pool = WorkerPool::spawn(1, factory, brx, Arc::clone(&metrics));
        let rxs = submit(&btx, &[9], 1, 2);
        let resp = rxs[0].recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.output.unwrap_err().contains("no artifacts"));
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("worker.errors").get(), 1);
    }

    #[test]
    fn native_cascade_executor_matches_direct_forward() {
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let cascade = crate::sell::acdc::AcdcCascade::nonlinear(
            16,
            3,
            crate::sell::init::DiagInit::CAFFENET,
            &mut rng,
        );
        let mut exe = NativeCascadeExecutor {
            cascade: cascade.clone(),
        };
        let x = crate::tensor::Tensor::from_vec(&[4, 16], rng.normal_vec(64, 0.0, 1.0));
        let out = exe.execute(4, x.data()).unwrap();
        let want = cascade.forward(&x);
        assert_eq!(out, want.data());
    }

    #[test]
    fn multiple_batches_across_workers() {
        let (btx, brx) = channel();
        let metrics = Arc::new(Registry::new());
        let factory: ExecutorFactory =
            Arc::new(|| Ok(Box::new(DoubleExecutor { n: 2 }) as Box<dyn BatchExecutor>));
        let pool = WorkerPool::spawn(3, factory, brx, Arc::clone(&metrics));
        let mut all = vec![];
        for b in 0..10u64 {
            all.extend(submit(&btx, &[b * 10, b * 10 + 1], 2, 2));
        }
        for rx in &all {
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        drop(btx);
        pool.join();
        assert_eq!(metrics.counter("worker.batches").get(), 10);
    }
}
