//! Size-bucketed dynamic batcher with deadline flush.
//!
//! Policy (vLLM-style continuous batching, specialized to fixed AOT batch
//! buckets — the XLA programs are compiled for static shapes, so the
//! batcher picks which compiled bucket to dispatch):
//!
//! * accumulate requests in arrival order;
//! * when the queue can fill the **largest** bucket, dispatch immediately;
//! * when the **oldest** request has waited ≥ `max_wait`, dispatch the
//!   smallest bucket ≥ queue length (padding the remainder) — bounded
//!   tail latency at the cost of padding waste;
//! * otherwise keep waiting.
//!
//! Pure decision logic lives in [`BatchPolicy`] (unit-testable without
//! threads); [`run_batcher`] wires it to channels.

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::request::{FormedBatch, InferRequest};
use crate::metrics::{Counter, Gauge};

/// Pure batch-formation policy over sorted buckets.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Ascending batch sizes with compiled executables.
    pub buckets: Vec<usize>,
    /// Deadline: max time the oldest request may wait.
    pub max_wait: Duration,
}

/// What the policy decides for the current queue state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Dispatch now with this bucket size.
    Dispatch {
        /// Compiled bucket to execute (rows padded up to this).
        bucket: usize,
        /// How many queued requests to take.
        take: usize,
    },
    /// Wait at most this long for more arrivals.
    Wait(Duration),
}

impl BatchPolicy {
    /// Policy over the given buckets (sorted/deduped) and deadline.
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!buckets.is_empty());
        buckets.sort_unstable();
        buckets.dedup();
        BatchPolicy { buckets, max_wait }
    }

    /// The largest compiled bucket.
    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits `m` rows (None if m == 0).
    pub fn bucket_for(&self, m: usize) -> Option<usize> {
        if m == 0 {
            return None;
        }
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= m)
            .or_else(|| Some(self.max_bucket()))
    }

    /// Decide given queue length and the oldest request's enqueue time.
    pub fn decide(&self, queue_len: usize, oldest: Option<Instant>, now: Instant) -> Decision {
        if queue_len == 0 {
            return Decision::Wait(self.max_wait);
        }
        let max_b = self.max_bucket();
        if queue_len >= max_b {
            return Decision::Dispatch {
                bucket: max_b,
                take: max_b,
            };
        }
        let oldest = oldest.expect("non-empty queue must have oldest");
        let waited = now.saturating_duration_since(oldest);
        if waited >= self.max_wait {
            let bucket = self.bucket_for(queue_len).unwrap();
            return Decision::Dispatch {
                bucket,
                take: queue_len.min(bucket),
            };
        }
        Decision::Wait(self.max_wait - waited)
    }
}

/// The batcher loop: drains a request channel, forms batches, forwards
/// them to the bounded worker channel (blocking there when every worker
/// is busy, which propagates backpressure to the request queue). Returns
/// when the request channel closes (flushing any remainder).
///
/// `recycle` receives emptied `requests` vectors back from the workers
/// (a bounded array channel, so the handoff itself never allocates);
/// steady-state batch formation therefore reuses a fixed pool of buffers
/// instead of allocating one `Vec` per formed batch.
///
/// `depth` (when present) is kept at the batcher's live queue length —
/// the `coordinator.queue_depth` series on `/metrics`, the direct
/// observable for "is latency queueing or compute".
///
/// `reaped` (when present) counts requests whose deadline passed while
/// they were still queued: at every batch-formation pass the queue is
/// swept and expired requests are answered with the typed
/// deadline-exceeded outcome ([`InferRequest::reap`]) instead of being
/// dispatched — past saturation, no cycle is spent on work nobody is
/// waiting for. The counter is the shared `gateway.deadline_reaped`
/// series.
pub fn run_batcher(
    policy: BatchPolicy,
    rx: Receiver<InferRequest>,
    tx: SyncSender<FormedBatch>,
    recycle: Receiver<Vec<InferRequest>>,
    depth: Option<Arc<Gauge>>,
    reaped: Option<Arc<Counter>>,
) {
    let mut queue: Vec<InferRequest> = Vec::new();
    let set_depth = |len: usize| {
        if let Some(g) = &depth {
            g.set(len as u64);
        }
    };
    let mut form = |queue: &mut Vec<InferRequest>, bucket: usize, take: usize, now: Instant| {
        let mut requests = recycle.try_recv().unwrap_or_default();
        requests.clear();
        requests.extend(queue.drain(..take));
        FormedBatch {
            bucket,
            requests,
            formed_at: now,
        }
    };
    loop {
        let now = Instant::now();
        if reap_expired(&mut queue, now, reaped.as_deref()) > 0 {
            set_depth(queue.len());
        }
        let decision = policy.decide(queue.len(), queue.first().map(|r| r.enqueued_at), now);
        match decision {
            Decision::Dispatch { bucket, take } => {
                let batch = form(&mut queue, bucket, take, now);
                set_depth(queue.len());
                if tx.send(batch).is_err() {
                    return; // workers gone
                }
            }
            Decision::Wait(dur) => match rx.recv_timeout(dur) {
                Ok(req) => {
                    queue.push(req);
                    // opportunistically drain whatever else is ready
                    while queue.len() < policy.max_bucket() {
                        match rx.try_recv() {
                            Ok(r) => queue.push(r),
                            Err(_) => break,
                        }
                    }
                    set_depth(queue.len());
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    // flush remainder then exit
                    while !queue.is_empty() {
                        let take = queue.len().min(policy.max_bucket());
                        let bucket = policy.bucket_for(take).unwrap();
                        let batch = form(&mut queue, bucket, take, Instant::now());
                        set_depth(queue.len());
                        if tx.send(batch).is_err() {
                            return;
                        }
                    }
                    return;
                }
            },
        }
    }
}

/// Sweep `queue` for requests whose deadline has passed: each one is
/// answered with the typed deadline-exceeded outcome and removed (FIFO
/// order of the survivors is preserved). Returns how many were reaped.
/// Allocation-free — removal shifts in place within the queue's existing
/// buffer.
fn reap_expired(queue: &mut Vec<InferRequest>, now: Instant, reaped: Option<&Counter>) -> usize {
    let mut n = 0;
    let mut i = 0;
    while i < queue.len() {
        if queue[i].expired(now) {
            let req = queue.remove(i);
            req.reap(now);
            if let Some(c) = reaped {
                c.inc();
            }
            n += 1;
        } else {
            i += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::super::request::Reply;
    use super::*;
    use std::sync::mpsc::{channel, sync_channel};

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 8, 32, 128], Duration::from_millis(2))
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let p = policy();
        assert_eq!(p.bucket_for(1), Some(1));
        assert_eq!(p.bucket_for(2), Some(8));
        assert_eq!(p.bucket_for(8), Some(8));
        assert_eq!(p.bucket_for(9), Some(32));
        assert_eq!(p.bucket_for(200), Some(128)); // clamp to max
        assert_eq!(p.bucket_for(0), None);
    }

    #[test]
    fn decide_empty_queue_waits_full_deadline() {
        let p = policy();
        assert_eq!(
            p.decide(0, None, Instant::now()),
            Decision::Wait(p.max_wait)
        );
    }

    #[test]
    fn decide_full_queue_dispatches_max_bucket() {
        let p = policy();
        let d = p.decide(128, Some(Instant::now()), Instant::now());
        assert_eq!(
            d,
            Decision::Dispatch {
                bucket: 128,
                take: 128
            }
        );
        // over-full also dispatches exactly max bucket
        let d = p.decide(300, Some(Instant::now()), Instant::now());
        assert_eq!(
            d,
            Decision::Dispatch {
                bucket: 128,
                take: 128
            }
        );
    }

    #[test]
    fn decide_deadline_forces_partial_dispatch() {
        let p = policy();
        let old = Instant::now() - Duration::from_millis(10);
        let d = p.decide(3, Some(old), Instant::now());
        assert_eq!(d, Decision::Dispatch { bucket: 8, take: 3 });
    }

    #[test]
    fn decide_fresh_queue_waits_remaining() {
        let p = policy();
        let now = Instant::now();
        match p.decide(3, Some(now), now) {
            Decision::Wait(d) => assert!(d <= p.max_wait),
            other => panic!("expected wait, got {other:?}"),
        }
    }

    #[test]
    fn buckets_sorted_and_deduped() {
        let p = BatchPolicy::new(vec![32, 1, 8, 8], Duration::from_millis(1));
        assert_eq!(p.buckets, vec![1, 8, 32]);
    }

    type RespRx = std::sync::mpsc::Receiver<super::super::request::InferResponse>;

    fn mk_req(id: u64) -> (InferRequest, RespRx) {
        let (tx, rx) = channel();
        (
            InferRequest {
                id,
                trace: 0,
                features: super::super::request::Features::Owned(vec![0.0; 4]),
                enqueued_at: Instant::now(),
                deadline: None,
                reply: Reply::Channel(tx),
            },
            rx,
        )
    }

    #[test]
    fn batcher_thread_forms_deadline_batch() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = sync_channel(16);
        let (_rtx, rrx) = sync_channel(4);
        let p = BatchPolicy::new(vec![4, 16], Duration::from_millis(1));
        let handle = std::thread::spawn(move || run_batcher(p, req_rx, batch_tx, rrx, None, None));
        let mut keep = vec![];
        for id in 0..3 {
            let (r, rx) = mk_req(id);
            keep.push(rx);
            req_tx.send(r).unwrap();
        }
        let batch = batch_rx.recv_timeout(Duration::from_millis(200)).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.bucket, 4);
        drop(req_tx);
        handle.join().unwrap();
    }

    #[test]
    fn batcher_thread_flushes_on_close() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = sync_channel(16);
        let (_rtx, rrx) = sync_channel(4);
        let p = BatchPolicy::new(vec![4, 16], Duration::from_secs(60)); // never deadline
        let handle = std::thread::spawn(move || run_batcher(p, req_rx, batch_tx, rrx, None, None));
        let mut keep = vec![];
        for id in 0..6 {
            let (r, rx) = mk_req(id);
            keep.push(rx);
            req_tx.send(r).unwrap();
        }
        drop(req_tx); // close → flush
        let b1 = batch_rx.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(b1.requests.len(), 6);
        assert_eq!(b1.bucket, 16);
        handle.join().unwrap();
    }

    #[test]
    fn batcher_thread_dispatches_immediately_when_full() {
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = sync_channel(16);
        let (rtx, rrx) = sync_channel(4);
        let p = BatchPolicy::new(vec![2], Duration::from_secs(60));
        let handle = std::thread::spawn(move || run_batcher(p, req_rx, batch_tx, rrx, None, None));
        // A recycled buffer round-trips back into batch formation.
        rtx.send(Vec::with_capacity(2)).unwrap();
        let mut keep = vec![];
        for id in 0..4 {
            let (r, rx) = mk_req(id);
            keep.push(rx);
            req_tx.send(r).unwrap();
        }
        let b1 = batch_rx.recv_timeout(Duration::from_millis(500)).unwrap();
        let b2 = batch_rx.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(b1.requests.len(), 2);
        assert_eq!(b2.requests.len(), 2);
        drop(req_tx);
        handle.join().unwrap();
    }

    #[test]
    fn expired_requests_reaped_at_formation_not_dispatched() {
        let reaped = Arc::new(Counter::default());
        let (req_tx, req_rx) = channel();
        let (batch_tx, batch_rx) = sync_channel(16);
        let (_rtx, rrx) = sync_channel(4);
        let p = BatchPolicy::new(vec![4], Duration::from_millis(1));
        let c = Arc::clone(&reaped);
        let handle =
            std::thread::spawn(move || run_batcher(p, req_rx, batch_tx, rrx, None, Some(c)));
        // Two requests whose deadline already passed, one fresh one.
        let mut keep = vec![];
        for id in 0..2 {
            let (mut r, rx) = mk_req(id);
            r.deadline = Some(Instant::now() - Duration::from_millis(5));
            keep.push(rx);
            req_tx.send(r).unwrap();
        }
        let (mut live, live_rx) = mk_req(9);
        live.deadline = Some(Instant::now() + Duration::from_secs(60));
        keep.push(live_rx);
        req_tx.send(live).unwrap();
        // The dispatched batch holds only the live request.
        let batch = batch_rx.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.requests[0].id, 9);
        assert_eq!(reaped.get(), 2);
        // Reaped requests were answered with the deadline error.
        for rx in &keep[..2] {
            let resp = rx.recv_timeout(Duration::from_millis(500)).unwrap();
            assert!(resp.output.unwrap_err().contains("deadline"));
        }
        drop(req_tx);
        handle.join().unwrap();
    }
}
