//! Deterministic executor fault injection for the chaos suite.
//!
//! [`FaultInjector`] wraps any [`BatchExecutor`] and, per executed batch,
//! draws from a seeded SplitMix64 stream to decide whether to inject a
//! delay, a long stall, or an error before delegating to the inner
//! executor. The stream is the whole point: a chaos test that sets
//! `delay_prob = 1.0` gets the fault on *every* batch, and a partial
//! probability replays identically under the same seed — no wall-clock
//! races deciding whether the test exercised anything.
//!
//! Activation is config-driven (`[faults]`, see
//! [`crate::config::FaultsConfig`]) with an `ACDC_FAULTS` environment
//! override, applied in [`crate::coordinator::Coordinator::start`] via
//! [`wrap_factory`]. Each worker thread builds its own injector whose
//! stream is derived from the base seed and a per-instance index, so the
//! decision sequence is reproducible per worker regardless of how the OS
//! schedules them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::worker::{BatchExecutor, ExecutorFactory};
use crate::config::FaultsConfig;

/// A SplitMix64 stream (Steele et al.) — the same finalizer the trace-ID
/// and ring-hash code uses, run as a sequential generator here.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Stream seeded with `seed`.
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next draw as a uniform f64 in `[0, 1)` (53-bit mantissa).
    pub fn next_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Executor wrapper injecting seeded delay/stall/error faults per batch.
pub struct FaultInjector {
    inner: Box<dyn BatchExecutor>,
    cfg: FaultsConfig,
    rng: SplitMix64,
}

impl FaultInjector {
    /// Wrap `inner`, drawing decisions from a stream seeded by the config
    /// seed XOR an instance discriminator (one per worker).
    pub fn new(inner: Box<dyn BatchExecutor>, cfg: FaultsConfig, instance: u64) -> FaultInjector {
        // Spread instances across the stream space; the odd multiplier is
        // the SplitMix64 increment, guaranteeing distinct seeds per worker.
        let seed = cfg
            .seed
            .wrapping_add(instance.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        FaultInjector {
            inner,
            cfg,
            rng: SplitMix64::new(seed),
        }
    }
}

impl BatchExecutor for FaultInjector {
    fn width(&self) -> usize {
        self.inner.width()
    }

    fn out_width(&self) -> usize {
        self.inner.out_width()
    }

    fn execute_into(
        &mut self,
        bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        // Fixed draw order (delay, stall, error) keeps the stream
        // deterministic regardless of which probabilities are set.
        let delay = self.rng.next_unit() < self.cfg.delay_prob;
        let stall = self.rng.next_unit() < self.cfg.stall_prob;
        let error = self.rng.next_unit() < self.cfg.error_prob;
        if delay && self.cfg.delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.cfg.delay_ms));
        }
        if stall && self.cfg.stall_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.cfg.stall_ms));
        }
        if error {
            return Err("injected fault (faults.error_prob)".to_string());
        }
        self.inner.execute_into(bucket, padded, out)
    }
}

/// Wrap an [`ExecutorFactory`] so every executor it builds carries a
/// [`FaultInjector`] with its own per-worker decision stream.
pub fn wrap_factory(inner: ExecutorFactory, cfg: FaultsConfig) -> ExecutorFactory {
    let instance = Arc::new(AtomicU64::new(0));
    Arc::new(move || {
        let exe = inner()?;
        let i = instance.fetch_add(1, Ordering::Relaxed);
        Ok(Box::new(FaultInjector::new(exe, cfg.clone(), i)) as Box<dyn BatchExecutor>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    struct EchoExecutor {
        n: usize,
    }

    impl BatchExecutor for EchoExecutor {
        fn width(&self) -> usize {
            self.n
        }
        fn out_width(&self) -> usize {
            self.n
        }
        fn execute_into(
            &mut self,
            _bucket: usize,
            padded: &[f32],
            out: &mut [f32],
        ) -> Result<(), String> {
            out.copy_from_slice(padded);
            Ok(())
        }
    }

    #[test]
    fn splitmix_stream_is_deterministic_and_uniformish() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let u = a.next_unit();
            assert_eq!(u, b.next_unit(), "same seed → same stream");
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean} far from uniform");
        assert_ne!(
            SplitMix64::new(1).next_u64(),
            SplitMix64::new(2).next_u64(),
            "different seeds diverge"
        );
    }

    #[test]
    fn error_prob_one_fails_every_batch() {
        let cfg = FaultsConfig {
            enabled: true,
            error_prob: 1.0,
            ..Default::default()
        };
        let mut inj = FaultInjector::new(Box::new(EchoExecutor { n: 2 }), cfg, 0);
        let mut out = [0.0f32; 2];
        for _ in 0..5 {
            let err = inj.execute_into(1, &[1.0, 2.0], &mut out).unwrap_err();
            assert!(err.contains("injected"));
        }
    }

    #[test]
    fn delay_prob_one_delays_and_still_computes() {
        let cfg = FaultsConfig {
            enabled: true,
            delay_ms: 30,
            delay_prob: 1.0,
            ..Default::default()
        };
        let mut inj = FaultInjector::new(Box::new(EchoExecutor { n: 2 }), cfg, 0);
        let mut out = [0.0f32; 2];
        let t0 = Instant::now();
        inj.execute_into(1, &[3.0, 4.0], &mut out).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(out, [3.0, 4.0]);
    }

    #[test]
    fn zero_probs_inject_nothing() {
        let cfg = FaultsConfig {
            enabled: true,
            ..Default::default()
        };
        let mut inj = FaultInjector::new(Box::new(EchoExecutor { n: 1 }), cfg, 3);
        let mut out = [0.0f32; 1];
        for _ in 0..100 {
            inj.execute_into(1, &[7.0], &mut out).unwrap();
            assert_eq!(out, [7.0]);
        }
    }

    #[test]
    fn wrapped_factory_gives_each_worker_its_own_stream() {
        let inner: ExecutorFactory =
            Arc::new(|| Ok(Box::new(EchoExecutor { n: 1 }) as Box<dyn BatchExecutor>));
        let cfg = FaultsConfig {
            enabled: true,
            error_prob: 0.5,
            ..Default::default()
        };
        let wrapped = wrap_factory(inner, cfg);
        let mut a = wrapped().unwrap();
        let mut b = wrapped().unwrap();
        // Streams differ per instance; over many draws the outcome
        // sequences must not be identical.
        let mut out = [0.0f32; 1];
        let seq = |exe: &mut Box<dyn BatchExecutor>, out: &mut [f32; 1]| {
            (0..64)
                .map(|_| exe.execute_into(1, &[1.0], out).is_ok())
                .collect::<Vec<_>>()
        };
        assert_ne!(seq(&mut a, &mut out), seq(&mut b, &mut out));
    }
}
