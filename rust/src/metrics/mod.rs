//! Serving/training metrics: counters, gauges, latency histograms.
//!
//! Lock-free counters (atomics) plus a log-bucketed latency histogram with
//! percentile queries — the minimal telemetry a serving coordinator needs.
//! A `Registry` aggregates named instruments and renders a text report.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` to the count.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current count.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-value gauge. Besides `set`, supports atomic inc/dec so callers
/// can use it as a live occupancy meter (in-flight requests, open
/// connections) whose reading doubles as an admission-control input.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// Overwrite the gauge value.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Atomically increment; returns the new value.
    pub fn inc(&self) -> u64 {
        self.value.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Atomically decrement (saturating at 0); returns the new value.
    pub fn dec(&self) -> u64 {
        let mut cur = self.value.load(Ordering::Acquire);
        loop {
            let next = cur.saturating_sub(1);
            match self.value.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return next,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Last-value gauge holding a float (bits in an `AtomicU64`) — loss,
/// learning-rate and other non-integer series the trainer exports.
#[derive(Debug)]
pub struct FloatGauge {
    bits: AtomicU64,
}

impl Default for FloatGauge {
    fn default() -> Self {
        FloatGauge {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }
}

impl FloatGauge {
    /// Overwrite the gauge value.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log₂-bucketed histogram for nanosecond latencies.
///
/// 64 buckets: bucket i counts samples with floor(log2(ns)) == i. Bounded
/// relative error (~2×) is plenty for p50/p99 reporting; recording is one
/// atomic increment.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration sample.
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Record one sample, in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        let bucket = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Consistent point-in-time copy of the bucket counts (plus sum and
    /// max). Every read-side query goes through one snapshot: loading
    /// each bucket lazily while writers keep recording would let the
    /// cumulative walk see a total that never matches the per-bucket sum
    /// (torn-read drift), so the rank targets and the rendered series
    /// must all be derived from the same copy.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; 64];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }

    /// Mean sample in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        self.snapshot().mean_ns()
    }

    /// Largest sample seen, in nanoseconds.
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Sum of all samples, in nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Approximate percentile (upper bucket bound at the target rank).
    pub fn percentile_ns(&self, pct: f64) -> u64 {
        self.snapshot().percentile_ns(pct)
    }
}

/// Point-in-time copy of a [`Histogram`]'s state. The count is derived
/// from the bucket copy itself, so percentile ranks computed from a
/// snapshot are always consistent with its cumulative bucket counts —
/// concurrent `record` calls between bucket loads cannot skew them.
#[derive(Debug, Clone, Copy)]
pub struct HistSnapshot {
    /// Bucket i counts samples with `floor(log2(ns)) == i`.
    pub buckets: [u64; 64],
    /// Sum of all samples, in nanoseconds.
    pub sum_ns: u64,
    /// Largest sample seen, in nanoseconds.
    pub max_ns: u64,
}

impl HistSnapshot {
    /// Total samples in the snapshot (sum of the bucket copy).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Mean sample in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / c as f64
    }

    /// Approximate percentile (upper bucket bound at the target rank).
    pub fn percentile_ns(&self, pct: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((pct / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return upper_bound(i);
            }
        }
        self.max_ns
    }
}

/// Upper bound (exclusive) of log₂ bucket `i`, saturating at `u64::MAX`
/// for the top bucket.
fn upper_bound(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        1u64 << (i + 1)
    }
}

/// Compiled cargo features, comma-joined (`"default"` when none) — the
/// `features` label of `acdc_build_info`.
pub fn build_features() -> &'static str {
    match (cfg!(feature = "pjrt"), cfg!(feature = "count-allocs")) {
        (true, true) => "pjrt,count-allocs",
        (true, false) => "pjrt",
        (false, true) => "count-allocs",
        (false, false) => "default",
    }
}

/// Process start in Unix seconds, captured on first call (callers render
/// metrics early in startup, so this tracks actual process start closely
/// enough to correlate dashboards with deploys).
pub fn process_start_time_seconds() -> u64 {
    use std::sync::OnceLock;
    static START: OnceLock<u64> = OnceLock::new();
    *START.get_or_init(|| {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .as_secs()
    })
}

/// Named instrument registry with a text report.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    float_gauges: Mutex<BTreeMap<String, Arc<FloatGauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        // Pin the process-start stamp as early as the first registry, so
        // `process_start_time_seconds` reflects startup, not first render.
        process_start_time_seconds();
        Self::default()
    }

    /// Named counter (created on first use, shared thereafter).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Named gauge (created on first use, shared thereafter).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Named float gauge (created on first use, shared thereafter).
    pub fn float_gauge(&self, name: &str) -> Arc<FloatGauge> {
        Arc::clone(
            self.float_gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Named histogram (created on first use, shared thereafter).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Prometheus text exposition (served by the gateway's `GET /metrics`).
    ///
    /// Counters and gauges render as `acdc_<name> <value>`; histograms
    /// render twice: as summaries with `quantile` labels plus `_sum` and
    /// `_count` series (the original dashboards read these), and as true
    /// histogram exposition under `<name>_hist` — cumulative
    /// `_bucket{le="..."}` series over the log₂ bucket bounds ending at
    /// `le="+Inf"`, plus `_hist_sum`/`_hist_count`. Both views of one
    /// histogram are rendered from a single [`Histogram::snapshot`], so
    /// the `+Inf` bucket, `_count` and the quantile ranks always agree
    /// even under concurrent recording. Every histogram in this registry
    /// records nanoseconds and is named `*_ns`, so bounds, quantiles and
    /// `_sum` are all in nanoseconds. Names are sanitized to `[a-z0-9_]`
    /// so `worker.execute_ns` becomes `acdc_worker_execute_ns`.
    ///
    /// The exposition also carries two deploy-correlation series:
    /// `acdc_build_info` (crate version, compiled features, active SIMD
    /// dispatch arm as labels, value always 1) and
    /// `process_start_time_seconds`.
    pub fn prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut out = String::with_capacity(name.len() + 5);
            out.push_str("acdc_");
            for c in name.chars() {
                if c.is_ascii_alphanumeric() {
                    out.push(c.to_ascii_lowercase());
                } else {
                    out.push('_');
                }
            }
            out
        }
        let mut out = String::new();
        out.push_str(&format!(
            "# TYPE acdc_build_info gauge\nacdc_build_info{{version=\"{}\",features=\"{}\",simd=\"{}\"}} 1\n",
            env!("CARGO_PKG_VERSION"),
            build_features(),
            crate::dct::simd::active().name(),
        ));
        out.push_str(&format!(
            "# TYPE process_start_time_seconds gauge\nprocess_start_time_seconds {}\n",
            process_start_time_seconds()
        ));
        for (name, c) in self.counters.lock().unwrap().iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
        }
        for (name, g) in self.float_gauges.lock().unwrap().iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let n = sanitize(name);
            let snap = h.snapshot();
            let total = snap.count();
            out.push_str(&format!("# TYPE {n} summary\n"));
            for (q, pct) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
                out.push_str(&format!(
                    "{n}{{quantile=\"{q}\"}} {}\n",
                    snap.percentile_ns(pct)
                ));
            }
            out.push_str(&format!("{n}_sum {}\n", snap.sum_ns));
            out.push_str(&format!("{n}_count {total}\n"));
            // True histogram exposition over the same snapshot. Buckets
            // are cumulative and rendered up to the highest non-empty
            // log₂ bucket; `+Inf` always equals `_count`.
            out.push_str(&format!("# TYPE {n}_hist histogram\n"));
            let top = snap
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .map(|i| i + 1)
                .unwrap_or(0);
            let mut cum = 0u64;
            for i in 0..top {
                cum += snap.buckets[i];
                out.push_str(&format!(
                    "{n}_hist_bucket{{le=\"{}\"}} {cum}\n",
                    upper_bound(i)
                ));
            }
            out.push_str(&format!("{n}_hist_bucket{{le=\"+Inf\"}} {total}\n"));
            out.push_str(&format!("{n}_hist_sum {}\n", snap.sum_ns));
            out.push_str(&format!("{n}_hist_count {total}\n"));
        }
        out
    }

    /// Multi-line `name value` report (sorted, stable).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, g) in self.float_gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist {name} count={} mean={:.1}µs p50={:.1}µs p99={:.1}µs max={:.1}µs\n",
                h.count(),
                h.mean_ns() / 1e3,
                h.percentile_ns(50.0) as f64 / 1e3,
                h.percentile_ns(99.0) as f64 / 1e3,
                h.max_ns() as f64 / 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(5);
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn gauge_overwrites() {
        let g = Gauge::default();
        g.set(10);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn float_gauge_holds_floats() {
        let g = FloatGauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(1.25e-3);
        assert_eq!(g.get(), 1.25e-3);
        g.set(-7.5);
        assert_eq!(g.get(), -7.5);
    }

    #[test]
    fn float_gauge_in_registry_and_expositions() {
        let r = Registry::new();
        r.float_gauge("trainer.m.loss").set(0.125);
        r.float_gauge("trainer.m.loss").set(0.5);
        assert_eq!(r.float_gauge("trainer.m.loss").get(), 0.5);
        let text = r.prometheus();
        assert!(text.contains("# TYPE acdc_trainer_m_loss gauge"), "{text}");
        assert!(text.contains("acdc_trainer_m_loss 0.5"), "{text}");
        assert!(r.report().contains("gauge trainer.m.loss 0.5"));
    }

    #[test]
    fn gauge_inc_dec_saturating() {
        let g = Gauge::default();
        assert_eq!(g.inc(), 1);
        assert_eq!(g.inc(), 2);
        assert_eq!(g.dec(), 1);
        assert_eq!(g.dec(), 0);
        assert_eq!(g.dec(), 0, "dec must saturate at zero");
    }

    #[test]
    fn gauge_inc_dec_balanced_across_threads() {
        let g = Arc::new(Gauge::default());
        let mut handles = vec![];
        for _ in 0..8 {
            let g = Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    g.inc();
                    g.dec();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.counter("gateway.admitted").add(3);
        r.gauge("gateway.inflight").set(2);
        r.histogram("gateway.request_ns").record(Duration::from_micros(100));
        let text = r.prometheus();
        assert!(text.contains("# TYPE acdc_gateway_admitted counter"), "{text}");
        assert!(text.contains("acdc_gateway_admitted 3"), "{text}");
        assert!(text.contains("acdc_gateway_inflight 2"), "{text}");
        assert!(text.contains("acdc_gateway_request_ns{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("acdc_gateway_request_ns_count 1"), "{text}");
        assert!(text.contains("acdc_gateway_request_ns_sum"), "{text}");
    }

    #[test]
    fn histogram_percentiles_bounded() {
        let h = Histogram::new();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile_ns(50.0);
        // True p50 is 400; log-bucketed answer must be within 2×.
        assert!((256..=1024).contains(&p50), "p50={p50}");
        let p99 = h.percentile_ns(99.0);
        assert!(p99 >= 65_536, "p99={p99}");
    }

    #[test]
    fn prometheus_histogram_exposition_is_cumulative_and_consistent() {
        let r = Registry::new();
        let h = r.histogram("gateway.request_ns");
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.record_ns(ns);
        }
        let text = r.prometheus();
        assert!(
            text.contains("# TYPE acdc_gateway_request_ns_hist histogram"),
            "{text}"
        );
        assert!(
            text.contains("acdc_gateway_request_ns_hist_bucket{le=\"+Inf\"} 5"),
            "{text}"
        );
        assert!(text.contains("acdc_gateway_request_ns_hist_count 5"), "{text}");
        assert!(
            text.contains("acdc_gateway_request_ns_hist_sum 101500"),
            "{text}"
        );
        // Bucket series are cumulative and non-decreasing.
        let mut last = 0u64;
        let mut bucket_lines = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("acdc_gateway_request_ns_hist_bucket{le=\"") {
                let v: u64 = rest.split("} ").nth(1).unwrap().parse().unwrap();
                assert!(v >= last, "non-monotone bucket series: {line}");
                last = v;
                bucket_lines += 1;
            }
        }
        assert!(bucket_lines >= 2, "{text}");
        assert_eq!(last, 5, "+Inf bucket must equal count");
    }

    #[test]
    fn prometheus_build_info_and_start_time() {
        let r = Registry::new();
        let text = r.prometheus();
        assert!(text.contains("# TYPE acdc_build_info gauge"), "{text}");
        assert!(
            text.contains(&format!("version=\"{}\"", env!("CARGO_PKG_VERSION"))),
            "{text}"
        );
        assert!(text.contains("simd=\""), "{text}");
        assert!(text.contains("# TYPE process_start_time_seconds gauge"), "{text}");
        let start: u64 = text
            .lines()
            .find(|l| l.starts_with("process_start_time_seconds "))
            .unwrap()
            .split_whitespace()
            .nth(1)
            .unwrap()
            .parse()
            .unwrap();
        assert!(start > 1_600_000_000, "implausible start time {start}");
    }

    #[test]
    fn snapshot_count_matches_bucket_sum_and_top_bucket_saturates() {
        let h = Histogram::new();
        h.record_ns(u64::MAX); // lands in bucket 63
        h.record_ns(1);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 2);
        assert_eq!(snap.buckets.iter().sum::<u64>(), 2);
        // p99 rank falls in the top bucket whose upper bound saturates
        // instead of overflowing the shift.
        assert_eq!(snap.percentile_ns(99.0), u64::MAX);
    }

    #[test]
    fn histogram_mean_and_max_exact() {
        let h = Histogram::new();
        h.record_ns(1000);
        h.record_ns(3000);
        assert_eq!(h.mean_ns(), 2000.0);
        assert_eq!(h.max_ns(), 3000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_ns(99.0), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn registry_reuses_instruments() {
        let r = Registry::new();
        r.counter("req").inc();
        r.counter("req").inc();
        assert_eq!(r.counter("req").get(), 2);
    }

    #[test]
    fn registry_report_contains_all() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("b").set(7);
        r.histogram("lat").record(Duration::from_micros(50));
        let rep = r.report();
        assert!(rep.contains("counter a 1"));
        assert!(rep.contains("gauge b 7"));
        assert!(rep.contains("hist lat count=1"));
    }

    #[test]
    fn concurrent_recording() {
        let h = Arc::new(Histogram::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record_ns(i + 1);
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 8000);
    }
}
