//! Training: SGD machinery and the experiment orchestrators driving the
//! AOT train-step artifacts (Figure 3, Table 1 / E6) plus native
//! cross-check trainers.

pub mod orchestrator;
pub mod sgd;

pub use orchestrator::{CnnTrainer, CnnVariant, EvalResult, Fig3NativeTrainer, Fig3Trainer};
pub use sgd::{LossCurve, Momentum, StepDecay};
