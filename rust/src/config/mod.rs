//! Typed run configuration + a TOML-subset parser (serde/toml are not in
//! the offline registry).
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments. That is
//! exactly what the launcher's config files need.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat `[a, b, c]` array.
    Array(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer payload, if this is an `Int`.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The integer as usize, if non-negative.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }

    /// Numeric payload as f64 (ints widen).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean payload, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an `Array`.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone)]
pub struct ConfigError {
    /// What went wrong.
    pub msg: String,
    /// 1-based line number of the error.
    pub line: usize,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// Parsed config: `section.key -> value` (top-level keys use section "").
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError {
                msg: msg.to_string(),
                line: ln + 1,
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(err("empty section name"));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            cfg.values.insert(full_key, value);
        }
        Ok(cfg)
    }

    /// Read and parse a config file.
    pub fn from_file(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Config::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Raw value at `section.key` (top-level keys use the bare key).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// String value with a default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    /// usize value with a default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    /// f64 value with a default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    /// bool value with a default.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// All `section.key` names present, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<Value, String> {
    if tok.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = tok.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = tok.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|t| parse_value(t.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    if let Ok(i) = tok.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{tok}'"))
}

// ---------------------------------------------------------------------------
// Typed run configs
// ---------------------------------------------------------------------------

/// Network gateway configuration (`[gateway]` section): the admission
/// control and HTTP front-end in front of the serving coordinator.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Listen address, e.g. `"127.0.0.1:7878"` (port 0 = ephemeral).
    pub addr: String,
    /// Concurrent connection cap; excess connects get an immediate 503.
    pub max_open_conns: usize,
    /// Global in-flight request cap enforced by admission control.
    pub max_inflight: usize,
    /// Token-bucket refill rate in requests/second (0 disables the bucket).
    pub rate_rps: f64,
    /// Token-bucket capacity (burst allowance).
    pub rate_burst: f64,
    /// Per-request budget for the coordinator to answer, else 504.
    pub request_timeout_ms: u64,
    /// Graceful-shutdown bound on waiting for in-flight connections.
    pub drain_timeout_ms: u64,
    /// `Retry-After` seconds attached to 429/503 shed responses.
    pub retry_after_s: u64,
    /// Reject request bodies larger than this with 413.
    pub max_body_bytes: usize,
    /// Cap on feature rows in one `POST /v1/infer` batch request.
    pub max_rows_per_request: usize,
    /// Gateway I/O architecture: `"reactor"` (epoll event loops),
    /// `"threaded"` (thread-per-connection fallback), or `""`/`"auto"`
    /// (the `ACDC_GW_MODE` environment variable, defaulting to the
    /// reactor). See [`GatewayConfig::resolved_mode`].
    pub mode: String,
    /// Event-loop shard count in reactor mode (each shard owns an epoll
    /// instance and its parked connections).
    pub shards: usize,
    /// Dispatch-pool worker count in reactor mode: the bound on requests
    /// concurrently in the parse → infer → write pipeline.
    pub dispatch_threads: usize,
    /// Budget for a blocked response write before the connection is
    /// evicted — a peer that stops reading cannot wedge a worker (reactor
    /// mode polls `POLLOUT` against this; threaded mode sets it as the
    /// socket write timeout).
    pub write_stall_ms: u64,
    /// Tracing + logging knobs (`[trace]` section; carried here so every
    /// gateway constructor path sees them).
    pub trace: TraceConfig,
    /// Request-deadline limits (`[limits]` section; carried here so the
    /// admission edge can mint a deadline for every request).
    pub limits: LimitsConfig,
    /// Brownout-degradation knobs (`[brownout]` section).
    pub brownout: BrownoutConfig,
}

/// A resolved `gateway.mode` (see [`GatewayConfig::resolved_mode`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatewayMode {
    /// Epoll reactor: one acceptor, N event-loop shards, a bounded
    /// dispatch pool. The default.
    Reactor,
    /// Thread-per-connection fallback.
    Threaded,
}

impl GatewayMode {
    /// The config-file spelling of this mode.
    pub fn name(self) -> &'static str {
        match self {
            GatewayMode::Reactor => "reactor",
            GatewayMode::Threaded => "threaded",
        }
    }

    fn parse(s: &str) -> Option<GatewayMode> {
        match s {
            "reactor" => Some(GatewayMode::Reactor),
            "threaded" => Some(GatewayMode::Threaded),
            _ => None,
        }
    }
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:7878".into(),
            max_open_conns: 256,
            max_inflight: 1_024,
            rate_rps: 0.0,
            rate_burst: 256.0,
            request_timeout_ms: 5_000,
            drain_timeout_ms: 10_000,
            retry_after_s: 1,
            max_body_bytes: 4 << 20,
            max_rows_per_request: 128,
            mode: String::new(),
            shards: 4,
            dispatch_threads: 32,
            write_stall_ms: 5_000,
            trace: TraceConfig::default(),
            limits: LimitsConfig::default(),
            brownout: BrownoutConfig::default(),
        }
    }
}

impl GatewayConfig {
    /// Build from a parsed config's `[gateway]` section (defaults fill
    /// missing keys).
    pub fn from_config(cfg: &Config) -> Result<GatewayConfig, String> {
        let d = GatewayConfig::default();
        let gc = GatewayConfig {
            addr: cfg.get_str("gateway.addr", &d.addr),
            max_open_conns: cfg.get_usize("gateway.max_open_conns", d.max_open_conns),
            max_inflight: cfg.get_usize("gateway.max_inflight", d.max_inflight),
            rate_rps: cfg.get_f64("gateway.rate_rps", d.rate_rps),
            rate_burst: cfg.get_f64("gateway.rate_burst", d.rate_burst),
            request_timeout_ms: cfg
                .get_usize("gateway.request_timeout_ms", d.request_timeout_ms as usize)
                as u64,
            drain_timeout_ms: cfg
                .get_usize("gateway.drain_timeout_ms", d.drain_timeout_ms as usize)
                as u64,
            retry_after_s: cfg.get_usize("gateway.retry_after_s", d.retry_after_s as usize) as u64,
            max_body_bytes: cfg.get_usize("gateway.max_body_bytes", d.max_body_bytes),
            max_rows_per_request: cfg
                .get_usize("gateway.max_rows_per_request", d.max_rows_per_request),
            mode: cfg.get_str("gateway.mode", &d.mode),
            shards: cfg.get_usize("gateway.shards", d.shards),
            dispatch_threads: cfg.get_usize("gateway.dispatch_threads", d.dispatch_threads),
            write_stall_ms: cfg.get_usize("gateway.write_stall_ms", d.write_stall_ms as usize)
                as u64,
            trace: TraceConfig::from_config(cfg)?,
            limits: LimitsConfig::from_config(cfg)?,
            brownout: BrownoutConfig::from_config(cfg)?,
        };
        gc.validate()?;
        Ok(gc)
    }

    /// Sanity-check the knobs (caps ≥ 1, rates finite).
    pub fn validate(&self) -> Result<(), String> {
        if self.addr.is_empty() {
            return Err("gateway.addr must not be empty".into());
        }
        if self.max_open_conns == 0 {
            return Err("gateway.max_open_conns must be >= 1".into());
        }
        if self.max_inflight == 0 {
            return Err("gateway.max_inflight must be >= 1".into());
        }
        if !self.rate_rps.is_finite() || self.rate_rps < 0.0 {
            return Err("gateway.rate_rps must be finite and >= 0".into());
        }
        if self.rate_rps > 0.0 && (!self.rate_burst.is_finite() || self.rate_burst < 1.0) {
            return Err("gateway.rate_burst must be >= 1 when rate limiting is on".into());
        }
        if self.request_timeout_ms == 0 {
            return Err("gateway.request_timeout_ms must be >= 1".into());
        }
        if self.max_rows_per_request == 0 {
            return Err("gateway.max_rows_per_request must be >= 1".into());
        }
        let m = self.mode.trim();
        if !m.is_empty() && m != "auto" && GatewayMode::parse(m).is_none() {
            return Err("gateway.mode must be \"reactor\", \"threaded\" or \"auto\"".into());
        }
        if self.shards == 0 {
            return Err("gateway.shards must be >= 1".into());
        }
        if self.dispatch_threads == 0 {
            return Err("gateway.dispatch_threads must be >= 1".into());
        }
        if self.write_stall_ms == 0 {
            return Err("gateway.write_stall_ms must be >= 1".into());
        }
        self.trace.validate()?;
        self.limits.validate()?;
        self.brownout.validate()
    }

    /// Resolve the `mode` knob to an architecture: an explicit config
    /// value wins; `""`/`"auto"` defers to the `ACDC_GW_MODE` environment
    /// variable (so CI lanes can pin a mode fleet-wide without touching
    /// configs); anything else falls through to the reactor.
    pub fn resolved_mode(&self) -> GatewayMode {
        if let Some(m) = GatewayMode::parse(self.mode.trim()) {
            return m;
        }
        if let Ok(env) = std::env::var("ACDC_GW_MODE") {
            if let Some(m) = GatewayMode::parse(env.trim()) {
                return m;
            }
        }
        GatewayMode::Reactor
    }
}

/// Model registry configuration (`[registry]` section): which checkpoint
/// manifests the gateway preloads and where legacy `/v1/infer` routes.
#[derive(Debug, Clone, Default)]
pub struct RegistryConfig {
    /// Model (or alias) the legacy `/v1/infer` route resolves. Empty
    /// means "first model loaded".
    pub default_model: String,
    /// Checkpoint manifests loaded at startup, as `name=path` pairs.
    pub preload: Vec<(String, String)>,
}

impl RegistryConfig {
    /// Build from a parsed config's `[registry]` section. `models` is an
    /// array of `"name=path"` strings.
    pub fn from_config(cfg: &Config) -> Result<RegistryConfig, String> {
        let mut rc = RegistryConfig {
            default_model: cfg.get_str("registry.default_model", ""),
            preload: Vec::new(),
        };
        if let Some(v) = cfg.get("registry.models") {
            let arr = v
                .as_array()
                .ok_or("registry.models must be an array of \"name=path\" strings")?;
            for item in arr {
                let s = item
                    .as_str()
                    .ok_or("registry.models entries must be strings")?;
                let (name, path) = s
                    .split_once('=')
                    .ok_or_else(|| format!("registry.models entry '{s}' must be name=path"))?;
                if name.is_empty() || path.is_empty() {
                    return Err(format!("registry.models entry '{s}' must be name=path"));
                }
                rc.preload.push((name.to_string(), path.to_string()));
            }
        }
        Ok(rc)
    }
}

/// Training-job configuration (`[trainer]` section): the default knobs a
/// [`crate::trainer::TrainerPool`] applies to submitted jobs. Every field
/// can be overridden per job (HTTP body of `POST /v1/models/{name}/train`
/// or `acdc train` options).
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// SGD steps per job (jobs may finish earlier on convergence).
    pub steps: usize,
    /// Minibatch rows per step (batches never mix jobs — each job owns
    /// its dataset and cascade).
    pub batch: usize,
    /// Base learning rate.
    pub lr: f64,
    /// Momentum coefficient β (0 = plain SGD).
    pub momentum: f64,
    /// Multiply lr by this every `lr_decay_every` steps (1.0 = constant).
    pub lr_decay: f64,
    /// Steps between learning-rate decays (0 = never decay).
    pub lr_decay_every: usize,
    /// SELL family to train: `acdc`, `fastfood`, `lowrank` or `circulant`.
    pub model_kind: String,
    /// Cascade width N (must be a power of two for the transform-based
    /// families; `lowrank` accepts any width in range).
    pub width: usize,
    /// Cascade depth K (`acdc`/`circulant`; the single-block `fastfood`
    /// and `lowrank` families ignore it).
    pub depth: usize,
    /// Low-rank factorization rank r (0 = auto: width/2). Must satisfy
    /// 1 ≤ r ≤ width; ignored by the other families.
    pub rank: usize,
    /// Mean of the diagonal init (the paper's working init is A = D = 1
    /// plus small Gaussian noise — mean 1.0).
    pub init_mean: f64,
    /// Std-dev of the diagonal init noise.
    pub init_sigma: f64,
    /// Train a §6.2-style nonlinear cascade (ReLU + permutations +
    /// trainable biases) instead of the linear Fig-3 operator.
    pub nonlinear: bool,
    /// Rows of the synthetic eq.-(15) regression dataset.
    pub dataset_rows: usize,
    /// Target-noise variance of the dataset.
    pub dataset_noise: f64,
    /// RNG seed for dataset + init.
    pub seed: u64,
    /// Write a checkpoint manifest every this many steps (0 = only at
    /// promotion/completion).
    pub checkpoint_every: usize,
    /// Directory checkpoint manifests are written into.
    pub checkpoint_dir: String,
    /// Convergence target: the job completes once loss ≤ first-loss ×
    /// this ratio (0.1 = a 10× drop).
    pub target_ratio: f64,
    /// Promote (checkpoint → registry load → hot swap) automatically
    /// when the job completes.
    pub promote_on_complete: bool,
    /// Cap on concurrently live (non-terminal) jobs in the pool.
    pub max_jobs: usize,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            steps: 2_000,
            batch: 64,
            lr: 2e-4,
            momentum: 0.9,
            lr_decay: 1.0,
            lr_decay_every: 0,
            model_kind: "acdc".into(),
            width: 32,
            depth: 2,
            rank: 0,
            init_mean: 1.0,
            init_sigma: 0.1,
            nonlinear: false,
            dataset_rows: 4_096,
            dataset_noise: 1e-4,
            seed: 0,
            checkpoint_every: 500,
            checkpoint_dir: "ckpts".into(),
            target_ratio: 0.1,
            promote_on_complete: true,
            max_jobs: 4,
        }
    }
}

impl TrainerConfig {
    /// Build from a parsed config's `[trainer]` section (defaults fill
    /// missing keys).
    pub fn from_config(cfg: &Config) -> Result<TrainerConfig, String> {
        let d = TrainerConfig::default();
        let tc = TrainerConfig {
            steps: cfg.get_usize("trainer.steps", d.steps),
            batch: cfg.get_usize("trainer.batch", d.batch),
            lr: cfg.get_f64("trainer.lr", d.lr),
            momentum: cfg.get_f64("trainer.momentum", d.momentum),
            lr_decay: cfg.get_f64("trainer.lr_decay", d.lr_decay),
            lr_decay_every: cfg.get_usize("trainer.lr_decay_every", d.lr_decay_every),
            model_kind: cfg.get_str("trainer.model_kind", &d.model_kind),
            width: cfg.get_usize("trainer.width", d.width),
            depth: cfg.get_usize("trainer.depth", d.depth),
            rank: cfg.get_usize("trainer.rank", d.rank),
            init_mean: cfg.get_f64("trainer.init_mean", d.init_mean),
            init_sigma: cfg.get_f64("trainer.init_sigma", d.init_sigma),
            nonlinear: cfg.get_bool("trainer.nonlinear", d.nonlinear),
            dataset_rows: cfg.get_usize("trainer.dataset_rows", d.dataset_rows),
            dataset_noise: cfg.get_f64("trainer.dataset_noise", d.dataset_noise),
            seed: cfg.get_usize("trainer.seed", d.seed as usize) as u64,
            checkpoint_every: cfg.get_usize("trainer.checkpoint_every", d.checkpoint_every),
            checkpoint_dir: cfg.get_str("trainer.checkpoint_dir", &d.checkpoint_dir),
            target_ratio: cfg.get_f64("trainer.target_ratio", d.target_ratio),
            promote_on_complete: cfg.get_bool("trainer.promote_on_complete", d.promote_on_complete),
            max_jobs: cfg.get_usize("trainer.max_jobs", d.max_jobs),
        };
        tc.validate()?;
        Ok(tc)
    }

    /// Cap on `dataset_rows × width` elements (64 MB per f32 tensor):
    /// the train endpoint is unauthenticated-adjacent admin surface, and
    /// an unbounded spec would let one request abort the gateway on a
    /// failed multi-GB allocation.
    pub const MAX_DATASET_ELEMS: usize = 1 << 24;

    /// Cap on `batch × width × depth` elements (the per-step activation
    /// cache the backward pass keeps).
    pub const MAX_STEP_ELEMS: usize = 1 << 24;

    /// The low-rank factorization rank after resolving the 0 = auto
    /// default (width/2, floored at 1).
    pub fn effective_rank(&self) -> usize {
        if self.rank == 0 {
            (self.width / 2).max(1)
        } else {
            self.rank
        }
    }

    /// Sanity-check the knobs. Rejecting an unknown `model_kind` or a
    /// non-power-of-two width for the transform families here is what
    /// keeps a bad HTTP train request a 400 instead of a panic in the
    /// DCT/FFT plan constructors; the size caps keep a hostile spec a
    /// 400 instead of an allocation abort.
    pub fn validate(&self) -> Result<(), String> {
        if self.steps == 0 {
            return Err("trainer.steps must be >= 1".into());
        }
        if self.batch == 0 {
            return Err("trainer.batch must be >= 1".into());
        }
        if self.batch > self.dataset_rows {
            return Err("trainer.batch must not exceed trainer.dataset_rows".into());
        }
        if !self.lr.is_finite() || self.lr <= 0.0 {
            return Err("trainer.lr must be finite and positive".into());
        }
        if !self.momentum.is_finite() || !(0.0..1.0).contains(&self.momentum) {
            return Err("trainer.momentum must be in [0, 1)".into());
        }
        if !self.lr_decay.is_finite() || self.lr_decay <= 0.0 || self.lr_decay > 1.0 {
            return Err("trainer.lr_decay must be in (0, 1]".into());
        }
        let kind = crate::sell::ModelKind::parse(&self.model_kind).ok_or_else(|| {
            format!(
                "trainer.model_kind must be one of acdc, fastfood, lowrank, circulant; got '{}'",
                self.model_kind
            )
        })?;
        if self.width < 2 || self.width > 16_384 {
            return Err(format!(
                "trainer.width must be in [2, 16384], got {}",
                self.width
            ));
        }
        if kind.needs_pow2_width() && !self.width.is_power_of_two() {
            return Err(format!(
                "trainer.width must be a power of two in [2, 16384], got {}",
                self.width
            ));
        }
        if kind == crate::sell::ModelKind::LowRank {
            let r = self.effective_rank();
            if r == 0 || r > self.width {
                return Err(format!(
                    "trainer.rank must be in [1, trainer.width={}], got {r}",
                    self.width
                ));
            }
        }
        if self.depth == 0 || self.depth > 64 {
            return Err("trainer.depth must be in [1, 64]".into());
        }
        if self.dataset_rows.saturating_mul(self.width) > Self::MAX_DATASET_ELEMS {
            return Err(format!(
                "trainer.dataset_rows x width must not exceed {} elements",
                Self::MAX_DATASET_ELEMS
            ));
        }
        let step_elems = self
            .batch
            .saturating_mul(self.width)
            .saturating_mul(self.depth);
        if step_elems > Self::MAX_STEP_ELEMS {
            return Err(format!(
                "trainer.batch x width x depth must not exceed {} elements",
                Self::MAX_STEP_ELEMS
            ));
        }
        if !self.init_mean.is_finite() || !self.init_sigma.is_finite() || self.init_sigma < 0.0 {
            return Err("trainer.init_mean/init_sigma must be finite (sigma >= 0)".into());
        }
        if !self.dataset_noise.is_finite() || self.dataset_noise < 0.0 {
            return Err("trainer.dataset_noise must be finite and >= 0".into());
        }
        if !self.target_ratio.is_finite() || self.target_ratio <= 0.0 || self.target_ratio > 1.0 {
            return Err("trainer.target_ratio must be in (0, 1]".into());
        }
        if self.max_jobs == 0 {
            return Err("trainer.max_jobs must be >= 1".into());
        }
        Ok(())
    }
}

/// Tracing + logging configuration (`[trace]` section): per-request
/// pipeline spans, the slow-request capture ring behind
/// `GET /v1/debug/slow`, and the structured JSON-lines logger.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Master switch for per-request span capture (on by default — the
    /// span record lives in the connection arena, so tracing costs no
    /// allocations).
    pub enabled: bool,
    /// Requests with end-to-end latency ≥ this land in the slow ring.
    pub slow_ms: u64,
    /// Slots in the slow-request ring.
    pub ring_capacity: usize,
    /// Trace 1 out of every N requests (1 = every request).
    pub sample_every: u64,
    /// Logger level: `off`, `error`, `warn`, `info` or `debug`
    /// (the `ACDC_LOG` env var overrides this at startup).
    pub log_level: String,
    /// Cap on emitted log lines per second (0 = uncapped); excess events
    /// are counted and summarized when the window rolls.
    pub log_max_per_s: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: true,
            slow_ms: 250,
            ring_capacity: 64,
            sample_every: 1,
            log_level: "info".into(),
            log_max_per_s: 200,
        }
    }
}

impl TraceConfig {
    /// Build from a parsed config's `[trace]` section (defaults fill
    /// missing keys).
    pub fn from_config(cfg: &Config) -> Result<TraceConfig, String> {
        let d = TraceConfig::default();
        let tc = TraceConfig {
            enabled: cfg.get_bool("trace.enabled", d.enabled),
            slow_ms: cfg.get_usize("trace.slow_ms", d.slow_ms as usize) as u64,
            ring_capacity: cfg.get_usize("trace.ring_capacity", d.ring_capacity),
            sample_every: cfg.get_usize("trace.sample_every", d.sample_every as usize) as u64,
            log_level: cfg.get_str("trace.log_level", &d.log_level),
            log_max_per_s: cfg.get_usize("trace.log_max_per_s", d.log_max_per_s as usize) as u64,
        };
        tc.validate()?;
        Ok(tc)
    }

    /// Sanity-check ring size, sampling and level name.
    pub fn validate(&self) -> Result<(), String> {
        if self.ring_capacity == 0 {
            return Err("trace.ring_capacity must be >= 1".into());
        }
        if self.sample_every == 0 {
            return Err("trace.sample_every must be >= 1".into());
        }
        if self.slow_ms == 0 {
            return Err("trace.slow_ms must be >= 1".into());
        }
        if crate::trace::log::Level::parse(&self.log_level).is_none() {
            return Err(format!(
                "trace.log_level must be off|error|warn|info|debug, got '{}'",
                self.log_level
            ));
        }
        Ok(())
    }
}

/// Request-deadline limits (`[limits]` section): every request is minted
/// a deadline at admission — either the client's `x-acdc-deadline-ms`
/// header clamped to `[1, max_deadline_ms]`, or `default_deadline_ms`
/// when the header is absent. The deadline rides on the request through
/// batcher, worker and router so expired work is reaped instead of
/// executed. See `DESIGN.md` §9.
#[derive(Debug, Clone)]
pub struct LimitsConfig {
    /// Deadline in milliseconds for requests that send no
    /// `x-acdc-deadline-ms` header.
    pub default_deadline_ms: u64,
    /// Upper clamp on client-requested deadlines in milliseconds.
    pub max_deadline_ms: u64,
}

impl Default for LimitsConfig {
    fn default() -> Self {
        LimitsConfig {
            default_deadline_ms: 5_000,
            max_deadline_ms: 30_000,
        }
    }
}

impl LimitsConfig {
    /// Build from a parsed config's `[limits]` section (defaults fill
    /// missing keys).
    pub fn from_config(cfg: &Config) -> Result<LimitsConfig, String> {
        let d = LimitsConfig::default();
        let lc = LimitsConfig {
            default_deadline_ms: cfg
                .get_usize("limits.default_deadline_ms", d.default_deadline_ms as usize)
                as u64,
            max_deadline_ms: cfg.get_usize("limits.max_deadline_ms", d.max_deadline_ms as usize)
                as u64,
        };
        lc.validate()?;
        Ok(lc)
    }

    /// Sanity-check the deadline bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.default_deadline_ms == 0 {
            return Err("limits.default_deadline_ms must be >= 1".into());
        }
        if self.max_deadline_ms == 0 {
            return Err("limits.max_deadline_ms must be >= 1".into());
        }
        if self.default_deadline_ms > self.max_deadline_ms {
            return Err("limits.default_deadline_ms must not exceed limits.max_deadline_ms".into());
        }
        Ok(())
    }

    /// Resolve a client-requested deadline (milliseconds, `None` when no
    /// header was sent) against these limits: absent → the default, and
    /// every result is clamped to `[1, max_deadline_ms]`. Pure, so the
    /// property suite can pin the clamp behavior.
    pub fn clamp_deadline_ms(&self, requested: Option<u64>) -> u64 {
        requested
            .unwrap_or(self.default_deadline_ms)
            .clamp(1, self.max_deadline_ms)
    }
}

/// Brownout-degradation configuration (`[brownout]` section): the gateway
/// controller that walks a degradation ladder under sustained pressure
/// (level 1 disables hedging, 2 coarsens trace sampling, 3 sheds
/// multi-row requests, 4 sheds all non-health traffic), with hysteresis
/// in both directions. See `DESIGN.md` §9.
#[derive(Debug, Clone)]
pub struct BrownoutConfig {
    /// Master switch for the brownout controller thread.
    pub enabled: bool,
    /// Milliseconds between controller pressure samples.
    pub tick_ms: u64,
    /// A tick is "hot" when in-flight requests exceed this fraction of
    /// `gateway.max_inflight` (or the coordinator queue passes
    /// `hot_queue_depth`).
    pub hot_inflight_pct: f64,
    /// A tick is "hot" when the coordinator queue depth reaches this
    /// many waiting requests (0 disables the queue-depth trigger).
    pub hot_queue_depth: u64,
    /// Consecutive hot ticks before the ladder steps up one level.
    pub up_after: u64,
    /// Consecutive cool ticks before the ladder steps down one level.
    pub down_after: u64,
    /// Multiplier applied to `trace.sample_every` at level ≥ 2.
    pub sample_coarsen: u64,
}

impl Default for BrownoutConfig {
    fn default() -> Self {
        BrownoutConfig {
            enabled: true,
            tick_ms: 100,
            hot_inflight_pct: 0.8,
            hot_queue_depth: 0,
            up_after: 3,
            down_after: 5,
            sample_coarsen: 16,
        }
    }
}

impl BrownoutConfig {
    /// Build from a parsed config's `[brownout]` section (defaults fill
    /// missing keys).
    pub fn from_config(cfg: &Config) -> Result<BrownoutConfig, String> {
        let d = BrownoutConfig::default();
        let bc = BrownoutConfig {
            enabled: cfg.get_bool("brownout.enabled", d.enabled),
            tick_ms: cfg.get_usize("brownout.tick_ms", d.tick_ms as usize) as u64,
            hot_inflight_pct: cfg.get_f64("brownout.hot_inflight_pct", d.hot_inflight_pct),
            hot_queue_depth: cfg
                .get_usize("brownout.hot_queue_depth", d.hot_queue_depth as usize)
                as u64,
            up_after: cfg.get_usize("brownout.up_after", d.up_after as usize) as u64,
            down_after: cfg.get_usize("brownout.down_after", d.down_after as usize) as u64,
            sample_coarsen: cfg.get_usize("brownout.sample_coarsen", d.sample_coarsen as usize)
                as u64,
        };
        bc.validate()?;
        Ok(bc)
    }

    /// Sanity-check the controller knobs.
    pub fn validate(&self) -> Result<(), String> {
        if self.tick_ms == 0 {
            return Err("brownout.tick_ms must be >= 1".into());
        }
        if !self.hot_inflight_pct.is_finite()
            || self.hot_inflight_pct <= 0.0
            || self.hot_inflight_pct > 1.0
        {
            return Err("brownout.hot_inflight_pct must be in (0, 1]".into());
        }
        if self.up_after == 0 {
            return Err("brownout.up_after must be >= 1".into());
        }
        if self.down_after == 0 {
            return Err("brownout.down_after must be >= 1".into());
        }
        if self.sample_coarsen == 0 {
            return Err("brownout.sample_coarsen must be >= 1".into());
        }
        Ok(())
    }
}

/// Deterministic fault-injection configuration (`[faults]` section): a
/// seeded SplitMix64 stream decides, per executed batch, whether the
/// wrapped executor sleeps (`delay`/`stall`) or fails (`error`). Off by
/// default; the chaos suite turns it on to drive overload without flaky
/// wall-clock sleeps. The `ACDC_FAULTS` environment variable (a
/// `key=value` comma list, e.g. `delay_ms=200,delay_prob=1`) overrides
/// any file config at coordinator startup.
#[derive(Debug, Clone)]
pub struct FaultsConfig {
    /// Master switch; when false the executor is never wrapped.
    pub enabled: bool,
    /// Seed for the SplitMix64 decision stream.
    pub seed: u64,
    /// Injected delay in milliseconds before a batch executes.
    pub delay_ms: u64,
    /// Per-batch probability of the injected delay, in `[0, 1]`.
    pub delay_prob: f64,
    /// Per-batch probability of an injected executor error, in `[0, 1]`.
    pub error_prob: f64,
    /// Injected long stall in milliseconds (models a wedged device).
    pub stall_ms: u64,
    /// Per-batch probability of the injected stall, in `[0, 1]`.
    pub stall_prob: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: false,
            seed: 0x5eed_face,
            delay_ms: 0,
            delay_prob: 0.0,
            error_prob: 0.0,
            stall_ms: 0,
            stall_prob: 0.0,
        }
    }
}

impl FaultsConfig {
    /// Build from a parsed config's `[faults]` section (defaults fill
    /// missing keys).
    pub fn from_config(cfg: &Config) -> Result<FaultsConfig, String> {
        let d = FaultsConfig::default();
        let fc = FaultsConfig {
            enabled: cfg.get_bool("faults.enabled", d.enabled),
            seed: cfg.get_usize("faults.seed", d.seed as usize) as u64,
            delay_ms: cfg.get_usize("faults.delay_ms", d.delay_ms as usize) as u64,
            delay_prob: cfg.get_f64("faults.delay_prob", d.delay_prob),
            error_prob: cfg.get_f64("faults.error_prob", d.error_prob),
            stall_ms: cfg.get_usize("faults.stall_ms", d.stall_ms as usize) as u64,
            stall_prob: cfg.get_f64("faults.stall_prob", d.stall_prob),
        };
        fc.validate()?;
        Ok(fc)
    }

    /// Sanity-check the probabilities.
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("faults.delay_prob", self.delay_prob),
            ("faults.error_prob", self.error_prob),
            ("faults.stall_prob", self.stall_prob),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!("{name} must be in [0, 1]"));
            }
        }
        Ok(())
    }

    /// True when injection is on and at least one fault can fire.
    pub fn active(&self) -> bool {
        self.enabled && (self.delay_prob > 0.0 || self.error_prob > 0.0 || self.stall_prob > 0.0)
    }

    /// Apply `ACDC_FAULTS` environment overrides (a comma-separated
    /// `key=value` list; setting any key implies `enabled=true` unless
    /// `enabled=false` is given explicitly). Unknown keys or malformed
    /// values are reported as errors so a typo'd chaos run cannot
    /// silently test nothing.
    pub fn with_env_overrides(&self) -> Result<FaultsConfig, String> {
        let spec = match std::env::var("ACDC_FAULTS") {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return Ok(self.clone()),
        };
        let mut fc = self.clone();
        fc.enabled = true;
        for pair in spec.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("ACDC_FAULTS entry '{pair}' must be key=value"))?;
            let bad = |e: &str| format!("ACDC_FAULTS {k}={v}: {e}");
            match k.trim() {
                "enabled" => fc.enabled = v.parse().map_err(|_| bad("expected bool"))?,
                "seed" => fc.seed = v.parse().map_err(|_| bad("expected u64"))?,
                "delay_ms" => fc.delay_ms = v.parse().map_err(|_| bad("expected u64"))?,
                "delay_prob" => fc.delay_prob = v.parse().map_err(|_| bad("expected f64"))?,
                "error_prob" => fc.error_prob = v.parse().map_err(|_| bad("expected f64"))?,
                "stall_ms" => fc.stall_ms = v.parse().map_err(|_| bad("expected u64"))?,
                "stall_prob" => fc.stall_prob = v.parse().map_err(|_| bad("expected f64"))?,
                other => return Err(format!("ACDC_FAULTS: unknown key '{other}'")),
            }
        }
        fc.validate()?;
        Ok(fc)
    }
}

/// Serving coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding the AOT artifacts + manifest.
    pub artifacts_dir: String,
    /// Batch buckets the batcher may dispatch (must match AOT buckets).
    pub buckets: Vec<usize>,
    /// Max time a request may wait for batch formation.
    pub max_wait_us: u64,
    /// Worker threads executing PJRT calls.
    pub workers: usize,
    /// Bound on queued requests before backpressure rejections.
    pub queue_cap: usize,
    /// Network front-end knobs (`[gateway]` section).
    pub gateway: GatewayConfig,
    /// Model registry knobs (`[registry]` section).
    pub registry: RegistryConfig,
    /// Training-job defaults (`[trainer]` section).
    pub trainer: TrainerConfig,
    /// Deterministic fault-injection knobs (`[faults]` section).
    pub faults: FaultsConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            buckets: vec![1, 8, 32, 128],
            max_wait_us: 2_000,
            workers: 2,
            queue_cap: 4_096,
            gateway: GatewayConfig::default(),
            registry: RegistryConfig::default(),
            trainer: TrainerConfig::default(),
            faults: FaultsConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Build from a parsed config's `[serve]` (+ `[gateway]`) sections.
    pub fn from_config(cfg: &Config) -> Result<ServeConfig, String> {
        let mut sc = ServeConfig {
            artifacts_dir: cfg.get_str("serve.artifacts_dir", "artifacts"),
            max_wait_us: cfg.get_usize("serve.max_wait_us", 2_000) as u64,
            workers: cfg.get_usize("serve.workers", 2),
            queue_cap: cfg.get_usize("serve.queue_cap", 4_096),
            gateway: GatewayConfig::from_config(cfg)?,
            registry: RegistryConfig::from_config(cfg)?,
            trainer: TrainerConfig::from_config(cfg)?,
            faults: FaultsConfig::from_config(cfg)?,
            ..Default::default()
        };
        if let Some(v) = cfg.get("serve.buckets") {
            let arr = v.as_array().ok_or("serve.buckets must be an array")?;
            sc.buckets = arr
                .iter()
                .map(|v| v.as_usize().ok_or("bucket must be a positive integer"))
                .collect::<Result<Vec<_>, _>>()?;
        }
        sc.validate()?;
        Ok(sc)
    }

    /// Sanity-check buckets/workers/queue bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.buckets.is_empty() {
            return Err("at least one batch bucket required".into());
        }
        let mut sorted = self.buckets.clone();
        sorted.sort_unstable();
        if sorted != self.buckets {
            return Err("buckets must be ascending".into());
        }
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if self.queue_cap == 0 {
            return Err("queue_cap must be >= 1".into());
        }
        self.gateway.validate()?;
        self.trainer.validate()?;
        self.faults.validate()
    }
}

/// Training orchestrator configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Directory holding the AOT artifacts + manifest.
    pub artifacts_dir: String,
    /// SGD steps to run.
    pub steps: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Base learning rate.
    pub lr: f64,
    /// Multiply lr by `lr_decay` every `lr_decay_every` steps (§6.2 style).
    pub lr_decay: f64,
    /// Steps between learning-rate decays.
    pub lr_decay_every: usize,
    /// Steps between held-out evaluations.
    pub eval_every: usize,
    /// RNG seed for data + init.
    pub seed: u64,
    /// Where to write the final checkpoint (None = don't).
    pub checkpoint_path: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts_dir: "artifacts".into(),
            steps: 400,
            batch: 64,
            lr: 0.02,
            lr_decay: 0.1,
            lr_decay_every: 100_000,
            eval_every: 50,
            seed: 0,
            checkpoint_path: None,
        }
    }
}

impl TrainConfig {
    /// Build from a parsed config's `[train]` section.
    pub fn from_config(cfg: &Config) -> Result<TrainConfig, String> {
        let tc = TrainConfig {
            artifacts_dir: cfg.get_str("train.artifacts_dir", "artifacts"),
            steps: cfg.get_usize("train.steps", 400),
            batch: cfg.get_usize("train.batch", 64),
            lr: cfg.get_f64("train.lr", 0.02),
            lr_decay: cfg.get_f64("train.lr_decay", 0.1),
            lr_decay_every: cfg.get_usize("train.lr_decay_every", 100_000),
            eval_every: cfg.get_usize("train.eval_every", 50),
            seed: cfg.get_usize("train.seed", 0) as u64,
            checkpoint_path: cfg
                .get("train.checkpoint_path")
                .and_then(|v| v.as_str())
                .map(String::from),
        };
        tc.validate()?;
        Ok(tc)
    }

    /// Sanity-check steps/lr/decay ranges.
    pub fn validate(&self) -> Result<(), String> {
        if self.steps == 0 {
            return Err("steps must be >= 1".into());
        }
        if self.lr <= 0.0 {
            return Err("lr must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.lr_decay) {
            return Err("lr_decay must be in (0, 1]".into());
        }
        Ok(())
    }
}

/// Cluster topology configuration (`[cluster]` section): the static
/// shard membership an `acdc router` process fronts, plus the placement,
/// health-check, and hedging knobs. See `DESIGN.md` §8.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Shard gateway addresses in topology order (`host:port`). The
    /// index into this array is the shard's identity everywhere: the
    /// consistent-hash ring, per-shard metric names
    /// (`cluster.shard{i}.*`), and the `x-acdc-upstream` header.
    pub shards: Vec<String>,
    /// Replicas per model (clamped to the shard count at placement).
    pub replication: usize,
    /// Virtual nodes per shard on the consistent-hash ring.
    pub vnodes: usize,
    /// Milliseconds between `/healthz` probe rounds.
    pub probe_interval_ms: u64,
    /// Consecutive failures (probe or request transport error) before a
    /// shard is marked down.
    pub down_after: u64,
    /// Consecutive probe successes before a down shard is re-admitted.
    pub up_after: u64,
    /// Latency percentile of the chosen shard's own history that arms
    /// the hedge timer (e.g. 99.0 = hedge past its p99).
    pub hedge_pct: f64,
    /// Floor on the hedge delay in milliseconds (also the effective
    /// delay while a shard's latency histogram is still cold).
    pub hedge_min_ms: u64,
    /// Upstream TCP connect budget in milliseconds.
    pub connect_timeout_ms: u64,
    /// End-to-end budget for one proxied request across all retries and
    /// hedges, in milliseconds.
    pub request_timeout_ms: u64,
    /// Rolling-swap bound on waiting for one replica's per-model
    /// in-flight count to reach zero (the swap proceeds regardless when
    /// it expires — the shard-local Arc-epoch swap is always safe).
    pub drain_timeout_ms: u64,
    /// Request outcomes in each upstream's rolling circuit-breaker
    /// window (capped at 64 — the window is a bitmask).
    pub breaker_window: u64,
    /// Failure ratio within a full window that opens the breaker, in
    /// `(0, 1]`.
    pub breaker_trip_ratio: f64,
    /// Milliseconds an open breaker waits before admitting one
    /// half-open probe request.
    pub breaker_cooldown_ms: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            shards: Vec::new(),
            replication: 2,
            vnodes: 128,
            probe_interval_ms: 500,
            down_after: 3,
            up_after: 2,
            hedge_pct: 99.0,
            hedge_min_ms: 20,
            connect_timeout_ms: 1_000,
            request_timeout_ms: 5_000,
            drain_timeout_ms: 10_000,
            breaker_window: 16,
            breaker_trip_ratio: 0.5,
            breaker_cooldown_ms: 1_000,
        }
    }
}

impl ClusterConfig {
    /// Build from a parsed config's `[cluster]` section. `shards` is an
    /// array of `"host:port"` strings and is required.
    pub fn from_config(cfg: &Config) -> Result<ClusterConfig, String> {
        let d = ClusterConfig::default();
        let mut cc = ClusterConfig {
            shards: Vec::new(),
            replication: cfg.get_usize("cluster.replication", d.replication),
            vnodes: cfg.get_usize("cluster.vnodes", d.vnodes),
            probe_interval_ms: cfg
                .get_usize("cluster.probe_interval_ms", d.probe_interval_ms as usize)
                as u64,
            down_after: cfg.get_usize("cluster.down_after", d.down_after as usize) as u64,
            up_after: cfg.get_usize("cluster.up_after", d.up_after as usize) as u64,
            hedge_pct: cfg.get_f64("cluster.hedge_pct", d.hedge_pct),
            hedge_min_ms: cfg.get_usize("cluster.hedge_min_ms", d.hedge_min_ms as usize) as u64,
            connect_timeout_ms: cfg
                .get_usize("cluster.connect_timeout_ms", d.connect_timeout_ms as usize)
                as u64,
            request_timeout_ms: cfg
                .get_usize("cluster.request_timeout_ms", d.request_timeout_ms as usize)
                as u64,
            drain_timeout_ms: cfg
                .get_usize("cluster.drain_timeout_ms", d.drain_timeout_ms as usize)
                as u64,
            breaker_window: cfg.get_usize("cluster.breaker_window", d.breaker_window as usize)
                as u64,
            breaker_trip_ratio: cfg.get_f64("cluster.breaker_trip_ratio", d.breaker_trip_ratio),
            breaker_cooldown_ms: cfg
                .get_usize("cluster.breaker_cooldown_ms", d.breaker_cooldown_ms as usize)
                as u64,
        };
        if let Some(v) = cfg.get("cluster.shards") {
            let arr = v
                .as_array()
                .ok_or("cluster.shards must be an array of \"host:port\" strings")?;
            for item in arr {
                let s = item
                    .as_str()
                    .ok_or("cluster.shards entries must be strings")?;
                cc.shards.push(s.to_string());
            }
        }
        cc.validate()?;
        Ok(cc)
    }

    /// Sanity-check the topology (shards present and distinct, replication
    /// within bounds, hysteresis/hedging/timeout knobs ≥ 1).
    pub fn validate(&self) -> Result<(), String> {
        if self.shards.is_empty() {
            return Err("cluster.shards must list at least one shard address".into());
        }
        for (i, s) in self.shards.iter().enumerate() {
            if s.is_empty() {
                return Err("cluster.shards entries must not be empty".into());
            }
            if self.shards[..i].contains(s) {
                return Err(format!("cluster.shards lists '{s}' twice"));
            }
        }
        if self.replication == 0 || self.replication > self.shards.len() {
            return Err(format!(
                "cluster.replication must be in [1, {}] (the shard count), got {}",
                self.shards.len(),
                self.replication
            ));
        }
        if self.vnodes == 0 {
            return Err("cluster.vnodes must be >= 1".into());
        }
        if self.probe_interval_ms == 0 {
            return Err("cluster.probe_interval_ms must be >= 1".into());
        }
        if self.down_after == 0 {
            return Err("cluster.down_after must be >= 1".into());
        }
        if self.up_after == 0 {
            return Err("cluster.up_after must be >= 1".into());
        }
        if !self.hedge_pct.is_finite() || self.hedge_pct <= 0.0 || self.hedge_pct > 100.0 {
            return Err("cluster.hedge_pct must be in (0, 100]".into());
        }
        if self.connect_timeout_ms == 0 {
            return Err("cluster.connect_timeout_ms must be >= 1".into());
        }
        if self.request_timeout_ms == 0 {
            return Err("cluster.request_timeout_ms must be >= 1".into());
        }
        if self.drain_timeout_ms == 0 {
            return Err("cluster.drain_timeout_ms must be >= 1".into());
        }
        if self.breaker_window == 0 || self.breaker_window > 64 {
            return Err("cluster.breaker_window must be in [1, 64]".into());
        }
        if !self.breaker_trip_ratio.is_finite()
            || self.breaker_trip_ratio <= 0.0
            || self.breaker_trip_ratio > 1.0
        {
            return Err("cluster.breaker_trip_ratio must be in (0, 1]".into());
        }
        if self.breaker_cooldown_ms == 0 {
            return Err("cluster.breaker_cooldown_ms must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
[serve]
artifacts_dir = "artifacts"
buckets = [1, 8, 32, 128]
max_wait_us = 500
workers = 4

[train]
steps = 300
lr = 0.05        # per §6.2
checkpoint_path = "ckpt.bin"
verbose = true

[gateway]
addr = "127.0.0.1:9000"
max_inflight = 64
rate_rps = 500.0
rate_burst = 50.0
retry_after_s = 2

[registry]
default_model = "stable"
models = ["m1=ckpts/m1.ckpt", "m2=ckpts/m2.ckpt"]

[trainer]
steps = 1200
batch = 32
lr = 0.005
momentum = 0.5
model_kind = "acdc"
width = 64
depth = 4
checkpoint_every = 100
checkpoint_dir = "out/ckpts"
target_ratio = 0.05

[trace]
slow_ms = 40
ring_capacity = 16
log_level = "debug"

[limits]
default_deadline_ms = 2000
max_deadline_ms = 8000

[brownout]
tick_ms = 50
hot_inflight_pct = 0.75
up_after = 2
down_after = 4

[faults]
enabled = true
seed = 7
delay_ms = 20
delay_prob = 0.25
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get_str("serve.artifacts_dir", ""), "artifacts");
        assert_eq!(cfg.get_usize("serve.workers", 0), 4);
        assert_eq!(cfg.get_f64("train.lr", 0.0), 0.05);
        assert!(cfg.get_bool("train.verbose", false));
        let arr = cfg.get("serve.buckets").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 4);
    }

    #[test]
    fn comments_stripped_but_not_in_strings() {
        let cfg = Config::parse("key = \"a#b\" # trailing").unwrap();
        assert_eq!(cfg.get_str("key", ""), "a#b");
    }

    #[test]
    fn top_level_keys() {
        let cfg = Config::parse("alpha = 1\n[s]\nbeta = 2").unwrap();
        assert_eq!(cfg.get_usize("alpha", 0), 1);
        assert_eq!(cfg.get_usize("s.beta", 0), 2);
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = Config::parse("good = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Config::parse("[unterminated").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Config::parse("k = \"open").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
        assert!(Config::parse("k = what").is_err());
        assert!(Config::parse("= 3").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        let cfg = Config::parse("a = -5\nb = 2.5e-3").unwrap();
        assert_eq!(cfg.get("a").unwrap().as_int(), Some(-5));
        assert!((cfg.get_f64("b", 0.0) - 2.5e-3).abs() < 1e-12);
    }

    #[test]
    fn serve_config_from_config() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.buckets, vec![1, 8, 32, 128]);
        assert_eq!(sc.max_wait_us, 500);
        assert_eq!(sc.workers, 4);
    }

    #[test]
    fn serve_config_validation() {
        let mut sc = ServeConfig {
            buckets: vec![8, 1],
            ..Default::default()
        };
        assert!(sc.validate().is_err());
        sc.buckets = vec![];
        assert!(sc.validate().is_err());
        let sc = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(sc.validate().is_err());
    }

    #[test]
    fn gateway_config_from_config_and_defaults() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let gc = GatewayConfig::from_config(&cfg).unwrap();
        assert_eq!(gc.addr, "127.0.0.1:9000");
        assert_eq!(gc.max_inflight, 64);
        assert!((gc.rate_rps - 500.0).abs() < 1e-9);
        assert!((gc.rate_burst - 50.0).abs() < 1e-9);
        assert_eq!(gc.retry_after_s, 2);
        // unspecified keys fall back to defaults
        assert_eq!(gc.max_open_conns, GatewayConfig::default().max_open_conns);
        // and the serve config embeds the same section
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.gateway.addr, "127.0.0.1:9000");
    }

    #[test]
    fn gateway_config_validation() {
        let ok = GatewayConfig::default();
        assert!(ok.validate().is_ok());
        let bad = GatewayConfig {
            max_inflight: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = GatewayConfig {
            rate_rps: -1.0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = GatewayConfig {
            rate_rps: 10.0,
            rate_burst: 0.0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = GatewayConfig {
            max_rows_per_request: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        for (mode, ok) in [
            ("", true),
            ("auto", true),
            ("reactor", true),
            ("threaded", true),
            ("epoll", false),
        ] {
            let gc = GatewayConfig {
                mode: mode.into(),
                ..Default::default()
            };
            assert_eq!(gc.validate().is_ok(), ok, "mode {mode:?}");
        }
        for bad in [
            GatewayConfig {
                shards: 0,
                ..Default::default()
            },
            GatewayConfig {
                dispatch_threads: 0,
                ..Default::default()
            },
            GatewayConfig {
                write_stall_ms: 0,
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }

    #[test]
    fn gateway_mode_explicit_config_wins() {
        // An explicit mode resolves regardless of the environment (CI
        // lanes pin modes via ACDC_GW_MODE, so only the explicit paths
        // are asserted here).
        let gc = GatewayConfig {
            mode: "threaded".into(),
            ..Default::default()
        };
        assert_eq!(gc.resolved_mode(), GatewayMode::Threaded);
        let gc = GatewayConfig {
            mode: " reactor ".into(),
            ..Default::default()
        };
        assert_eq!(gc.resolved_mode(), GatewayMode::Reactor);
        assert_eq!(GatewayMode::Reactor.name(), "reactor");
        assert_eq!(GatewayMode::Threaded.name(), "threaded");
    }

    #[test]
    fn registry_config_from_config() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let rc = RegistryConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.default_model, "stable");
        assert_eq!(
            rc.preload,
            vec![
                ("m1".to_string(), "ckpts/m1.ckpt".to_string()),
                ("m2".to_string(), "ckpts/m2.ckpt".to_string()),
            ]
        );
        // The serve config embeds the same section.
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.registry.default_model, "stable");
        // Malformed entries are rejected.
        let bad = Config::parse("[registry]\nmodels = [\"nopath\"]").unwrap();
        assert!(RegistryConfig::from_config(&bad).is_err());
        let bad = Config::parse("[registry]\nmodels = [7]").unwrap();
        assert!(RegistryConfig::from_config(&bad).is_err());
        // Absent section falls back to defaults.
        let empty = Config::parse("").unwrap();
        let rc = RegistryConfig::from_config(&empty).unwrap();
        assert!(rc.default_model.is_empty() && rc.preload.is_empty());
    }

    #[test]
    fn train_config_from_config_and_validation() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let tc = TrainConfig::from_config(&cfg).unwrap();
        assert_eq!(tc.steps, 300);
        assert_eq!(tc.checkpoint_path.as_deref(), Some("ckpt.bin"));
        let mut bad = tc.clone();
        bad.lr = -1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn trainer_config_from_config() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let tc = TrainerConfig::from_config(&cfg).unwrap();
        assert_eq!(tc.steps, 1200);
        assert_eq!(tc.batch, 32);
        assert!((tc.lr - 0.005).abs() < 1e-12);
        assert!((tc.momentum - 0.5).abs() < 1e-12);
        assert_eq!((tc.width, tc.depth), (64, 4));
        assert_eq!(tc.model_kind, "acdc");
        assert_eq!(tc.rank, 0);
        assert_eq!(tc.effective_rank(), 32); // 0 = auto: width/2
        assert_eq!(tc.checkpoint_every, 100);
        assert_eq!(tc.checkpoint_dir, "out/ckpts");
        assert!((tc.target_ratio - 0.05).abs() < 1e-12);
        // Unspecified keys fall back to defaults; ServeConfig embeds it.
        assert_eq!(tc.dataset_rows, TrainerConfig::default().dataset_rows);
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.trainer.steps, 1200);
    }

    #[test]
    fn trainer_config_validation() {
        let ok = TrainerConfig::default();
        assert!(ok.validate().is_ok());
        // Low-rank is exempt from the power-of-two width rule.
        let lr_ok = TrainerConfig {
            model_kind: "lowrank".into(),
            width: 48,
            rank: 12,
            ..Default::default()
        };
        assert!(lr_ok.validate().is_ok());
        for bad in [
            TrainerConfig {
                width: 48, // not a power of two → must be a 400, not a panic
                ..Default::default()
            },
            TrainerConfig {
                model_kind: "dense".into(), // unknown family → typed 400
                ..Default::default()
            },
            TrainerConfig {
                model_kind: "circulant".into(),
                width: 48, // transform family keeps the pow2 rule
                ..Default::default()
            },
            TrainerConfig {
                model_kind: "lowrank".into(),
                width: 32,
                rank: 64, // rank > width → typed 400
                ..Default::default()
            },
            TrainerConfig {
                momentum: 1.0,
                ..Default::default()
            },
            TrainerConfig {
                lr: 0.0,
                ..Default::default()
            },
            TrainerConfig {
                batch: 10_000_000,
                ..Default::default()
            },
            TrainerConfig {
                // rows x width over the allocation cap: must be a 400,
                // not an OOM abort of the serving process.
                dataset_rows: 30_000_000_000,
                batch: 64,
                ..Default::default()
            },
            TrainerConfig {
                width: 1 << 20, // pow2 but over the width cap
                ..Default::default()
            },
            TrainerConfig {
                depth: 100_000,
                ..Default::default()
            },
            TrainerConfig {
                // per-step activation cache over the cap
                batch: 4096,
                width: 16_384,
                depth: 64,
                dataset_rows: 4096,
                ..Default::default()
            },
            TrainerConfig {
                target_ratio: 0.0,
                ..Default::default()
            },
            TrainerConfig {
                depth: 0,
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }

    #[test]
    fn trace_config_from_config_and_validation() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let tc = TraceConfig::from_config(&cfg).unwrap();
        assert!(tc.enabled, "tracing defaults on");
        assert_eq!(tc.slow_ms, 40);
        assert_eq!(tc.ring_capacity, 16);
        assert_eq!(tc.log_level, "debug");
        // Unspecified keys fall back to defaults; the gateway section
        // embeds the same knobs.
        assert_eq!(tc.sample_every, TraceConfig::default().sample_every);
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.gateway.trace.slow_ms, 40);
        // Bad values are rejected.
        for bad in [
            TraceConfig {
                ring_capacity: 0,
                ..Default::default()
            },
            TraceConfig {
                sample_every: 0,
                ..Default::default()
            },
            TraceConfig {
                slow_ms: 0,
                ..Default::default()
            },
            TraceConfig {
                log_level: "loud".into(),
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
        let bad = Config::parse("[trace]\nlog_level = \"loud\"").unwrap();
        assert!(TraceConfig::from_config(&bad).is_err());
    }

    #[test]
    fn limits_config_from_config_and_clamp() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let lc = LimitsConfig::from_config(&cfg).unwrap();
        assert_eq!(lc.default_deadline_ms, 2000);
        assert_eq!(lc.max_deadline_ms, 8000);
        // The gateway section embeds the same knobs.
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.gateway.limits.max_deadline_ms, 8000);
        // Clamp semantics: absent → default, 0 → 1, over-max → max.
        assert_eq!(lc.clamp_deadline_ms(None), 2000);
        assert_eq!(lc.clamp_deadline_ms(Some(0)), 1);
        assert_eq!(lc.clamp_deadline_ms(Some(500)), 500);
        assert_eq!(lc.clamp_deadline_ms(Some(u64::MAX)), 8000);
        // Bad values are rejected.
        for bad in [
            LimitsConfig {
                default_deadline_ms: 0,
                ..Default::default()
            },
            LimitsConfig {
                max_deadline_ms: 0,
                ..Default::default()
            },
            LimitsConfig {
                default_deadline_ms: 10,
                max_deadline_ms: 5,
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }

    #[test]
    fn brownout_config_from_config_and_validation() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let bc = BrownoutConfig::from_config(&cfg).unwrap();
        assert_eq!(bc.tick_ms, 50);
        assert!((bc.hot_inflight_pct - 0.75).abs() < 1e-12);
        assert_eq!((bc.up_after, bc.down_after), (2, 4));
        assert_eq!(bc.sample_coarsen, BrownoutConfig::default().sample_coarsen);
        for bad in [
            BrownoutConfig {
                tick_ms: 0,
                ..Default::default()
            },
            BrownoutConfig {
                hot_inflight_pct: 0.0,
                ..Default::default()
            },
            BrownoutConfig {
                hot_inflight_pct: 1.5,
                ..Default::default()
            },
            BrownoutConfig {
                up_after: 0,
                ..Default::default()
            },
            BrownoutConfig {
                down_after: 0,
                ..Default::default()
            },
            BrownoutConfig {
                sample_coarsen: 0,
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }

    #[test]
    fn faults_config_from_config_and_validation() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let fc = FaultsConfig::from_config(&cfg).unwrap();
        assert!(fc.enabled);
        assert_eq!(fc.seed, 7);
        assert_eq!(fc.delay_ms, 20);
        assert!((fc.delay_prob - 0.25).abs() < 1e-12);
        assert!(fc.active());
        // Enabled with all probabilities zero injects nothing.
        let idle = FaultsConfig {
            enabled: true,
            ..Default::default()
        };
        assert!(!idle.active());
        assert!(!FaultsConfig::default().active());
        // ServeConfig embeds the section.
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert!(sc.faults.enabled);
        for bad in [
            FaultsConfig {
                delay_prob: -0.1,
                ..Default::default()
            },
            FaultsConfig {
                error_prob: 1.5,
                ..Default::default()
            },
            FaultsConfig {
                stall_prob: f64::NAN,
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }

    #[test]
    fn cluster_config_from_config() {
        let text = r#"
[cluster]
shards = ["127.0.0.1:9101", "127.0.0.1:9102", "127.0.0.1:9103"]
replication = 2
vnodes = 64
probe_interval_ms = 100
down_after = 2
up_after = 2
hedge_pct = 95.0
hedge_min_ms = 5
"#;
        let cfg = Config::parse(text).unwrap();
        let cc = ClusterConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.shards.len(), 3);
        assert_eq!(cc.shards[1], "127.0.0.1:9102");
        assert_eq!(cc.replication, 2);
        assert_eq!(cc.vnodes, 64);
        assert_eq!(cc.probe_interval_ms, 100);
        assert_eq!(cc.hedge_pct, 95.0);
        assert_eq!(cc.hedge_min_ms, 5);
        // Unspecified keys fall back to defaults.
        let d = ClusterConfig::default();
        assert_eq!(cc.connect_timeout_ms, d.connect_timeout_ms);
        assert_eq!(cc.request_timeout_ms, d.request_timeout_ms);
        assert_eq!(cc.drain_timeout_ms, d.drain_timeout_ms);
    }

    #[test]
    fn cluster_config_validation() {
        let two = || ClusterConfig {
            shards: vec!["a:1".into(), "b:2".into()],
            ..Default::default()
        };
        assert!(two().validate().is_ok());
        // No shards at all (the default) is invalid for a router.
        assert!(ClusterConfig::default().validate().is_err());
        // Replication beyond the shard count.
        let bad = ClusterConfig {
            replication: 3,
            ..two()
        };
        assert!(bad.validate().is_err());
        // Duplicate shard addresses.
        let bad = ClusterConfig {
            shards: vec!["a:1".into(), "a:1".into()],
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // Hedge percentile out of range.
        let bad = ClusterConfig {
            hedge_pct: 0.0,
            ..two()
        };
        assert!(bad.validate().is_err());
        // Hysteresis knobs must be >= 1.
        let bad = ClusterConfig {
            down_after: 0,
            ..two()
        };
        assert!(bad.validate().is_err());
        let bad = ClusterConfig {
            up_after: 0,
            ..two()
        };
        assert!(bad.validate().is_err());
        // from_config without a [cluster] section fails on empty shards.
        let cfg = Config::parse("[gateway]\naddr = \"127.0.0.1:0\"").unwrap();
        assert!(ClusterConfig::from_config(&cfg).is_err());
        // Non-string shard entries are rejected.
        let cfg = Config::parse("[cluster]\nshards = [1, 2]").unwrap();
        assert!(ClusterConfig::from_config(&cfg).is_err());
        // Circuit-breaker knobs must be in range.
        for bad in [
            ClusterConfig {
                breaker_window: 0,
                ..two()
            },
            ClusterConfig {
                breaker_window: 65,
                ..two()
            },
            ClusterConfig {
                breaker_trip_ratio: 0.0,
                ..two()
            },
            ClusterConfig {
                breaker_trip_ratio: 1.5,
                ..two()
            },
            ClusterConfig {
                breaker_cooldown_ms: 0,
                ..two()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }

    #[test]
    fn defaults_are_valid() {
        assert!(ServeConfig::default().validate().is_ok());
        assert!(TrainConfig::default().validate().is_ok());
        assert!(TrainerConfig::default().validate().is_ok());
        assert!(TraceConfig::default().validate().is_ok());
        assert!(LimitsConfig::default().validate().is_ok());
        assert!(BrownoutConfig::default().validate().is_ok());
        assert!(FaultsConfig::default().validate().is_ok());
    }
}
