//! Background training jobs that feed the live model registry.
//!
//! This module closes the train → checkpoint → load → hot-swap loop
//! (DESIGN.md §6): a [`TrainerPool`] owns named background jobs, each
//! running minibatch SGD on a SELL-family model over the synthetic
//! eq.-(15) regression task. The job's `model_kind` knob selects the
//! family — an ACDC cascade on the batched SoA engine
//! ([`crate::sell::acdc::AcdcCascade::forward_train_pooled`]) by
//! default, or Adaptive Fastfood, low-rank and diagonal-circulant
//! models behind the same [`TrainableModel`] interface. Every
//! `checkpoint_every` steps a job serializes its cascade through the
//! bit-exact [`SellModel`] manifest codec; on convergence (or on demand
//! via [`TrainerPool::promote`]) it loads that manifest into the
//! [`ModelRegistry`], which promotes the new version under live traffic
//! by Arc epoch handoff — in-flight requests finish on the old version,
//! new admissions see the new one, zero requests fail.
//!
//! The paper's central training findings are the pool's defaults: the
//! diagonals initialize to A = D = 1 plus small Gaussian noise (the init
//! that makes deep cascades trainable — Figure 3 / [`DiagInit`]), and
//! depth/learning-rate are first-class per-job knobs.
//!
//! **Batches never mix jobs**: each job owns its dataset, cursor and
//! cascade, and only talks to the rest of the system through checkpoint
//! files and registry loads. Serving-side, the per-(model, version)
//! coordinator invariant of DESIGN.md §5.1 keeps inference batches
//! equally isolated.
//!
//! Job lifecycle (see [`JobState`]):
//!
//! ```text
//!   submit ─▶ Running ⇄ Paused          (pause / resume)
//!                │  │ └────▶ Cancelled  (cancel, from either state)
//!                │  └──────▶ Failed     (diverged loss, I/O error, panic)
//!                └─────────▶ Completed  (converged or step budget spent)
//!   promote: Running/Paused → checkpoint + registry.load at the next
//!            step boundary; Completed → load the final checkpoint now
//! ```
//!
//! The experiment orchestrators ([`orchestrator`]) and SGD machinery
//! ([`sgd`]) live here too — they were `crate::train` before the trainer
//! subsystem absorbed them.
//!
//! ```
//! use acdc::config::{ServeConfig, TrainerConfig};
//! use acdc::metrics::Registry;
//! use acdc::registry::ModelRegistry;
//! use acdc::trainer::{JobSpec, JobState, TrainerPool};
//! use std::sync::Arc;
//! use std::time::Duration;
//!
//! let metrics = Arc::new(Registry::new());
//! let registry = Arc::new(ModelRegistry::new(ServeConfig::default(), Arc::clone(&metrics)));
//! let defaults = TrainerConfig {
//!     checkpoint_dir: std::env::temp_dir()
//!         .join(format!("acdc_doc_{}", std::process::id()))
//!         .display()
//!         .to_string(),
//!     ..Default::default()
//! };
//! let pool = TrainerPool::new(Arc::clone(&registry), metrics, defaults.clone());
//! let spec = JobSpec {
//!     width: 8,
//!     depth: 1,
//!     steps: 40,
//!     batch: 16,
//!     dataset_rows: 64,
//!     lr: 5e-3,
//!     momentum: 0.0,
//!     promote_on_complete: true,
//!     ..JobSpec::from_config(&defaults)
//! };
//! let id = pool.submit("doc-model", spec).unwrap();
//! let status = pool.join(id, Duration::from_secs(120)).expect("job finished");
//! assert_eq!(status.state, JobState::Completed);
//! // The finished job promoted its checkpoint into the registry.
//! assert_eq!(registry.resolve("doc-model").unwrap().version(), 1);
//! pool.shutdown();
//! ```

pub mod model;
pub mod orchestrator;
pub mod sgd;

pub use model::{build_trainable, FamilyTuning, TrainableModel};
pub use orchestrator::{
    CnnTrainer, CnnVariant, EvalResult, FamilyTrainer, Fig3NativeTrainer, Fig3Trainer,
};
pub use sgd::{LossCurve, Momentum, StepDecay};

use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TrainerConfig;
use crate::data::regression::RegressionTask;
use crate::data::BatchCursor;
use crate::metrics::{Counter, FloatGauge, Gauge, Registry};
use crate::registry::{ModelRegistry, SellModel};
use crate::sell::acdc::{AcdcCascade, AcdcGrads};
use crate::sell::init::DiagInit;
use crate::sell::ModelKind;
use crate::trace::log::{self, Field, Level};
use crate::util::rng::Pcg32;

/// Why a trainer operation failed. Maps onto HTTP statuses at the
/// gateway (404 / 409 / 400), mirroring [`crate::registry::RegistryError`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrainerError {
    /// No job with that id.
    NotFound(u64),
    /// The operation conflicts with the job's current state (e.g. resume
    /// on a running job, a second live job for the same model).
    Conflict(String),
    /// Malformed job spec or model name.
    Invalid(String),
}

impl TrainerError {
    /// The HTTP status this error maps to at the gateway.
    pub fn status(&self) -> u16 {
        match self {
            TrainerError::NotFound(_) => 404,
            TrainerError::Conflict(_) => 409,
            TrainerError::Invalid(_) => 400,
        }
    }
}

impl std::fmt::Display for TrainerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainerError::NotFound(id) => write!(f, "unknown job {id}"),
            TrainerError::Conflict(msg) => write!(f, "{msg}"),
            TrainerError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

/// Lifecycle state of one training job (see the module docs for the
/// transition diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Stepping; the only state that consumes CPU.
    Running,
    /// Frozen at a step boundary; resume or cancel to leave.
    Paused,
    /// Converged (loss ≤ first × `target_ratio`) or step budget spent.
    Completed,
    /// Cancelled by an operator; parameters are discarded (checkpoints
    /// already written remain on disk).
    Cancelled,
    /// Diverged loss, checkpoint I/O error, or a panic in the step.
    Failed,
}

impl JobState {
    /// Lowercase wire name (`GET /v1/jobs` payloads and the CLI).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Completed => "completed",
            JobState::Cancelled => "cancelled",
            JobState::Failed => "failed",
        }
    }

    /// Whether the job's thread has exited (no further transitions).
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Completed | JobState::Cancelled | JobState::Failed
        )
    }
}

/// Everything one job needs to run, resolved up front so a bad request
/// fails at submit time (HTTP 400) instead of inside the worker thread.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Which SELL family to train (see [`ModelKind`]).
    pub model_kind: ModelKind,
    /// Width N (power of two for the transform families; low-rank takes
    /// any width ≥ 2).
    pub width: usize,
    /// Cascade depth K (acdc and circulant; ignored by fastfood/lowrank).
    pub depth: usize,
    /// Low-rank factorization rank r (0 = width/2; ignored by the other
    /// families).
    pub rank: usize,
    /// SGD step budget.
    pub steps: usize,
    /// Minibatch rows.
    pub batch: usize,
    /// Base learning rate.
    pub lr: f64,
    /// Momentum coefficient β.
    pub momentum: f64,
    /// lr multiplier applied every `lr_decay_every` steps (1.0 = constant).
    pub lr_decay: f64,
    /// Steps between decays (0 = never).
    pub lr_decay_every: usize,
    /// Diagonal initialization (the paper's identity-plus-noise by default).
    pub init: DiagInit,
    /// §6.2-style nonlinear cascade instead of the linear operator.
    pub nonlinear: bool,
    /// Rows of the generated eq.-(15) regression dataset.
    pub dataset_rows: usize,
    /// Target-noise variance of the dataset.
    pub dataset_noise: f64,
    /// RNG seed (dataset and init).
    pub seed: u64,
    /// Checkpoint cadence in steps (0 = only at promotion/completion).
    pub checkpoint_every: usize,
    /// Convergence target: done when loss ≤ first-loss × this.
    pub target_ratio: f64,
    /// Promote into the registry automatically on completion.
    pub promote_on_complete: bool,
}

impl JobSpec {
    /// A spec carrying the `[trainer]` config defaults.
    pub fn from_config(cfg: &TrainerConfig) -> JobSpec {
        JobSpec {
            // Unknown kinds are rejected by TrainerConfig::validate at
            // startup; the fallback only covers hand-built configs.
            model_kind: ModelKind::parse(&cfg.model_kind).unwrap_or(ModelKind::Acdc),
            width: cfg.width,
            depth: cfg.depth,
            rank: cfg.rank,
            steps: cfg.steps,
            batch: cfg.batch,
            lr: cfg.lr,
            momentum: cfg.momentum,
            lr_decay: cfg.lr_decay,
            lr_decay_every: cfg.lr_decay_every,
            init: DiagInit {
                mean: cfg.init_mean,
                sigma: cfg.init_sigma,
            },
            nonlinear: cfg.nonlinear,
            dataset_rows: cfg.dataset_rows,
            dataset_noise: cfg.dataset_noise,
            seed: cfg.seed,
            checkpoint_every: cfg.checkpoint_every,
            target_ratio: cfg.target_ratio,
            promote_on_complete: cfg.promote_on_complete,
        }
    }

    /// Validate by round-tripping through [`TrainerConfig::validate`] (one
    /// source of truth for the knob ranges).
    pub fn validate(&self) -> Result<(), String> {
        let probe = TrainerConfig {
            model_kind: self.model_kind.as_str().to_string(),
            width: self.width,
            depth: self.depth,
            rank: self.rank,
            steps: self.steps,
            batch: self.batch,
            lr: self.lr,
            momentum: self.momentum,
            lr_decay: self.lr_decay,
            lr_decay_every: self.lr_decay_every,
            init_mean: self.init.mean,
            init_sigma: self.init.sigma,
            nonlinear: self.nonlinear,
            dataset_rows: self.dataset_rows,
            dataset_noise: self.dataset_noise,
            seed: self.seed,
            checkpoint_every: self.checkpoint_every,
            target_ratio: self.target_ratio,
            promote_on_complete: self.promote_on_complete,
            ..Default::default()
        };
        probe.validate()
    }

    /// The low-rank factorization rank this spec resolves to (`rank` with
    /// the 0-means-width/2 default applied).
    pub fn effective_rank(&self) -> usize {
        if self.rank == 0 {
            (self.width / 2).max(1)
        } else {
            self.rank
        }
    }
}

/// Point-in-time snapshot of one job (`GET /v1/jobs` row).
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// Pool-unique job id.
    pub id: u64,
    /// Registry model name the job trains toward.
    pub model: String,
    /// Lifecycle state.
    pub state: JobState,
    /// Steps completed so far.
    pub step: usize,
    /// Step budget.
    pub steps: usize,
    /// Most recent minibatch loss.
    pub loss: f64,
    /// Loss of the first step (the convergence baseline).
    pub first_loss: f64,
    /// Learning rate at the last step.
    pub lr: f64,
    /// Times this job promoted a checkpoint into the registry.
    pub promotions: u64,
    /// Registry version of the most recent promotion, if any.
    pub promoted_version: Option<u64>,
    /// Path of the most recent checkpoint manifest, if any.
    pub last_checkpoint: Option<String>,
    /// Most recent failure: job-fatal when `state == Failed`, or a
    /// non-fatal promotion error (the job keeps its progress — the
    /// checkpoint is on disk — and keeps running).
    pub error: Option<String>,
}

/// Mutable job fields shared between the worker thread and the control
/// surface, guarded by one mutex (the condvar wakes paused workers and
/// `join` waiters).
struct Ctl {
    state: JobState,
    promote_requested: bool,
    step: usize,
    loss: f64,
    first_loss: f64,
    lr: f64,
    promotions: u64,
    promoted_version: Option<u64>,
    last_checkpoint: Option<PathBuf>,
    error: Option<String>,
}

struct JobShared {
    id: u64,
    model: String,
    spec: JobSpec,
    ctl: Mutex<Ctl>,
    cv: Condvar,
    m_step: Arc<Gauge>,
    m_loss: Arc<FloatGauge>,
    m_lr: Arc<FloatGauge>,
    m_promotions: Arc<Counter>,
}

impl JobShared {
    fn status(&self) -> JobStatus {
        let ctl = self.ctl.lock().unwrap();
        JobStatus {
            id: self.id,
            model: self.model.clone(),
            state: ctl.state,
            step: ctl.step,
            steps: self.spec.steps,
            loss: ctl.loss,
            first_loss: ctl.first_loss,
            lr: ctl.lr,
            promotions: ctl.promotions,
            promoted_version: ctl.promoted_version,
            last_checkpoint: ctl.last_checkpoint.as_ref().map(|p| p.display().to_string()),
            error: ctl.error.clone(),
        }
    }
}

struct JobEntry {
    shared: Arc<JobShared>,
    handle: Option<JoinHandle<()>>,
}

struct PoolInner {
    next_id: u64,
    jobs: Vec<JobEntry>,
    /// Set by [`TrainerPool::shutdown`]; submits are refused afterwards so
    /// a straggler request cannot leak a job thread past the drain.
    closed: bool,
}

/// Pool of background training jobs feeding a [`ModelRegistry`]. See the
/// module docs for the lifecycle and a runnable end-to-end example.
pub struct TrainerPool {
    registry: Arc<ModelRegistry>,
    metrics: Arc<Registry>,
    defaults: TrainerConfig,
    inner: Mutex<PoolInner>,
}

impl TrainerPool {
    /// Pool promoting into `registry`, exporting per-job
    /// `trainer.{model}.{step,loss,lr,promotions}` series into `metrics`
    /// (the gateway's shared registry), with `defaults` filling
    /// unspecified job knobs.
    pub fn new(
        registry: Arc<ModelRegistry>,
        metrics: Arc<Registry>,
        defaults: TrainerConfig,
    ) -> TrainerPool {
        TrainerPool {
            registry,
            metrics,
            defaults,
            inner: Mutex::new(PoolInner {
                next_id: 1,
                jobs: Vec::new(),
                closed: false,
            }),
        }
    }

    /// The `[trainer]` defaults jobs inherit.
    pub fn defaults(&self) -> &TrainerConfig {
        &self.defaults
    }

    /// Start a background job training toward registry model `model`.
    /// Returns the job id. Refuses a second live job for the same model
    /// (the per-model metric series and promotion target would collide)
    /// and more than `max_jobs` live jobs total.
    pub fn submit(&self, model: &str, spec: JobSpec) -> Result<u64, TrainerError> {
        crate::registry::validate_name(model).map_err(|e| TrainerError::Invalid(e.to_string()))?;
        // Fail fast instead of training for hours toward a promotion the
        // registry will always refuse (loads under an alias are invalid).
        if self.registry.is_alias(model) {
            return Err(TrainerError::Conflict(format!(
                "'{model}' is an alias; train under the model name instead"
            )));
        }
        spec.validate().map_err(TrainerError::Invalid)?;
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(TrainerError::Conflict(
                "trainer pool is shut down".to_string(),
            ));
        }
        prune_terminal(&mut inner);
        let live = |e: &JobEntry| !e.shared.ctl.lock().unwrap().state.is_terminal();
        if inner.jobs.iter().any(|e| e.shared.model == model && live(e)) {
            return Err(TrainerError::Conflict(format!(
                "model '{model}' already has a live training job"
            )));
        }
        if inner.jobs.iter().filter(|e| live(e)).count() >= self.defaults.max_jobs {
            return Err(TrainerError::Conflict(format!(
                "trainer pool is full ({} live jobs)",
                self.defaults.max_jobs
            )));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let steps = spec.steps;
        let shared = Arc::new(JobShared {
            id,
            model: model.to_string(),
            spec,
            ctl: Mutex::new(Ctl {
                state: JobState::Running,
                promote_requested: false,
                step: 0,
                loss: f64::NAN,
                first_loss: f64::NAN,
                lr: 0.0,
                promotions: 0,
                promoted_version: None,
                last_checkpoint: None,
                error: None,
            }),
            cv: Condvar::new(),
            m_step: self.metrics.gauge(&format!("trainer.{model}.step")),
            m_loss: self.metrics.float_gauge(&format!("trainer.{model}.loss")),
            m_lr: self.metrics.float_gauge(&format!("trainer.{model}.lr")),
            m_promotions: self.metrics.counter(&format!("trainer.{model}.promotions")),
        });
        let worker_shared = Arc::clone(&shared);
        let registry = Arc::clone(&self.registry);
        let ckpt_dir = PathBuf::from(&self.defaults.checkpoint_dir);
        let handle = std::thread::Builder::new()
            .name(format!("acdc-trainer-{id}"))
            .spawn(move || run_job(worker_shared, registry, ckpt_dir))
            .map_err(|e| TrainerError::Invalid(format!("spawn job thread: {e}")))?;
        inner.jobs.push(JobEntry {
            shared,
            handle: Some(handle),
        });
        log::event(
            Level::Info,
            "trainer",
            "job_submitted",
            0,
            &[
                ("job", Field::U64(id)),
                ("model", Field::Str(model)),
                ("steps", Field::U64(steps as u64)),
            ],
        );
        Ok(id)
    }

    fn find(&self, id: u64) -> Result<Arc<JobShared>, TrainerError> {
        self.inner
            .lock()
            .unwrap()
            .jobs
            .iter()
            .find(|e| e.shared.id == id)
            .map(|e| Arc::clone(&e.shared))
            .ok_or(TrainerError::NotFound(id))
    }

    /// Freeze a running job at its next step boundary.
    pub fn pause(&self, id: u64) -> Result<(), TrainerError> {
        let shared = self.find(id)?;
        let mut ctl = shared.ctl.lock().unwrap();
        match ctl.state {
            JobState::Running => {
                ctl.state = JobState::Paused;
                shared.cv.notify_all();
                Ok(())
            }
            other => Err(TrainerError::Conflict(format!(
                "cannot pause a {} job",
                other.as_str()
            ))),
        }
    }

    /// Resume a paused job.
    pub fn resume(&self, id: u64) -> Result<(), TrainerError> {
        let shared = self.find(id)?;
        let mut ctl = shared.ctl.lock().unwrap();
        match ctl.state {
            JobState::Paused => {
                ctl.state = JobState::Running;
                shared.cv.notify_all();
                Ok(())
            }
            other => Err(TrainerError::Conflict(format!(
                "cannot resume a {} job",
                other.as_str()
            ))),
        }
    }

    /// Cancel a running or paused job; its thread exits at the next step
    /// boundary.
    pub fn cancel(&self, id: u64) -> Result<(), TrainerError> {
        let shared = self.find(id)?;
        let mut ctl = shared.ctl.lock().unwrap();
        match ctl.state {
            JobState::Running | JobState::Paused => {
                ctl.state = JobState::Cancelled;
                shared.cv.notify_all();
                Ok(())
            }
            other => Err(TrainerError::Conflict(format!(
                "cannot cancel a {} job",
                other.as_str()
            ))),
        }
    }

    /// Promote the job's current parameters into the registry. A live job
    /// checkpoints and loads at its next step boundary; a completed job's
    /// final checkpoint is loaded immediately (hot-swapping whatever
    /// version is currently serving).
    pub fn promote(&self, id: u64) -> Result<(), TrainerError> {
        let shared = self.find(id)?;
        let mut ctl = shared.ctl.lock().unwrap();
        match ctl.state {
            JobState::Running | JobState::Paused => {
                ctl.promote_requested = true;
                shared.cv.notify_all();
                Ok(())
            }
            JobState::Completed => {
                let path = ctl.last_checkpoint.clone().ok_or_else(|| {
                    TrainerError::Conflict("completed job has no checkpoint".to_string())
                })?;
                drop(ctl);
                let version = self
                    .registry
                    .load_path(&shared.model, &path, None)
                    .map_err(|e| TrainerError::Conflict(e.to_string()))?;
                let mut ctl = shared.ctl.lock().unwrap();
                ctl.promotions += 1;
                ctl.promoted_version = Some(version);
                shared.m_promotions.inc();
                Ok(())
            }
            other => Err(TrainerError::Conflict(format!(
                "cannot promote a {} job",
                other.as_str()
            ))),
        }
    }

    /// Snapshot of one job.
    pub fn status(&self, id: u64) -> Result<JobStatus, TrainerError> {
        Ok(self.find(id)?.status())
    }

    /// Snapshot of every job, ordered by id (submission order). History
    /// is bounded: terminal jobs beyond the most recent
    /// [`MAX_TERMINAL_KEPT`] are pruned when new jobs are submitted.
    pub fn list(&self) -> Vec<JobStatus> {
        self.inner
            .lock()
            .unwrap()
            .jobs
            .iter()
            .map(|e| e.shared.status())
            .collect()
    }

    /// Block until job `id` reaches a terminal state (or `timeout`);
    /// returns the final status, or `None` on timeout / unknown id.
    pub fn join(&self, id: u64, timeout: Duration) -> Option<JobStatus> {
        let shared = self.find(id).ok()?;
        let deadline = Instant::now() + timeout;
        let mut ctl = shared.ctl.lock().unwrap();
        while !ctl.state.is_terminal() {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = shared.cv.wait_timeout(ctl, deadline - now).unwrap();
            ctl = guard;
        }
        drop(ctl);
        Some(shared.status())
    }

    /// Cancel every live job and join all job threads. Idempotent; called
    /// by the gateway on drain.
    pub fn shutdown(&self) {
        let handles: Vec<(Arc<JobShared>, Option<JoinHandle<()>>)> = {
            let mut inner = self.inner.lock().unwrap();
            inner.closed = true;
            inner
                .jobs
                .iter_mut()
                .map(|e| (Arc::clone(&e.shared), e.handle.take()))
                .collect()
        };
        for (shared, _) in &handles {
            let mut ctl = shared.ctl.lock().unwrap();
            if !ctl.state.is_terminal() {
                ctl.state = JobState::Cancelled;
            }
            shared.cv.notify_all();
        }
        for (_, handle) in handles {
            if let Some(h) = handle {
                let _ = h.join();
            }
        }
    }
}

impl Drop for TrainerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Terminal job entries kept as history; older ones are pruned at
/// submit time so a long-running gateway with periodic retraining does
/// not grow its job list (and `GET /v1/jobs` payloads) without bound.
pub const MAX_TERMINAL_KEPT: usize = 64;

/// Drop the oldest terminal entries beyond [`MAX_TERMINAL_KEPT`],
/// joining their (already-exited) threads.
fn prune_terminal(inner: &mut PoolInner) {
    let is_terminal = |e: &JobEntry| e.shared.ctl.lock().unwrap().state.is_terminal();
    let mut terminal = inner.jobs.iter().filter(|e| is_terminal(e)).count();
    let mut i = 0;
    while terminal > MAX_TERMINAL_KEPT && i < inner.jobs.len() {
        if is_terminal(&inner.jobs[i]) {
            let mut e = inner.jobs.remove(i);
            if let Some(h) = e.handle.take() {
                let _ = h.join();
            }
            terminal -= 1;
        } else {
            i += 1;
        }
    }
}

/// What the worker should do next, decided at each step boundary.
enum Directive {
    Continue,
    Promote,
    Stop,
}

/// Observe pause/cancel/promote requests; blocks while paused.
fn control_point(shared: &JobShared) -> Directive {
    let mut ctl = shared.ctl.lock().unwrap();
    loop {
        match ctl.state {
            JobState::Cancelled => return Directive::Stop,
            JobState::Paused => {
                if ctl.promote_requested {
                    ctl.promote_requested = false;
                    return Directive::Promote;
                }
                ctl = shared.cv.wait(ctl).unwrap();
            }
            _ => {
                if ctl.promote_requested {
                    ctl.promote_requested = false;
                    return Directive::Promote;
                }
                return Directive::Continue;
            }
        }
    }
}

/// Set a terminal state (unless the operator already cancelled) and wake
/// `join` waiters. A recorded non-fatal error (failed promotion) is kept
/// unless a fatal one replaces it.
fn finish(shared: &JobShared, state: JobState, error: Option<String>) {
    let mut ctl = shared.ctl.lock().unwrap();
    if ctl.state != JobState::Cancelled {
        ctl.state = state;
        if error.is_some() {
            ctl.error = error;
        }
    }
    let (final_state, step, err) = (ctl.state, ctl.step, ctl.error.clone());
    drop(ctl);
    let base = [
        ("job", Field::U64(shared.id)),
        ("model", Field::Str(&shared.model)),
        ("state", Field::Str(final_state.as_str())),
        ("step", Field::U64(step as u64)),
    ];
    match &err {
        // A Failed job (or a kept non-fatal promotion error) carries its
        // message; clean exits stay at info so default logging shows the
        // full submitted → finished arc without per-step noise.
        Some(e) => {
            let level = if final_state == JobState::Failed {
                Level::Error
            } else {
                Level::Info
            };
            let mut fields = base.to_vec();
            fields.push(("error", Field::Str(e)));
            log::event(level, "trainer", "job_finished", 0, &fields);
        }
        None => log::event(Level::Info, "trainer", "job_finished", 0, &base),
    }
    shared.cv.notify_all();
}

/// Worker-thread entry: run the training loop, downgrading panics to a
/// `Failed` state so a bug in one job can never take the pool down.
fn run_job(shared: Arc<JobShared>, registry: Arc<ModelRegistry>, ckpt_dir: PathBuf) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        train_loop(&shared, &registry, &ckpt_dir)
    }));
    match result {
        Ok(Ok(completed)) => {
            if completed {
                finish(&shared, JobState::Completed, None);
            } else {
                // Cancelled mid-run: finish() preserves the Cancelled state.
                finish(&shared, JobState::Cancelled, None);
            }
        }
        Ok(Err(msg)) => finish(&shared, JobState::Failed, Some(msg)),
        Err(_) => finish(
            &shared,
            JobState::Failed,
            Some("training step panicked".to_string()),
        ),
    }
}

/// Write the model as a bit-exact checkpoint manifest.
fn write_checkpoint(
    dir: &Path,
    shared: &JobShared,
    step: usize,
    model: &SellModel,
) -> Result<PathBuf, String> {
    std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
    let path = dir.join(format!("{}-job{}-step{}.ckpt", shared.model, shared.id, step));
    model.to_checkpoint()?.save(&path)?;
    let mut ctl = shared.ctl.lock().unwrap();
    ctl.last_checkpoint = Some(path.clone());
    Ok(path)
}

/// Checkpoint then load into the registry: the full train → manifest →
/// hot-swap loop, not an in-memory shortcut, so every promotion exercises
/// the same codec path serving restarts depend on.
fn promote(
    dir: &Path,
    shared: &JobShared,
    registry: &ModelRegistry,
    step: usize,
    model: &SellModel,
) -> Result<u64, String> {
    let path = write_checkpoint(dir, shared, step, model)?;
    let version = registry
        .load_path(&shared.model, &path, None)
        .map_err(|e| format!("promote '{}': {e}", shared.model))?;
    {
        let mut ctl = shared.ctl.lock().unwrap();
        ctl.promotions += 1;
        ctl.promoted_version = Some(version);
    }
    shared.m_promotions.inc();
    log::event(
        Level::Info,
        "trainer",
        "job_promoted",
        0,
        &[
            ("job", Field::U64(shared.id)),
            ("model", Field::Str(&shared.model)),
            ("version", Field::U64(version)),
            ("step", Field::U64(step as u64)),
        ],
    );
    Ok(version)
}

/// Momentum SGD update over every layer's (a, d, bias) banks — the
/// trainer's optimizer step, shared with the `acdc bench-trainer`
/// throughput sweep. Bias gradients are zeroed first when the cascade
/// doesn't train biases, so the velocity buffers stay zero too.
/// `momentum` must hold `3 × depth` buffers of width N (see
/// [`Momentum::new`]), ordered (a, d, bias) per layer.
pub fn apply_momentum_update(
    cascade: &mut AcdcCascade,
    grads: &mut [AcdcGrads],
    momentum: &mut Momentum,
    lr: f32,
) {
    if !cascade.train_bias {
        for g in grads.iter_mut() {
            g.bias.fill(0.0);
        }
    }
    let mut params: Vec<&mut [f32]> = Vec::with_capacity(3 * cascade.layers.len());
    for layer in cascade.layers.iter_mut() {
        let crate::sell::acdc::AcdcLayer { a, d, bias, .. } = layer;
        params.push(a.as_mut_slice());
        params.push(d.as_mut_slice());
        params.push(bias.as_mut_slice());
    }
    let gs: Vec<&[f32]> = grads
        .iter()
        .flat_map(|g| [g.a.as_slice(), g.d.as_slice(), g.bias.as_slice()])
        .collect();
    momentum.apply(&mut params, &gs, lr);
}

/// The SGD loop. Returns `Ok(true)` on completion (converged or budget
/// spent), `Ok(false)` when cancelled, `Err` on failure.
fn train_loop(
    shared: &JobShared,
    registry: &ModelRegistry,
    ckpt_dir: &Path,
) -> Result<bool, String> {
    let spec = shared.spec.clone();
    let mut rng = Pcg32::seeded(spec.seed);
    let task = RegressionTask::generate(
        spec.dataset_rows,
        spec.width,
        spec.dataset_noise,
        spec.seed,
    );
    let mut model = build_trainable(&spec, &mut rng);
    let mut momentum = Momentum::new(spec.momentum as f32, &model.param_sizes());
    let schedule = if spec.lr_decay_every == 0 || spec.lr_decay >= 1.0 {
        StepDecay::constant(spec.lr)
    } else {
        StepDecay::new(spec.lr, spec.lr_decay, spec.lr_decay_every)
    };
    let mut cursor = BatchCursor::new(task.rows(), spec.batch);
    let pool = crate::util::threadpool::global();
    let mut first_loss = f64::NAN;
    let mut last_step = 0usize;

    for step in 0..spec.steps {
        // Step boundary: honour pause/cancel/promote before touching data.
        loop {
            match control_point(shared) {
                Directive::Continue => break,
                Directive::Stop => return Ok(false),
                Directive::Promote => {
                    // A failed promotion (e.g. the model name turned into
                    // an alias) must not kill hours of training: record
                    // it and keep stepping — the checkpoint is on disk.
                    if let Err(e) = promote(ckpt_dir, shared, registry, step, &model.snapshot()) {
                        shared.ctl.lock().unwrap().error = Some(e);
                    }
                }
            }
        }

        let idx = cursor.next_indices();
        let (bx, by) = task.gather(&idx);
        // Family-generic hot path: ACDC rides the pooled batched SoA
        // engine (bit-identical to the serial engine, property-pinned);
        // the other families use their batched backward kernels.
        let pred = model.forward_train(&bx, pool);
        let diff = pred.sub(&by);
        let loss = diff.data().iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            / spec.batch as f64;
        if !loss.is_finite() {
            return Err(format!("loss diverged at step {step}"));
        }
        let mut g = diff;
        g.scale(2.0 / spec.batch as f32);
        let lr = schedule.lr_at(step) as f32;
        model.backward_step(&g, &mut momentum, lr);

        if first_loss.is_nan() {
            first_loss = loss;
        }
        last_step = step + 1;
        {
            let mut ctl = shared.ctl.lock().unwrap();
            ctl.step = last_step;
            ctl.loss = loss;
            ctl.first_loss = first_loss;
            ctl.lr = lr as f64;
        }
        shared.m_step.set(last_step as u64);
        shared.m_loss.set(loss);
        shared.m_lr.set(lr as f64);

        if spec.checkpoint_every > 0 && last_step % spec.checkpoint_every == 0 {
            write_checkpoint(ckpt_dir, shared, last_step, &model.snapshot())?;
        }
        if loss <= first_loss * spec.target_ratio {
            break;
        }
    }

    // Completion boundary: a cancel that landed during the last step must
    // win — a cancelled job neither checkpoints nor promotes. The pending
    // promote flag is taken under the same lock so an acknowledged
    // on-demand promote folds into the final promotion instead of being
    // dropped on the floor.
    let (cancelled, promote_pending) = {
        let mut ctl = shared.ctl.lock().unwrap();
        (
            ctl.state == JobState::Cancelled,
            std::mem::take(&mut ctl.promote_requested),
        )
    };
    if cancelled {
        return Ok(false);
    }
    // Final checkpoint always exists, so promote-after-completion works
    // even with checkpoint_every = 0.
    let snapshot = model.snapshot();
    write_checkpoint(ckpt_dir, shared, last_step, &snapshot)?;
    if spec.promote_on_complete || promote_pending {
        if let Err(e) = promote(ckpt_dir, shared, registry, last_step, &snapshot) {
            shared.ctl.lock().unwrap().error = Some(e);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::tensor::Tensor;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("acdc_trainer_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn template() -> ServeConfig {
        ServeConfig {
            buckets: vec![1, 4],
            max_wait_us: 200,
            workers: 1,
            queue_cap: 64,
            ..Default::default()
        }
    }

    fn pool_with(tag: &str, defaults: TrainerConfig) -> (TrainerPool, Arc<ModelRegistry>, PathBuf) {
        let dir = temp_dir(tag);
        let metrics = Arc::new(Registry::new());
        let registry = Arc::new(ModelRegistry::new(template(), Arc::clone(&metrics)));
        let defaults = TrainerConfig {
            checkpoint_dir: dir.display().to_string(),
            ..defaults
        };
        (
            TrainerPool::new(Arc::clone(&registry), metrics, defaults),
            registry,
            dir,
        )
    }

    /// A spec that converges in well under a second: shallow linear
    /// cascade, small task, identity init.
    fn quick_spec(defaults: &TrainerConfig) -> JobSpec {
        JobSpec {
            width: 16,
            depth: 2,
            steps: 1_000,
            batch: 32,
            dataset_rows: 256,
            lr: 5e-3,
            momentum: 0.0,
            seed: 1,
            checkpoint_every: 0,
            target_ratio: 0.2,
            ..JobSpec::from_config(defaults)
        }
    }

    /// A spec that keeps stepping long enough to exercise controls.
    fn long_spec(defaults: &TrainerConfig) -> JobSpec {
        JobSpec {
            steps: 5_000_000,
            target_ratio: 1e-12,
            promote_on_complete: false,
            ..quick_spec(defaults)
        }
    }

    #[test]
    fn paper_init_statistics_pinned() {
        // The paper's working init: A = D = 1 + small Gaussian noise,
        // biases exactly zero. Pin the sample statistics the trainer's
        // default spec produces.
        let defaults = TrainerConfig::default();
        let spec = JobSpec::from_config(&defaults);
        assert_eq!(spec.init.mean, 1.0);
        assert_eq!(spec.init.sigma, 0.1);
        let mut rng = Pcg32::seeded(7);
        let cascade = AcdcCascade::linear(256, 8, spec.init, &mut rng);
        let mut diag = Vec::new();
        for layer in &cascade.layers {
            diag.extend_from_slice(&layer.a);
            diag.extend_from_slice(&layer.d);
            assert!(layer.bias.iter().all(|&b| b == 0.0), "biases start at 0");
        }
        let n = diag.len() as f64; // 2 * 8 * 256 = 4096 samples
        let mean = diag.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = diag.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
        assert!((var.sqrt() - 0.1).abs() < 0.01, "std {}", var.sqrt());
    }

    #[test]
    fn job_trains_converges_and_promotes() {
        let (pool, registry, dir) = pool_with("converge", TrainerConfig::default());
        let spec = quick_spec(pool.defaults());
        let id = pool.submit("m", spec).unwrap();
        let status = pool.join(id, Duration::from_secs(120)).expect("join");
        assert_eq!(status.state, JobState::Completed, "{:?}", status.error);
        assert!(
            status.loss <= status.first_loss * 0.2,
            "loss {} vs first {}",
            status.loss,
            status.first_loss
        );
        // Auto-promotion loaded version 1 into the registry.
        assert_eq!(status.promoted_version, Some(1));
        assert_eq!(status.promotions, 1);
        let handle = registry.resolve("m").unwrap();
        assert_eq!((handle.version(), handle.width()), (1, 16));
        // The checkpoint on disk is the same bit-exact manifest.
        let path = PathBuf::from(status.last_checkpoint.unwrap());
        let model =
            SellModel::from_checkpoint(&crate::checkpoint::Checkpoint::load(&path).unwrap())
                .unwrap();
        let mut rng = Pcg32::seeded(9);
        let x = rng.normal_vec(16, 0.0, 1.0);
        let got = handle.infer(x.clone(), Duration::from_secs(10)).unwrap();
        let want = model.forward(&Tensor::from_vec(&[1, 16], x));
        for (g, w) in got.iter().zip(want.data()) {
            assert_eq!(g.to_bits(), w.to_bits(), "registry infer vs manifest");
        }
        drop(handle);
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_family_converges_and_promotes_bit_exact() {
        // The convergence pass/fail knobs are per family, not the ACDC
        // recipe everywhere: fastfood wants a smaller learning rate with
        // momentum, and a depth-1 circulant floors well above the target
        // ratio (the rank-1 limit). FamilyTuning carries each family's
        // mirror-validated preset; the assertions below are identical
        // across kinds.
        for kind in ModelKind::ALL {
            let (pool, registry, dir) =
                pool_with(&format!("family_{kind}"), TrainerConfig::default());
            let spec = FamilyTuning::quick_spec(kind, pool.defaults());
            let width = spec.width;
            let id = pool.submit("fam", spec).unwrap();
            let status = pool.join(id, Duration::from_secs(300)).expect("join");
            assert_eq!(status.state, JobState::Completed, "{kind}: {:?}", status.error);
            assert!(
                status.loss <= status.first_loss * 0.2,
                "{kind}: loss {} vs first {}",
                status.loss,
                status.first_loss
            );
            assert_eq!(
                (status.promotions, status.promoted_version),
                (1, Some(1)),
                "{kind}"
            );
            let handle = registry.resolve("fam").unwrap();
            assert_eq!(handle.width(), width, "{kind}");
            // The promoted version serves bit-exactly what the on-disk
            // manifest deserializes to, for every family's codec.
            let path = PathBuf::from(status.last_checkpoint.unwrap());
            let model =
                SellModel::from_checkpoint(&crate::checkpoint::Checkpoint::load(&path).unwrap())
                    .unwrap();
            assert_eq!(model.kind(), kind.as_str());
            let mut rng = Pcg32::seeded(11);
            let x = rng.normal_vec(width, 0.0, 1.0);
            let got = handle.infer(x.clone(), Duration::from_secs(10)).unwrap();
            let want = model.forward(&Tensor::from_vec(&[1, width], x));
            for (g, w) in got.iter().zip(want.data()) {
                assert_eq!(g.to_bits(), w.to_bits(), "{kind}: registry infer vs manifest");
            }
            drop(handle);
            pool.shutdown();
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn family_spec_validation_round_trips_config_rules() {
        let defaults = TrainerConfig::default();
        let base = JobSpec::from_config(&defaults);
        assert_eq!(base.model_kind, ModelKind::Acdc);
        // Transform families require pow2 widths; lowrank is exempt but
        // caps its rank at the width.
        let ff = JobSpec {
            model_kind: ModelKind::Fastfood,
            width: 48,
            ..base.clone()
        };
        assert!(ff.validate().is_err());
        let lr_ok = JobSpec {
            model_kind: ModelKind::LowRank,
            width: 48,
            rank: 12,
            ..base.clone()
        };
        assert!(lr_ok.validate().is_ok());
        assert_eq!(lr_ok.effective_rank(), 12);
        let lr_bad = JobSpec {
            model_kind: ModelKind::LowRank,
            width: 32,
            rank: 64,
            ..base.clone()
        };
        assert!(lr_bad.validate().is_err());
        // rank 0 resolves to width/2.
        let auto = JobSpec {
            width: 16,
            rank: 0,
            ..base
        };
        assert_eq!(auto.effective_rank(), 8);
    }

    #[test]
    fn pause_resume_cancel_state_machine() {
        let (pool, _registry, dir) = pool_with("ctl", TrainerConfig::default());
        let id = pool.submit("m", long_spec(pool.defaults())).unwrap();
        // Pause freezes the step counter (allow the in-flight step).
        pool.pause(id).unwrap();
        let s1 = pool.status(id).unwrap();
        assert_eq!(s1.state, JobState::Paused);
        std::thread::sleep(Duration::from_millis(120));
        let s2 = pool.status(id).unwrap();
        assert!(
            s2.step <= s1.step + 1,
            "paused job kept stepping: {} -> {}",
            s1.step,
            s2.step
        );
        assert!(pool.pause(id).is_err(), "pause while paused conflicts");
        // Resume makes progress again.
        pool.resume(id).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if pool.status(id).unwrap().step > s2.step + 1 {
                break;
            }
            assert!(Instant::now() < deadline, "no progress after resume");
            std::thread::sleep(Duration::from_millis(10));
        }
        // Cancel terminates.
        pool.cancel(id).unwrap();
        let status = pool.join(id, Duration::from_secs(30)).expect("join");
        assert_eq!(status.state, JobState::Cancelled);
        assert!(pool.resume(id).is_err(), "resume on terminal conflicts");
        assert!(pool.cancel(id).is_err(), "cancel on terminal conflicts");
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn on_demand_promotion_loads_registry_mid_run() {
        let (pool, registry, dir) = pool_with("promote", TrainerConfig::default());
        let id = pool.submit("m", long_spec(pool.defaults())).unwrap();
        // Let it take a few steps, then promote mid-run.
        let deadline = Instant::now() + Duration::from_secs(30);
        while pool.status(id).unwrap().step < 5 {
            assert!(Instant::now() < deadline, "job made no progress");
            std::thread::sleep(Duration::from_millis(5));
        }
        pool.promote(id).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let s = pool.status(id).unwrap();
            if s.promotions >= 1 {
                assert_eq!(s.promoted_version, Some(1));
                break;
            }
            assert!(Instant::now() < deadline, "promotion never happened");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(registry.resolve("m").unwrap().version(), 1);
        // A second promotion hot-swaps version 2.
        pool.promote(id).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        while pool.status(id).unwrap().promotions < 2 {
            assert!(Instant::now() < deadline, "second promotion never happened");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(registry.resolve("m").unwrap().version(), 2);
        pool.cancel(id).unwrap();
        pool.join(id, Duration::from_secs(30)).unwrap();
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submit_refuses_alias_names_up_front() {
        // Training toward an alias would fail at every promotion (the
        // registry refuses loads under alias names) — refuse at submit.
        let (pool, registry, dir) = pool_with("alias", TrainerConfig::default());
        let mut rng = Pcg32::seeded(3);
        registry
            .load(
                "real",
                SellModel::Acdc(AcdcCascade::linear(8, 1, DiagInit::IDENTITY, &mut rng)),
                None,
            )
            .unwrap();
        registry.alias("prod", "real").unwrap();
        match pool.submit("prod", quick_spec(pool.defaults())).unwrap_err() {
            TrainerError::Conflict(msg) => assert!(msg.contains("alias"), "{msg}"),
            other => panic!("expected Conflict, got {other:?}"),
        }
        // The model name itself is fine.
        let id = pool.submit("real", long_spec(pool.defaults())).unwrap();
        pool.cancel(id).unwrap();
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submit_guards_duplicates_capacity_and_bad_specs() {
        let defaults = TrainerConfig {
            max_jobs: 2,
            ..TrainerConfig::default()
        };
        let (pool, _registry, dir) = pool_with("guards", defaults);
        let long = long_spec(pool.defaults());
        let id = pool.submit("m", long.clone()).unwrap();
        // Same model, live job → 409.
        match pool.submit("m", long.clone()).unwrap_err() {
            TrainerError::Conflict(msg) => assert!(msg.contains("live"), "{msg}"),
            other => panic!("expected Conflict, got {other:?}"),
        }
        // Pool capacity → 409.
        let id2 = pool.submit("m2", long.clone()).unwrap();
        assert!(matches!(
            pool.submit("m3", long.clone()).unwrap_err(),
            TrainerError::Conflict(_)
        ));
        // Bad name / bad spec → 400.
        assert!(matches!(
            pool.submit("has space", long.clone()).unwrap_err(),
            TrainerError::Invalid(_)
        ));
        let bad = JobSpec {
            width: 48,
            ..long.clone()
        };
        assert!(matches!(
            pool.submit("m3", bad).unwrap_err(),
            TrainerError::Invalid(_)
        ));
        // Unknown job id → 404.
        assert!(matches!(
            pool.pause(999).unwrap_err(),
            TrainerError::NotFound(999)
        ));
        pool.cancel(id).unwrap();
        pool.cancel(id2).unwrap();
        pool.join(id, Duration::from_secs(30)).unwrap();
        pool.join(id2, Duration::from_secs(30)).unwrap();
        // Terminal jobs free their model name for resubmission.
        let id3 = pool.submit("m", long).unwrap();
        pool.cancel(id3).unwrap();
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn per_job_metric_series_exported() {
        let dir = temp_dir("metrics");
        let metrics = Arc::new(Registry::new());
        let registry = Arc::new(ModelRegistry::new(template(), Arc::clone(&metrics)));
        let defaults = TrainerConfig {
            checkpoint_dir: dir.display().to_string(),
            ..TrainerConfig::default()
        };
        let pool = TrainerPool::new(registry, Arc::clone(&metrics), defaults);
        let id = pool.submit("m", quick_spec(pool.defaults())).unwrap();
        let status = pool.join(id, Duration::from_secs(120)).expect("join");
        assert_eq!(status.state, JobState::Completed, "{:?}", status.error);
        assert_eq!(metrics.gauge("trainer.m.step").get(), status.step as u64);
        assert_eq!(metrics.float_gauge("trainer.m.loss").get(), status.loss);
        assert!(metrics.float_gauge("trainer.m.lr").get() > 0.0);
        assert_eq!(metrics.counter("trainer.m.promotions").get(), 1);
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
