//! Training orchestrators: drive the AOT train-step artifacts (and the
//! native reference implementations) over the synthetic workloads.
//!
//! Three experiments:
//! * **Figure 3** — `Fig3Trainer` fits an `ACDC_K` cascade (or the dense
//!   baseline) to the eq. (15) regression, via the `fig3_step_k{K}` /
//!   `fig3_dense_step` artifacts; `Fig3NativeTrainer` is the pure-rust
//!   cross-check.
//! * **Table 1 / E6** — `CnnTrainer` trains MiniCaffeNet (ACDC or dense
//!   FC variant) on the synthimg corpus via the `cnn_*_train_step`
//!   artifacts, with held-out evaluation through `cnn_*_eval`.
//! * **Families grid** — `FamilyTrainer` runs any [`TrainableModel`]
//!   family through the same minibatch-SGD loop, for the
//!   `bench-families` params × MSE comparison.

use crate::checkpoint::Checkpoint;
use crate::data::regression::RegressionTask;
use crate::data::synthimg::ImageCorpus;
use crate::data::BatchCursor;
use crate::registry::SellModel;
use crate::runtime::values::HostValue;
use crate::runtime::Engine;
use crate::sell::acdc::AcdcCascade;
use crate::sell::init::DiagInit;
use crate::tensor::Tensor;
use crate::trainer::model::{build_trainable, TrainableModel};
use crate::trainer::sgd::{LossCurve, Momentum, StepDecay};
use crate::trainer::JobSpec;
use crate::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// Figure 3: artifact-driven ACDC_K regression
// ---------------------------------------------------------------------------

/// Drives `fig3_step_k{K}` (or `fig3_dense_step` when `k == 0`).
pub struct Fig3Trainer<'e> {
    engine: &'e Engine,
    /// Cascade depth (0 = dense baseline).
    pub k: usize,
    /// Operator width from the artifact's tags.
    pub n: usize,
    /// Minibatch size from the artifact's tags.
    pub batch: usize,
}

impl<'e> Fig3Trainer<'e> {
    /// Bind to the depth-K train-step artifact.
    pub fn new(engine: &'e Engine, k: usize) -> Result<Fig3Trainer<'e>, String> {
        let name = if k == 0 {
            "fig3_dense_step".to_string()
        } else {
            format!("fig3_step_k{k}")
        };
        let meta = engine
            .manifest()
            .get(&name)
            .ok_or_else(|| format!("artifact '{name}' not in manifest"))?;
        let n = meta.tag_usize("n").ok_or("missing n tag")?;
        let batch = meta.tag_usize("batch").ok_or("missing batch tag")?;
        Ok(Fig3Trainer {
            engine,
            k,
            n,
            batch,
        })
    }

    /// Run SGD for `steps` minibatch steps; returns the loss curve.
    pub fn run(
        &self,
        task: &RegressionTask,
        init: DiagInit,
        steps: usize,
        schedule: &StepDecay,
        seed: u64,
    ) -> Result<LossCurve, String> {
        assert_eq!(task.n(), self.n, "task width vs artifact width");
        let mut rng = Pcg32::seeded(seed);
        let name = if self.k == 0 {
            "fig3_dense_step".to_string()
        } else {
            format!("fig3_step_k{}", self.k)
        };
        let art = self.engine.load(&name)?;
        let mut cursor = BatchCursor::new(task.rows(), self.batch);
        let label = if self.k == 0 {
            "dense".to_string()
        } else {
            format!("ACDC_{} init {}", self.k, init.label())
        };
        let mut curve = LossCurve::new(&label);

        // Parameter bank(s).
        let mut params: Vec<HostValue> = if self.k == 0 {
            vec![HostValue::F32 {
                shape: vec![self.n, self.n],
                data: vec![0.0; self.n * self.n],
            }]
        } else {
            vec![
                HostValue::F32 {
                    shape: vec![self.k, self.n],
                    data: init.sample(self.k * self.n, &mut rng),
                },
                HostValue::F32 {
                    shape: vec![self.k, self.n],
                    data: init.sample(self.k * self.n, &mut rng),
                },
            ]
        };

        for step in 0..steps {
            let idx = cursor.next_indices();
            let (bx, by) = task.gather(&idx);
            let lr = schedule.lr_at(step) as f32;
            let mut inputs = params.clone();
            inputs.push(HostValue::from_tensor(&bx));
            inputs.push(HostValue::from_tensor(&by));
            inputs.push(HostValue::scalar_f32(lr));
            let out = art.call(&inputs)?;
            // outputs: params... , loss
            let loss = out.last().unwrap().scalar();
            if !loss.is_finite() {
                curve.push(step, loss);
                return Ok(curve); // diverged — record and stop (Fig 3 right panel!)
            }
            params = out[..out.len() - 1].to_vec();
            curve.push(step, loss);
        }
        Ok(curve)
    }
}

/// Pure-rust Figure-3 trainer (cross-checks the artifact path and runs
/// without artifacts).
pub struct Fig3NativeTrainer {
    /// The cascade being trained.
    pub cascade: AcdcCascade,
}

impl Fig3NativeTrainer {
    /// Fresh linear cascade with the given init.
    pub fn new(n: usize, k: usize, init: DiagInit, seed: u64) -> Fig3NativeTrainer {
        let mut rng = Pcg32::seeded(seed);
        Fig3NativeTrainer {
            cascade: AcdcCascade::linear(n, k, init, &mut rng),
        }
    }

    /// Run SGD for `steps` minibatch steps; returns the loss curve.
    pub fn run(
        &mut self,
        task: &RegressionTask,
        steps: usize,
        batch: usize,
        schedule: &StepDecay,
    ) -> LossCurve {
        let mut cursor = BatchCursor::new(task.rows(), batch);
        let mut curve = LossCurve::new(&format!("native ACDC_{}", self.cascade.k()));
        // Pooled batched engine, like the trainer's hot path —
        // bit-identical to the serial sweep (property-pinned).
        let pool = crate::util::threadpool::global();
        for step in 0..steps {
            let idx = cursor.next_indices();
            let (bx, by) = task.gather(&idx);
            let (pred, cache) = self.cascade.forward_train_pooled(&bx, pool);
            let diff = pred.sub(&by);
            let loss = diff.data().iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
                / batch as f64;
            let mut g = diff;
            g.scale(2.0 / batch as f32);
            let (_, grads) = self.cascade.backward(&cache, &g);
            self.cascade.sgd_step(&grads, schedule.lr_at(step) as f32);
            curve.push(step, loss);
            if !loss.is_finite() {
                break;
            }
        }
        curve
    }
}

// ---------------------------------------------------------------------------
// Families grid: family-generic native training
// ---------------------------------------------------------------------------

/// Family-generic native trainer: any [`TrainableModel`] behind the same
/// minibatch-SGD loop as [`Fig3NativeTrainer`]. Powers the
/// `bench-families` params × MSE grid and cross-checks the trainer
/// pool's loop outside the job machinery.
pub struct FamilyTrainer {
    model: Box<dyn TrainableModel>,
    momentum: Momentum,
}

impl FamilyTrainer {
    /// Fresh model per `spec` — the same construction path (and RNG
    /// stream) as the pool's background jobs.
    pub fn new(spec: &JobSpec) -> FamilyTrainer {
        let mut rng = Pcg32::seeded(spec.seed);
        let model = build_trainable(spec, &mut rng);
        let momentum = Momentum::new(spec.momentum as f32, &model.param_sizes());
        FamilyTrainer { model, momentum }
    }

    /// Run SGD for `steps` minibatch steps; returns the loss curve.
    pub fn run(
        &mut self,
        task: &RegressionTask,
        steps: usize,
        batch: usize,
        schedule: &StepDecay,
    ) -> LossCurve {
        let mut cursor = BatchCursor::new(task.rows(), batch);
        let mut curve = LossCurve::new(&format!("native {}", self.model.kind()));
        let pool = crate::util::threadpool::global();
        for step in 0..steps {
            let idx = cursor.next_indices();
            let (bx, by) = task.gather(&idx);
            let pred = self.model.forward_train(&bx, pool);
            let diff = pred.sub(&by);
            let loss = diff.data().iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
                / batch as f64;
            let mut g = diff;
            g.scale(2.0 / batch as f32);
            self.model.backward_step(&g, &mut self.momentum, schedule.lr_at(step) as f32);
            curve.push(step, loss);
            if !loss.is_finite() {
                break;
            }
        }
        curve
    }

    /// The current parameters as a servable / checkpointable model.
    pub fn snapshot(&self) -> SellModel {
        self.model.snapshot()
    }

    /// Learnable parameter count (the Table-1 quantity).
    pub fn param_count(&self) -> usize {
        self.model.param_sizes().iter().sum()
    }
}

// ---------------------------------------------------------------------------
// MiniCaffeNet: artifact-driven CNN training (Table 1 analogue + E6)
// ---------------------------------------------------------------------------

/// Which FC-block variant to train.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CnnVariant {
    /// FC block replaced by the 12-layer ACDC stack.
    Acdc,
    /// Dense FC block (the reference).
    Dense,
}

impl CnnVariant {
    /// Name of the lowered train-step artifact.
    pub fn train_artifact(&self) -> &'static str {
        match self {
            CnnVariant::Acdc => "cnn_acdc_train_step",
            CnnVariant::Dense => "cnn_dense_train_step",
        }
    }

    /// Name of the lowered eval artifact.
    pub fn eval_artifact(&self) -> &'static str {
        match self {
            CnnVariant::Acdc => "cnn_acdc_eval",
            CnnVariant::Dense => "cnn_dense_eval",
        }
    }
}

/// Result of one evaluation pass.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    /// Mean loss over the evaluated examples.
    pub loss: f64,
    /// Fraction classified correctly.
    pub accuracy: f64,
    /// Examples evaluated.
    pub examples: usize,
}

/// Artifact-driven MiniCaffeNet trainer.
pub struct CnnTrainer<'e> {
    engine: &'e Engine,
    /// Which FC-block variant this trainer drives.
    pub variant: CnnVariant,
    /// Current parameter bank, positionally matching the artifact inputs
    /// (params then momenta).
    params: Vec<HostValue>,
    moms: Vec<HostValue>,
    param_names: Vec<String>,
    train_batch: usize,
    eval_batch: usize,
}

impl<'e> CnnTrainer<'e> {
    /// Initialize parameters in rust (He-normal convs/classifier, §6
    /// diagonal init for the SELL stack) matching the artifact's specs.
    pub fn new(engine: &'e Engine, variant: CnnVariant, seed: u64) -> Result<Self, String> {
        let meta = engine
            .manifest()
            .get(variant.train_artifact())
            .ok_or_else(|| format!("artifact '{}' missing", variant.train_artifact()))?
            .clone();
        let train_batch = meta.tag_usize("batch").ok_or("missing batch tag")?;
        let eval_meta = engine
            .manifest()
            .get(variant.eval_artifact())
            .ok_or("eval artifact missing")?;
        let eval_batch = eval_meta.tag_usize("batch").ok_or("missing batch tag")?;

        // Parameter specs = leading inputs up to the first "m_" name.
        let n_params = meta
            .inputs
            .iter()
            .position(|s| s.name.starts_with("m_"))
            .ok_or("train artifact has no momentum inputs")?;
        let mut rng = Pcg32::seeded(seed);
        let mut params = Vec::with_capacity(n_params);
        let mut names = Vec::with_capacity(n_params);
        for spec in &meta.inputs[..n_params] {
            params.push(init_param(&spec.name, &spec.shape, &mut rng));
            names.push(spec.name.clone());
        }
        let moms = meta.inputs[n_params..2 * n_params]
            .iter()
            .map(|s| HostValue::F32 {
                shape: s.shape.clone(),
                data: vec![0.0; s.numel()],
            })
            .collect();
        Ok(CnnTrainer {
            engine,
            variant,
            params,
            moms,
            param_names: names,
            train_batch,
            eval_batch,
        })
    }

    /// Minibatch size the train artifact was compiled for.
    pub fn train_batch_size(&self) -> usize {
        self.train_batch
    }

    /// One SGD step on a training batch; returns the loss.
    pub fn step(
        &mut self,
        images: &Tensor,
        labels: &[i32],
        lr: f32,
        seed: u32,
    ) -> Result<f64, String> {
        let art = self.engine.load(self.variant.train_artifact())?;
        let mut inputs = Vec::with_capacity(2 * self.params.len() + 4);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.moms.iter().cloned());
        inputs.push(HostValue::from_tensor(images));
        inputs.push(HostValue::from_i32(&[labels.len()], labels.to_vec()));
        inputs.push(HostValue::scalar_f32(lr));
        if self.variant == CnnVariant::Acdc {
            inputs.push(HostValue::scalar_u32(seed));
        }
        let out = art.call(&inputs)?;
        let np = self.params.len();
        self.params = out[..np].to_vec();
        self.moms = out[np..2 * np].to_vec();
        Ok(out[2 * np].scalar())
    }

    /// Evaluate on a held-out batch; returns loss + accuracy.
    pub fn eval(&self, images: &Tensor, labels: &[i32]) -> Result<EvalResult, String> {
        let art = self.engine.load(self.variant.eval_artifact())?;
        let mut inputs: Vec<HostValue> = self.params.clone();
        inputs.push(HostValue::from_tensor(images));
        inputs.push(HostValue::from_i32(&[labels.len()], labels.to_vec()));
        let out = art.call(&inputs)?;
        let loss = out[0].scalar();
        let correct = out[1].scalar();
        Ok(EvalResult {
            loss,
            accuracy: correct / labels.len() as f64,
            examples: labels.len(),
        })
    }

    /// Full training run over a corpus. Returns (train curve, final eval).
    pub fn run(
        &mut self,
        train: &ImageCorpus,
        test: &ImageCorpus,
        steps: usize,
        schedule: &StepDecay,
        log_every: usize,
    ) -> Result<(LossCurve, EvalResult), String> {
        let mut cursor = BatchCursor::new(train.rows(), self.train_batch);
        let mut curve = LossCurve::new(&format!("{:?} cnn", self.variant));
        for step in 0..steps {
            let idx = cursor.next_indices();
            let (imgs, labels) = train.gather(&idx);
            let lr = schedule.lr_at(step) as f32;
            let loss = self.step(&imgs, &labels, lr, step as u32)?;
            if step % log_every.max(1) == 0 || step + 1 == steps {
                curve.push(step, loss);
            }
            if !loss.is_finite() {
                return Err(format!("loss diverged at step {step}"));
            }
        }
        let eval = self.eval_on_corpus(test)?;
        Ok((curve, eval))
    }

    /// Evaluate over as much of a corpus as fits whole eval batches.
    pub fn eval_on_corpus(&self, corpus: &ImageCorpus) -> Result<EvalResult, String> {
        let b = self.eval_batch;
        let batches = corpus.rows() / b;
        assert!(batches > 0, "corpus smaller than eval batch");
        let mut loss = 0.0;
        let mut correct = 0.0;
        let mut seen = 0usize;
        for bi in 0..batches {
            let idx: Vec<usize> = (bi * b..(bi + 1) * b).collect();
            let (imgs, labels) = corpus.gather(&idx);
            let r = self.eval(&imgs, &labels)?;
            loss += r.loss * r.examples as f64;
            correct += r.accuracy * r.examples as f64;
            seen += r.examples;
        }
        Ok(EvalResult {
            loss: loss / seen as f64,
            accuracy: correct / seen as f64,
            examples: seen,
        })
    }

    /// Count of learnable parameters in the bank (the Table-1 number).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Export parameters as a named checkpoint.
    pub fn checkpoint(&self) -> Checkpoint {
        let mut ckpt = Checkpoint::new();
        for (name, p) in self.param_names.iter().zip(&self.params) {
            ckpt.insert(name, Tensor::from_vec(p.shape(), p.as_f32().to_vec()));
        }
        ckpt
    }

    /// Restore parameters from a checkpoint (momenta reset to zero).
    pub fn restore(&mut self, ckpt: &Checkpoint) -> Result<(), String> {
        for (name, p) in self.param_names.iter().zip(self.params.iter_mut()) {
            let t = ckpt
                .get(name)
                .ok_or_else(|| format!("checkpoint missing '{name}'"))?;
            if t.shape() != p.shape() {
                return Err(format!("'{name}': shape mismatch"));
            }
            *p = HostValue::from_tensor(t);
        }
        for m in self.moms.iter_mut() {
            if let HostValue::F32 { data, .. } = m {
                data.fill(0.0);
            }
        }
        Ok(())
    }
}

/// He-normal for conv/fc weights; §6 diagonal init for SELL stacks;
/// zeros for biases and momenta-like banks.
fn init_param(name: &str, shape: &[usize], rng: &mut Pcg32) -> HostValue {
    let numel: usize = shape.iter().product();
    let data = match name {
        "a_stack" | "d_stack" => DiagInit::CAFFENET.sample(numel, rng),
        "bias_stack" | "conv1_b" | "conv2_b" | "fc6_b" | "fc7_b" | "cls_b" => vec![0.0; numel],
        _ => {
            // He-normal: std = sqrt(2 / fan_in); fan_in = all dims but last.
            let fan_in: usize = shape[..shape.len().saturating_sub(1)].iter().product();
            let std = (2.0 / fan_in.max(1) as f64).sqrt();
            rng.normal_vec(numel, 0.0, std)
        }
    };
    HostValue::F32 {
        shape: shape.to_vec(),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_fig3_identity_init_converges() {
        let task = RegressionTask::generate(512, 16, 1e-4, 1);
        let mut t = Fig3NativeTrainer::new(16, 2, DiagInit::IDENTITY, 2);
        let curve = t.run(&task, 300, 128, &StepDecay::constant(5e-3));
        let ratio = curve.improvement_ratio().unwrap();
        assert!(ratio < 0.2, "ratio={ratio}");
    }

    #[test]
    fn native_fig3_deep_standard_init_fails_to_train() {
        // The Fig-3 right panel: near-zero init stalls for deep cascades
        // (the signal dies through the product of near-zero diagonals).
        let task = RegressionTask::generate(256, 16, 1e-4, 3);
        let mut t = Fig3NativeTrainer::new(16, 8, DiagInit::STANDARD, 4);
        let curve = t.run(&task, 200, 128, &StepDecay::constant(5e-3));
        let ratio = curve.improvement_ratio().unwrap_or(1.0);
        assert!(ratio > 0.5, "standard init unexpectedly trained: {ratio}");
    }

    #[test]
    fn family_trainer_converges_for_every_kind() {
        use crate::config::TrainerConfig;
        use crate::sell::ModelKind;
        use crate::trainer::FamilyTuning;
        let defaults = TrainerConfig::default();
        for kind in ModelKind::ALL {
            let spec = FamilyTuning::quick_spec(kind, &defaults);
            let task = RegressionTask::generate(
                spec.dataset_rows,
                spec.width,
                spec.dataset_noise,
                spec.seed,
            );
            let mut t = FamilyTrainer::new(&spec);
            assert!(t.param_count() > 0);
            let curve = t.run(&task, spec.steps, spec.batch, &StepDecay::constant(spec.lr));
            let ratio = curve.improvement_ratio().unwrap();
            assert!(ratio < spec.target_ratio, "{kind}: ratio={ratio}");
            assert_eq!(t.snapshot().kind(), kind.as_str());
        }
    }

    #[test]
    fn init_param_dispatch() {
        let mut rng = Pcg32::seeded(1);
        let a = init_param("a_stack", &[2, 8], &mut rng);
        let mean: f32 = a.as_f32().iter().sum::<f32>() / 16.0;
        assert!((mean - 1.0).abs() < 0.2, "diag init centers at 1");
        let b = init_param("conv1_b", &[8], &mut rng);
        assert!(b.as_f32().iter().all(|&v| v == 0.0));
        let w = init_param("conv1_w", &[5, 5, 1, 8], &mut rng);
        assert!(w.as_f32().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn variant_artifact_names() {
        assert_eq!(CnnVariant::Acdc.train_artifact(), "cnn_acdc_train_step");
        assert_eq!(CnnVariant::Dense.eval_artifact(), "cnn_dense_eval");
    }

    // Artifact-driven trainer tests live in rust/tests/integration_training.rs
    // (they need built artifacts + the PJRT engine).
}
