//! The family-generic training surface: every SELL family behind one
//! forward / backward / update interface ([`TrainableModel`]), so the
//! pool's SGD loop, the checkpoint cadence and the promotion path are the
//! exact same code for `acdc`, `fastfood`, `lowrank` and `circulant` jobs
//! (DESIGN.md §6).
//!
//! Each wrapper owns its concrete layer plus the activation cache its
//! backward pass needs; `backward_step` folds the gradient computation and
//! the momentum-SGD update into one call so parameter banks and velocity
//! buffers can never disagree on layout.

use crate::registry::SellModel;
use crate::sell::acdc::{AcdcCascade, CascadeCache};
use crate::sell::circulant::DiagonalCirculantCascade;
use crate::sell::fastfood::FastfoodLayer;
use crate::sell::lowrank::LowRankLayer;
use crate::sell::ModelKind;
use crate::tensor::Tensor;
use crate::trainer::sgd::Momentum;
use crate::util::rng::Pcg32;
use crate::util::threadpool::ThreadPool;

use super::JobSpec;

/// A SELL model the trainer pool can run minibatch SGD on.
///
/// The contract mirrors the ACDC training hot path: `forward_train`
/// evaluates a batch and caches whatever the backward pass needs;
/// `backward_step` consumes that cache, computes parameter gradients and
/// applies one momentum-SGD update. `snapshot` produces the servable
/// [`SellModel`] the checkpoint codec and the registry promote.
pub trait TrainableModel: Send {
    /// Which family this is.
    fn kind(&self) -> ModelKind;
    /// Input/output width N.
    fn width(&self) -> usize;
    /// Parameter-bank sizes, in the fixed order `backward_step` applies
    /// updates — this is the [`Momentum::new`] buffer layout.
    fn param_sizes(&self) -> Vec<usize>;
    /// Forward a `[batch, N]` minibatch, caching activations for the
    /// matching `backward_step` call.
    fn forward_train(&mut self, x: &Tensor, pool: &ThreadPool) -> Tensor;
    /// Backprop `gy` through the cached activations and apply one
    /// momentum-SGD update at rate `lr`.
    fn backward_step(&mut self, gy: &Tensor, momentum: &mut Momentum, lr: f32);
    /// The current parameters as a servable / checkpointable model.
    fn snapshot(&self) -> SellModel;
}

/// Build the trainable model a [`JobSpec`] asks for, drawing its init
/// from `rng` (the job's seeded generator, after the dataset draw).
pub fn build_trainable(spec: &JobSpec, rng: &mut Pcg32) -> Box<dyn TrainableModel> {
    match spec.model_kind {
        ModelKind::Acdc => {
            let cascade = if spec.nonlinear {
                AcdcCascade::nonlinear(spec.width, spec.depth, spec.init, rng)
            } else {
                AcdcCascade::linear(spec.width, spec.depth, spec.init, rng)
            };
            Box::new(TrainableAcdc {
                cascade,
                cache: None,
            })
        }
        ModelKind::Fastfood => Box::new(TrainableFastfood {
            layer: FastfoodLayer::random(spec.width, rng),
            input: None,
        }),
        ModelKind::LowRank => Box::new(TrainableLowRank {
            layer: LowRankLayer::random(spec.width, spec.effective_rank(), rng),
            input: None,
        }),
        ModelKind::Circulant => Box::new(TrainableCirculant {
            cascade: DiagonalCirculantCascade::init(spec.width, spec.depth, spec.init, rng),
            acts: None,
        }),
    }
}

/// Mirror-validated per-family SGD knobs for the eq.-(15) regression task
/// at small widths (the deterministic-test and bench presets). The
/// families condition differently — the S·H·G·P·H·B chain concentrates
/// curvature in the two diagonals around the dense Hadamard mixing, and a
/// circulant cascade needs depth ≥ 2 to escape its rank-1 floor — so each
/// family carries its own learning rate, momentum and step budget,
/// cross-checked against the NumPy mirror of the training loop at
/// multiple seeds with ≥ 3× margin on the target ratio.
#[derive(Debug, Clone, Copy)]
pub struct FamilyTuning {
    /// Learning rate that converges without divergence at widths 8–64.
    pub lr: f64,
    /// Momentum coefficient β.
    pub momentum: f64,
    /// Step budget that reaches `target_ratio` with margin at fixed seeds.
    pub steps: usize,
    /// Pass/fail convergence ratio for deterministic tests.
    pub target_ratio: f64,
}

impl FamilyTuning {
    /// The validated preset for one family.
    pub fn for_kind(kind: ModelKind) -> FamilyTuning {
        match kind {
            ModelKind::Acdc => FamilyTuning {
                lr: 5e-3,
                momentum: 0.0,
                steps: 2_500,
                target_ratio: 0.2,
            },
            // lr 5e-3 overflows within ~10³ steps at every tested seed;
            // 1e-3 with heavy-ball momentum converges in a few 10³ steps.
            ModelKind::Fastfood => FamilyTuning {
                lr: 1e-3,
                momentum: 0.9,
                steps: 8_000,
                target_ratio: 0.2,
            },
            ModelKind::LowRank => FamilyTuning {
                lr: 5e-3,
                momentum: 0.0,
                steps: 2_500,
                target_ratio: 0.2,
            },
            // Depth ≥ 2 is load-bearing: one fixed-sign block floors at a
            // ~0.1–0.3 loss ratio on eq. (15) (rank-1 obstruction), while
            // the K = 2 cascade trains through it.
            ModelKind::Circulant => FamilyTuning {
                lr: 2e-3,
                momentum: 0.0,
                steps: 4_000,
                target_ratio: 0.2,
            },
        }
    }

    /// A [`JobSpec`] preset for deterministic family tests and benches:
    /// the family's validated knobs over `defaults`, with the quick-test
    /// dataset shape shared by every family.
    pub fn quick_spec(kind: ModelKind, defaults: &crate::config::TrainerConfig) -> JobSpec {
        let t = FamilyTuning::for_kind(kind);
        JobSpec {
            model_kind: kind,
            width: 16,
            depth: 2,
            rank: 0,
            steps: t.steps,
            batch: 32,
            dataset_rows: 256,
            lr: t.lr,
            momentum: t.momentum,
            seed: 1,
            checkpoint_every: 0,
            target_ratio: t.target_ratio,
            ..JobSpec::from_config(defaults)
        }
    }
}

/// ACDC wrapper: the pooled batched SoA engine plus
/// [`super::apply_momentum_update`], exactly the pre-trait hot path.
struct TrainableAcdc {
    cascade: AcdcCascade,
    cache: Option<CascadeCache>,
}

impl TrainableModel for TrainableAcdc {
    fn kind(&self) -> ModelKind {
        ModelKind::Acdc
    }

    fn width(&self) -> usize {
        self.cascade.n()
    }

    fn param_sizes(&self) -> Vec<usize> {
        vec![self.cascade.n(); 3 * self.cascade.k()]
    }

    fn forward_train(&mut self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        let (pred, cache) = self.cascade.forward_train_pooled(x, pool);
        self.cache = Some(cache);
        pred
    }

    fn backward_step(&mut self, gy: &Tensor, momentum: &mut Momentum, lr: f32) {
        let cache = self.cache.take().expect("backward_step before forward_train");
        let (_, mut grads) = self.cascade.backward(&cache, gy);
        super::apply_momentum_update(&mut self.cascade, &mut grads, momentum, lr);
    }

    fn snapshot(&self) -> SellModel {
        SellModel::Acdc(self.cascade.clone())
    }
}

/// Adaptive Fastfood wrapper: banks ordered (s, g, b).
struct TrainableFastfood {
    layer: FastfoodLayer,
    input: Option<Tensor>,
}

impl TrainableModel for TrainableFastfood {
    fn kind(&self) -> ModelKind {
        ModelKind::Fastfood
    }

    fn width(&self) -> usize {
        crate::sell::LinearOp::width(&self.layer)
    }

    fn param_sizes(&self) -> Vec<usize> {
        vec![self.width(); 3]
    }

    fn forward_train(&mut self, x: &Tensor, _pool: &ThreadPool) -> Tensor {
        let pred = crate::sell::LinearOp::forward(&self.layer, x);
        self.input = Some(x.clone());
        pred
    }

    fn backward_step(&mut self, gy: &Tensor, momentum: &mut Momentum, lr: f32) {
        let x = self.input.take().expect("backward_step before forward_train");
        let (_, grads) = self.layer.backward(&x, gy);
        let mut params: Vec<&mut [f32]> = vec![
            self.layer.s.as_mut_slice(),
            self.layer.g.as_mut_slice(),
            self.layer.b.as_mut_slice(),
        ];
        let gs: Vec<&[f32]> = vec![&grads.s, &grads.g, &grads.b];
        momentum.apply(&mut params, &gs, lr);
    }

    fn snapshot(&self) -> SellModel {
        SellModel::Fastfood(self.layer.clone())
    }
}

/// Low-rank wrapper: banks ordered (U, V), each flattened row-major.
struct TrainableLowRank {
    layer: LowRankLayer,
    input: Option<Tensor>,
}

impl TrainableModel for TrainableLowRank {
    fn kind(&self) -> ModelKind {
        ModelKind::LowRank
    }

    fn width(&self) -> usize {
        crate::sell::LinearOp::width(&self.layer)
    }

    fn param_sizes(&self) -> Vec<usize> {
        vec![self.layer.u.numel(), self.layer.v.numel()]
    }

    fn forward_train(&mut self, x: &Tensor, _pool: &ThreadPool) -> Tensor {
        let pred = crate::sell::LinearOp::forward(&self.layer, x);
        self.input = Some(x.clone());
        pred
    }

    fn backward_step(&mut self, gy: &Tensor, momentum: &mut Momentum, lr: f32) {
        let x = self.input.take().expect("backward_step before forward_train");
        let (_, grads) = self.layer.backward(&x, gy);
        let mut params: Vec<&mut [f32]> = vec![
            self.layer.u.data_mut(),
            self.layer.v.data_mut(),
        ];
        let gs: Vec<&[f32]> = vec![grads.u.data(), grads.v.data()];
        momentum.apply(&mut params, &gs, lr);
    }

    fn snapshot(&self) -> SellModel {
        SellModel::LowRank(self.layer.clone())
    }
}

/// Diagonal-circulant wrapper: banks ordered (r, d) per layer,
/// first-to-last.
struct TrainableCirculant {
    cascade: DiagonalCirculantCascade,
    acts: Option<Vec<Tensor>>,
}

impl TrainableModel for TrainableCirculant {
    fn kind(&self) -> ModelKind {
        ModelKind::Circulant
    }

    fn width(&self) -> usize {
        self.cascade.n()
    }

    fn param_sizes(&self) -> Vec<usize> {
        vec![self.cascade.n(); 2 * self.cascade.depth()]
    }

    fn forward_train(&mut self, x: &Tensor, _pool: &ThreadPool) -> Tensor {
        let (pred, acts) = self.cascade.forward_train(x);
        self.acts = Some(acts);
        pred
    }

    fn backward_step(&mut self, gy: &Tensor, momentum: &mut Momentum, lr: f32) {
        let acts = self.acts.take().expect("backward_step before forward_train");
        let (_, grads) = self.cascade.backward(&acts, gy);
        let mut params: Vec<&mut [f32]> = Vec::with_capacity(2 * self.cascade.depth());
        for layer in self.cascade.layers.iter_mut() {
            let (r, d) = (&mut layer.r, &mut layer.d);
            params.push(r.as_mut_slice());
            params.push(d.as_mut_slice());
        }
        let gs: Vec<&[f32]> = grads
            .iter()
            .flat_map(|g| [g.r.as_slice(), g.d.as_slice()])
            .collect();
        momentum.apply(&mut params, &gs, lr);
    }

    fn snapshot(&self) -> SellModel {
        SellModel::Circulant(self.cascade.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainerConfig;

    #[test]
    fn build_trainable_covers_every_kind() {
        let defaults = TrainerConfig::default();
        for kind in ModelKind::ALL {
            let spec = FamilyTuning::quick_spec(kind, &defaults);
            let mut rng = Pcg32::seeded(spec.seed);
            let model = build_trainable(&spec, &mut rng);
            assert_eq!(model.kind(), kind);
            assert_eq!(model.width(), spec.width);
            let sizes = model.param_sizes();
            assert!(!sizes.is_empty());
            // The snapshot serves the same family and width.
            let snap = model.snapshot();
            assert_eq!(snap.kind(), kind.as_str());
            assert_eq!(snap.width(), spec.width);
            assert_eq!(snap.param_count(), sizes.iter().sum::<usize>());
        }
    }

    #[test]
    fn forward_train_matches_snapshot_forward() {
        let defaults = TrainerConfig::default();
        let pool = crate::util::threadpool::global();
        for kind in ModelKind::ALL {
            let spec = FamilyTuning::quick_spec(kind, &defaults);
            let mut rng = Pcg32::seeded(3);
            let mut model = build_trainable(&spec, &mut rng);
            let x = Tensor::from_vec(&[6, 16], rng.normal_vec(96, 0.0, 1.0));
            let pred = model.forward_train(&x, pool);
            let want = model.snapshot().forward(&x);
            assert!(
                pred.max_abs_diff(&want) < 1e-4,
                "{kind}: train-path forward drifted from the serve path"
            );
        }
    }

    #[test]
    fn backward_step_moves_parameters_downhill() {
        // One SGD step on gy = y must reduce ‖y‖² for every family (lr
        // small enough that the quadratic term cannot dominate).
        let defaults = TrainerConfig::default();
        let pool = crate::util::threadpool::global();
        for kind in ModelKind::ALL {
            let spec = FamilyTuning::quick_spec(kind, &defaults);
            let mut rng = Pcg32::seeded(5);
            let mut model = build_trainable(&spec, &mut rng);
            let mut momentum = Momentum::new(0.0, &model.param_sizes());
            let x = Tensor::from_vec(&[8, 16], rng.normal_vec(128, 0.0, 1.0));
            let before: f64 = model
                .forward_train(&x, pool)
                .data()
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum();
            let y = model.snapshot().forward(&x);
            model.backward_step(&y.map(|v| 2.0 * v), &mut momentum, 1e-4);
            let after: f64 = model
                .snapshot()
                .forward(&x)
                .data()
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum();
            assert!(after < before, "{kind}: {after} !< {before}");
        }
    }
}
