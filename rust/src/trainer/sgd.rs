//! SGD machinery: learning-rate schedules and momentum state for the
//! native (pure-rust) training paths. The artifact paths carry their
//! optimizer inside the lowered program; these utilities drive everything
//! else (schedule, curves, convergence checks).

/// Step-decay learning-rate schedule (§6.2: "learning rate 0.1 multiplied
/// by 0.1 every 100,000 iterations").
#[derive(Debug, Clone)]
pub struct StepDecay {
    /// Learning rate at step 0.
    pub base_lr: f64,
    /// Multiplier applied at each decay.
    pub factor: f64,
    /// Steps between decays.
    pub every: usize,
}

impl StepDecay {
    /// Schedule multiplying `base_lr` by `factor` every `every` steps.
    pub fn new(base_lr: f64, factor: f64, every: usize) -> StepDecay {
        assert!(base_lr > 0.0 && factor > 0.0 && every > 0);
        StepDecay {
            base_lr,
            factor,
            every,
        }
    }

    /// Constant schedule.
    pub fn constant(lr: f64) -> StepDecay {
        StepDecay::new(lr, 1.0, usize::MAX)
    }

    /// The paper's §6.2 schedule.
    pub fn paper_62() -> StepDecay {
        StepDecay::new(0.1, 0.1, 100_000)
    }

    /// Learning rate at the given step.
    pub fn lr_at(&self, step: usize) -> f64 {
        let decays = if self.every == usize::MAX {
            0
        } else {
            step / self.every
        };
        self.base_lr * self.factor.powi(decays as i32)
    }
}

/// Momentum buffers for a bank of equally-shaped vectors.
#[derive(Debug, Clone)]
pub struct Momentum {
    /// Velocity decay coefficient.
    pub beta: f32,
    bufs: Vec<Vec<f32>>,
}

impl Momentum {
    /// Zeroed velocity buffers of the given sizes.
    pub fn new(beta: f32, sizes: &[usize]) -> Momentum {
        Momentum {
            beta,
            bufs: sizes.iter().map(|&s| vec![0.0; s]).collect(),
        }
    }

    /// v ← β·v + g; p ← p − lr·v, for each (param, grad) pair.
    pub fn apply(&mut self, params: &mut [&mut [f32]], grads: &[&[f32]], lr: f32) {
        assert_eq!(params.len(), self.bufs.len());
        assert_eq!(grads.len(), self.bufs.len());
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.bufs) {
            assert_eq!(p.len(), v.len());
            assert_eq!(g.len(), v.len());
            for i in 0..v.len() {
                v[i] = self.beta * v[i] + g[i];
                p[i] -= lr * v[i];
            }
        }
    }
}

/// A recorded loss curve: (step, loss) samples with convergence helpers.
#[derive(Debug, Clone, Default)]
pub struct LossCurve {
    /// (step, loss) samples in recording order.
    pub points: Vec<(usize, f64)>,
    /// Curve label for rendering.
    pub label: String,
}

impl LossCurve {
    /// Empty curve with a label.
    pub fn new(label: &str) -> LossCurve {
        LossCurve {
            points: vec![],
            label: label.to_string(),
        }
    }

    /// Record one (step, loss) sample.
    pub fn push(&mut self, step: usize, loss: f64) {
        self.points.push((step, loss));
    }

    /// First recorded loss.
    pub fn first(&self) -> Option<f64> {
        self.points.first().map(|p| p.1)
    }

    /// Last recorded loss.
    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    /// Minimum loss seen.
    pub fn best(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.1)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// final/initial ratio (< 1 means improved).
    pub fn improvement_ratio(&self) -> Option<f64> {
        match (self.first(), self.last()) {
            (Some(f), Some(l)) if f > 0.0 => Some(l / f),
            _ => None,
        }
    }

    /// Render as a compact text series (for EXPERIMENTS.md and benches).
    pub fn render(&self, every: usize) -> String {
        let mut out = format!("# {}\n", self.label);
        for (i, (step, loss)) in self.points.iter().enumerate() {
            if i % every.max(1) == 0 || i + 1 == self.points.len() {
                out.push_str(&format!("step {step:>7}  loss {loss:.6e}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_schedule() {
        let s = StepDecay::paper_62();
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(99_999), 0.1);
        assert!((s.lr_at(100_000) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(250_000) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn constant_schedule_never_decays() {
        let s = StepDecay::constant(0.05);
        assert_eq!(s.lr_at(0), 0.05);
        assert_eq!(s.lr_at(10_000_000), 0.05);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut m = Momentum::new(0.5, &[2]);
        let mut p = vec![0.0f32, 0.0];
        let g = vec![1.0f32, -1.0];
        m.apply(&mut [&mut p], &[&g], 1.0);
        assert_eq!(p, vec![-1.0, 1.0]); // v = g
        m.apply(&mut [&mut p], &[&g], 1.0);
        // v = 0.5*1 + 1 = 1.5 → p = -1 - 1.5 = -2.5
        assert_eq!(p, vec![-2.5, 2.5]);
    }

    #[test]
    fn zero_momentum_is_plain_sgd() {
        let mut m = Momentum::new(0.0, &[1]);
        let mut p = vec![1.0f32];
        m.apply(&mut [&mut p], &[&[0.5f32] as &[f32]], 0.1);
        assert!((p[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn loss_curve_stats() {
        let mut c = LossCurve::new("test");
        c.push(0, 10.0);
        c.push(10, 4.0);
        c.push(20, 5.0);
        assert_eq!(c.first(), Some(10.0));
        assert_eq!(c.last(), Some(5.0));
        assert_eq!(c.best(), Some(4.0));
        assert_eq!(c.improvement_ratio(), Some(0.5));
        let r = c.render(1);
        assert!(r.contains("step      20"));
    }

    #[test]
    fn empty_curve_is_safe() {
        let c = LossCurve::new("empty");
        assert_eq!(c.first(), None);
        assert_eq!(c.improvement_ratio(), None);
    }
}
