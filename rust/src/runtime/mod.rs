//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. The `Engine` owns the client and a lazy per-artifact
//! executable cache; `LoadedArtifact::call` is the typed entry point the
//! coordinator and training orchestrator use.
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The whole PJRT surface is gated behind the `pjrt` cargo feature. The
//! default build substitutes [`stub`] for the `xla` crate (the bindings are
//! not in the offline registry), so `Engine::open` fails cleanly with a
//! "built without pjrt" error and every caller falls back to the native
//! executors — the crate stays pure-Rust and green without artifacts.

pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;
pub mod values;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use manifest::{ArtifactMeta, Manifest};
#[cfg(not(feature = "pjrt"))]
use self::stub as xla;
use values::HostValue;

/// PJRT engine: client + manifest + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Open the CPU PJRT client over an artifacts directory.
    pub fn open(artifacts_dir: &Path) -> Result<Engine, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (or the stub's marker when disabled).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<LoadedArtifact<'_>, String> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| format!("unknown artifact '{name}' (manifest has {})",
                self.manifest.artifacts.len()))?
            .clone();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(LoadedArtifact {
                    engine: self,
                    meta,
                    exe: Arc::clone(exe),
                });
            }
        }
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .map_err(|e| format!("parse HLO text {}: {e}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| format!("compile '{name}': {e}"))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(LoadedArtifact {
            engine: self,
            meta,
            exe,
        })
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Compile (or fetch) an artifact and return owned handles — the
    /// hot-path variant used by executors that pin executables at
    /// construction (no per-call cache lock / meta clone; perf pass L3-1).
    pub fn load_owned(
        &self,
        name: &str,
    ) -> Result<(ArtifactMeta, Arc<xla::PjRtLoadedExecutable>), String> {
        let art = self.load(name)?;
        Ok((art.meta, art.exe))
    }
}

/// Execute a compiled artifact against its manifest contract. Free
/// function so owners of `(meta, exe)` pairs can call without holding a
/// `LoadedArtifact` (which borrows the engine).
pub fn execute_artifact(
    meta: &ArtifactMeta,
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[HostValue],
) -> Result<Vec<HostValue>, String> {
    if inputs.len() != meta.inputs.len() {
        return Err(format!(
            "'{}': expected {} inputs, got {}",
            meta.name,
            meta.inputs.len(),
            inputs.len()
        ));
    }
    let mut literals = Vec::with_capacity(inputs.len());
    for (hv, spec) in inputs.iter().zip(&meta.inputs) {
        hv.check_spec(spec)
            .map_err(|e| format!("'{}' input {e}", meta.name))?;
        literals.push(hv.to_literal()?);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| format!("execute '{}': {e}", meta.name))?;
    let buffer = &result[0][0];
    let tuple_lit = buffer
        .to_literal_sync()
        .map_err(|e| format!("fetch result: {e}"))?;
    // aot.py lowers with return_tuple=True, so the root is always a tuple
    // (possibly a 1-tuple).
    let elems = tuple_lit
        .to_tuple()
        .map_err(|e| format!("decompose tuple: {e}"))?;
    if elems.len() != meta.outputs.len() {
        return Err(format!(
            "'{}': program returned {} outputs, manifest declares {}",
            meta.name,
            elems.len(),
            meta.outputs.len()
        ));
    }
    elems
        .iter()
        .zip(&meta.outputs)
        .map(|(lit, spec)| HostValue::from_literal(lit, spec))
        .collect()
}

/// A compiled artifact plus its manifest contract.
pub struct LoadedArtifact<'e> {
    #[allow(dead_code)]
    engine: &'e Engine,
    /// The artifact's manifest contract.
    pub meta: ArtifactMeta,
    exe: Arc<xla::PjRtLoadedExecutable>,
}

impl LoadedArtifact<'_> {
    /// Execute with typed host values; validates inputs against the
    /// manifest and decodes the output tuple back into host values.
    pub fn call(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>, String> {
        execute_artifact(&self.meta, &self.exe, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn open_fails_without_manifest() {
        let err = match Engine::open(Path::new("/definitely/not/here")) {
            Ok(_) => panic!("expected error"),
            Err(e) => e,
        };
        assert!(err.contains("manifest.json"));
    }

    #[test]
    fn quickstart_executes_and_matches_reference() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::open(&dir).unwrap();
        let art = engine.load("quickstart_acdc_b4_n64").unwrap();
        // Inputs: x [4,64], a, d, bias [64]
        let mut rng = crate::util::rng::Pcg32::seeded(42);
        let n = 64;
        let x = crate::tensor::Tensor::from_vec(&[4, n], rng.normal_vec(4 * n, 0.0, 1.0));
        let a = rng.normal_vec(n, 1.0, 0.1);
        let d = rng.normal_vec(n, 1.0, 0.1);
        let b = rng.normal_vec(n, 0.0, 0.1);
        let out = art
            .call(&[
                HostValue::from_tensor(&x),
                HostValue::F32 { shape: vec![n], data: a.clone() },
                HostValue::F32 { shape: vec![n], data: d.clone() },
                HostValue::F32 { shape: vec![n], data: b.clone() },
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].to_tensor();
        // Compare against the rust reference SELL.
        let layer = crate::sell::acdc::AcdcLayer::new(
            a,
            d,
            b,
            std::sync::Arc::new(crate::dct::DctPlan::new(n)),
        );
        let want = layer.forward_fused(&x);
        assert!(
            y.max_abs_diff(&want) < 1e-3,
            "pjrt vs rust reference diff = {}",
            y.max_abs_diff(&want)
        );
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::open(&dir).unwrap();
        let _a = engine.load("quickstart_acdc_b4_n64").unwrap();
        assert_eq!(engine.cached_count(), 1);
        let _b = engine.load("quickstart_acdc_b4_n64").unwrap();
        assert_eq!(engine.cached_count(), 1);
    }

    #[test]
    fn call_rejects_wrong_arity_and_shape() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::open(&dir).unwrap();
        let art = engine.load("quickstart_acdc_b4_n64").unwrap();
        assert!(art.call(&[]).is_err());
        let bad = vec![
            HostValue::from_tensor(&crate::tensor::Tensor::zeros(&[4, 32])), // wrong n
            HostValue::from_tensor(&crate::tensor::Tensor::zeros(&[64])),
            HostValue::from_tensor(&crate::tensor::Tensor::zeros(&[64])),
            HostValue::from_tensor(&crate::tensor::Tensor::zeros(&[64])),
        ];
        assert!(art.call(&bad).is_err());
    }
}
