//! Inert stand-ins for the `xla` PJRT bindings (default, non-`pjrt` build).
//!
//! The offline registry does not carry the `xla` crate, so the default
//! build replaces it with this module (`use crate::runtime::stub as xla`).
//! Every constructor that would touch PJRT returns [`Error`] instead, which
//! surfaces through `Engine::open` as a clean "built without pjrt" message;
//! callers already treat that the same as "artifacts not present" and fall
//! back to the native executors. The method signatures mirror the subset of
//! the real crate the runtime uses, so enabling the `pjrt` feature swaps the
//! real crate back in with no call-site changes.

use std::fmt;
use std::path::Path;

/// Error produced by every stubbed PJRT entry point.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn disabled() -> Error {
        Error("acdc was built without the `pjrt` feature (PJRT execution disabled)".to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Element types the runtime exchanges with executables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    S32,
    /// 32-bit unsigned integer.
    U32,
}

/// Stand-in for `xla::PjRtClient`; construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: PJRT is disabled in this build.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::disabled())
    }

    /// Marker platform name for the disabled build.
    pub fn platform_name(&self) -> String {
        "pjrt-disabled".to_string()
    }

    /// Always fails: PJRT is disabled in this build.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::disabled())
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails: PJRT is disabled in this build.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error::disabled())
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Inert wrapper (nothing to convert without PJRT).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`; unreachable in practice
/// because no client can be constructed to compile one.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails: PJRT is disabled in this build.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::disabled())
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails: PJRT is disabled in this build.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::disabled())
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Always fails: PJRT is disabled in this build.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        Err(Error::disabled())
    }

    /// Always zero in the stub.
    pub fn element_count(&self) -> usize {
        0
    }

    /// Always fails: PJRT is disabled in this build.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::disabled())
    }

    /// Always fails: PJRT is disabled in this build.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::disabled())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_disabled_feature() {
        let err = match PjRtClient::cpu() {
            Ok(_) => panic!("stub client must not construct"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn literal_entry_points_all_fail_closed() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let bad = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4]);
        assert!(bad.is_err());
        let lit = Literal;
        assert_eq!(lit.element_count(), 0);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }
}
