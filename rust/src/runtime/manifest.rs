//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! this crate. Positional input/output specs let the runtime feed and
//! decode any lowered program without knowing anything about jax.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Element dtype of an artifact tensor (matches aot.py's `_dtype_str`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
    /// 32-bit unsigned integer.
    U32,
}

impl Dtype {
    /// Parse aot.py's dtype string (`"f32"`, `"i32"`, `"u32"`).
    pub fn parse(s: &str) -> Result<Dtype, String> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => Err(format!("unsupported dtype '{other}'")),
        }
    }

    /// Bytes per element.
    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// One positional tensor spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Parameter name from the lowering.
    pub name: String,
    /// Static shape.
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec, String> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or("spec missing name")?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or("spec missing shape")?
            .iter()
            .map(|v| v.as_usize().ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = Dtype::parse(
            j.get("dtype")
                .and_then(|v| v.as_str())
                .ok_or("spec missing dtype")?,
        )?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name (the registry key).
    pub name: String,
    /// HLO text file path (resolved against the manifest dir).
    pub file: PathBuf,
    /// Positional input specs.
    pub inputs: Vec<TensorSpec>,
    /// Positional output specs.
    pub outputs: Vec<TensorSpec>,
    /// Free-form tags (`experiment`, `n`, `batch`, ...).
    pub tags: BTreeMap<String, Json>,
    /// Content hash of the HLO text, when present.
    pub sha256: Option<String>,
}

impl ArtifactMeta {
    /// String tag by key.
    pub fn tag_str(&self, key: &str) -> Option<&str> {
        self.tags.get(key).and_then(|v| v.as_str())
    }

    /// Integer tag by key.
    pub fn tag_usize(&self, key: &str) -> Option<usize> {
        self.tags.get(key).and_then(|v| v.as_usize())
    }

    /// Position of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    /// Position of a named output.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Schema version (must be 1).
    pub format: u64,
    /// Seed used for the lowering's fixed permutations, when recorded.
    pub perm_seed: Option<u64>,
    /// Every lowered artifact.
    pub artifacts: Vec<ArtifactMeta>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse manifest JSON; `dir` anchors relative file paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let root = Json::parse(text).map_err(|e| e.to_string())?;
        let format = root
            .get("format")
            .and_then(|v| v.as_usize())
            .ok_or("manifest missing format")? as u64;
        if format != 1 {
            return Err(format!("unsupported manifest format {format}"));
        }
        let perm_seed = root.get("perm_seed").and_then(|v| v.as_usize()).map(|v| v as u64);
        let mut artifacts = Vec::new();
        for aj in root
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing artifacts")?
        {
            let name = aj
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing name")?
                .to_string();
            let file = dir.join(
                aj.get("file")
                    .and_then(|v| v.as_str())
                    .ok_or("artifact missing file")?,
            );
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
                aj.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| format!("artifact '{name}' missing {key}"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            let inputs = parse_specs("inputs")?;
            let outputs = parse_specs("outputs")?;
            let tags = aj
                .get("tags")
                .and_then(|v| v.as_obj())
                .map(|o| o.clone())
                .unwrap_or_default();
            let sha256 = aj
                .get("sha256")
                .and_then(|v| v.as_str())
                .map(String::from);
            artifacts.push(ArtifactMeta {
                name,
                file,
                inputs,
                outputs,
                tags,
                sha256,
            });
        }
        // Names must be unique — the registry indexes by name.
        let mut names: Vec<&str> = artifacts.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != artifacts.len() {
            return Err("duplicate artifact names in manifest".into());
        }
        Ok(Manifest {
            format,
            perm_seed,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts`?)", path.display()))?;
        Manifest::parse(&text, dir)
    }

    /// Artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts whose `experiment` tag matches.
    pub fn by_experiment(&self, experiment: &str) -> Vec<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.tag_str("experiment") == Some(experiment))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "perm_seed": 7,
      "artifacts": [
        {"name": "quickstart", "file": "q.hlo.txt",
         "inputs": [{"name": "x", "shape": [4, 64], "dtype": "f32"},
                    {"name": "a", "shape": [64], "dtype": "f32"}],
         "outputs": [{"name": "y", "shape": [4, 64], "dtype": "f32"}],
         "tags": {"experiment": "quickstart", "n": 64},
         "sha256": "ab"},
        {"name": "fig3_step_k4", "file": "f.hlo.txt",
         "inputs": [{"name": "a_stack", "shape": [4, 32], "dtype": "f32"},
                    {"name": "lr", "shape": [], "dtype": "f32"}],
         "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
         "tags": {"experiment": "fig3", "k": 4}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.format, 1);
        assert_eq!(m.perm_seed, Some(7));
        assert_eq!(m.artifacts.len(), 2);
        let q = m.get("quickstart").unwrap();
        assert_eq!(q.inputs[0].shape, vec![4, 64]);
        assert_eq!(q.inputs[0].dtype, Dtype::F32);
        assert_eq!(q.file, Path::new("/tmp/a/q.hlo.txt"));
        assert_eq!(q.tag_usize("n"), Some(64));
    }

    #[test]
    fn by_experiment_filters() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.by_experiment("fig3").len(), 1);
        assert_eq!(m.by_experiment("nope").len(), 0);
    }

    #[test]
    fn scalar_spec_numel_is_one() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        let f = m.get("fig3_step_k4").unwrap();
        assert_eq!(f.inputs[1].numel(), 1);
        assert_eq!(f.input_index("lr"), Some(1));
        assert_eq!(f.output_index("loss"), Some(0));
    }

    #[test]
    fn rejects_duplicate_names() {
        let dup = SAMPLE.replace("fig3_step_k4", "quickstart");
        assert!(Manifest::parse(&dup, Path::new("/x")).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("\"format\": 1", "\"format\": 9");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("quickstart_acdc_b4_n64").is_some());
        assert!(!m.by_experiment("fig3").is_empty());
    }
}
