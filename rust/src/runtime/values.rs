//! Host-side values exchanged with PJRT executables.
//!
//! `HostValue` is the typed host tensor (f32/i32/u32) that converts to and
//! from `xla::Literal` according to a `TensorSpec`. Conversion validates
//! shape and dtype so a mis-wired harness fails loudly instead of feeding
//! garbage to a compiled program.

use crate::runtime::manifest::{Dtype, TensorSpec};
#[cfg(not(feature = "pjrt"))]
use crate::runtime::stub as xla;
use crate::tensor::Tensor;

/// A typed host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum HostValue {
    /// f32 tensor.
    F32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat row-major elements.
        data: Vec<f32>,
    },
    /// i32 tensor.
    I32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat row-major elements.
        data: Vec<i32>,
    },
    /// u32 tensor.
    U32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat row-major elements.
        data: Vec<u32>,
    },
}

impl HostValue {
    /// Rank-0 f32 value.
    pub fn scalar_f32(v: f32) -> HostValue {
        HostValue::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Rank-0 u32 value.
    pub fn scalar_u32(v: u32) -> HostValue {
        HostValue::U32 {
            shape: vec![],
            data: vec![v],
        }
    }

    /// f32 value copying a [`Tensor`]'s shape and data.
    pub fn from_tensor(t: &Tensor) -> HostValue {
        HostValue::F32 {
            shape: t.shape().to_vec(),
            data: t.data().to_vec(),
        }
    }

    /// i32 value from shape + data (lengths must agree).
    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> HostValue {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Dimension sizes.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. }
            | HostValue::I32 { shape, .. }
            | HostValue::U32 { shape, .. } => shape,
        }
    }

    /// Element dtype.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostValue::F32 { .. } => Dtype::F32,
            HostValue::I32 { .. } => Dtype::I32,
            HostValue::U32 { .. } => Dtype::U32,
        }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    /// Borrow f32 payload (panics on dtype mismatch — test/impl errors).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostValue::F32 { data, .. } => data,
            other => panic!("expected f32 value, got {:?}", other.dtype()),
        }
    }

    /// Borrow i32 payload (panics on dtype mismatch).
    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostValue::I32 { data, .. } => data,
            other => panic!("expected i32 value, got {:?}", other.dtype()),
        }
    }

    /// First element as f64 (for scalar losses/metrics).
    pub fn scalar(&self) -> f64 {
        match self {
            HostValue::F32 { data, .. } => data[0] as f64,
            HostValue::I32 { data, .. } => data[0] as f64,
            HostValue::U32 { data, .. } => data[0] as f64,
        }
    }

    /// Into a 2-D `Tensor` view (f32 only).
    pub fn to_tensor(&self) -> Tensor {
        Tensor::from_vec(self.shape(), self.as_f32().to_vec())
    }

    /// Validate against a manifest spec.
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<(), String> {
        if self.dtype() != spec.dtype {
            return Err(format!(
                "'{}': dtype mismatch ({:?} vs {:?})",
                spec.name,
                self.dtype(),
                spec.dtype
            ));
        }
        if self.shape() != spec.shape.as_slice() {
            return Err(format!(
                "'{}': shape mismatch ({:?} vs {:?})",
                spec.name,
                self.shape(),
                spec.shape
            ));
        }
        Ok(())
    }

    /// Convert to an `xla::Literal`.
    pub fn to_literal(&self) -> Result<xla::Literal, String> {
        let (ty, bytes): (xla::ElementType, Vec<u8>) = match self {
            HostValue::F32 { data, .. } => (
                xla::ElementType::F32,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
            HostValue::I32 { data, .. } => (
                xla::ElementType::S32,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
            HostValue::U32 { data, .. } => (
                xla::ElementType::U32,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, self.shape(), &bytes)
            .map_err(|e| format!("literal create: {e}"))
    }

    /// Convert back from a `xla::Literal` according to a spec.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostValue, String> {
        let count = lit.element_count();
        if count != spec.numel() {
            return Err(format!(
                "'{}': literal has {count} elements, spec wants {}",
                spec.name,
                spec.numel()
            ));
        }
        let hv = match spec.dtype {
            Dtype::F32 => HostValue::F32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<f32>().map_err(|e| format!("to_vec f32: {e}"))?,
            },
            Dtype::I32 => HostValue::I32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<i32>().map_err(|e| format!("to_vec i32: {e}"))?,
            },
            Dtype::U32 => HostValue::U32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<u32>().map_err(|e| format!("to_vec u32: {e}"))?,
            },
        };
        Ok(hv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: Dtype) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype,
        }
    }

    #[test]
    fn scalar_constructors() {
        assert_eq!(HostValue::scalar_f32(2.5).scalar(), 2.5);
        assert_eq!(HostValue::scalar_u32(3).scalar(), 3.0);
        assert!(HostValue::scalar_f32(1.0).shape().is_empty());
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let hv = HostValue::from_tensor(&t);
        assert_eq!(hv.to_tensor(), t);
        assert_eq!(hv.numel(), 6);
    }

    #[test]
    fn check_spec_validates() {
        let hv = HostValue::from_tensor(&Tensor::zeros(&[2, 2]));
        assert!(hv.check_spec(&spec("x", &[2, 2], Dtype::F32)).is_ok());
        assert!(hv.check_spec(&spec("x", &[4], Dtype::F32)).is_err());
        assert!(hv.check_spec(&spec("x", &[2, 2], Dtype::I32)).is_err());
    }

    #[test]
    #[should_panic]
    fn as_f32_panics_on_i32() {
        HostValue::from_i32(&[1], vec![1]).as_f32();
    }

    // Literal conversions need a real `xla::Literal`; the non-pjrt stub
    // fails closed, so these roundtrips only run with the feature on.
    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 2], vec![1.5, -2.0, 0.0, 7.25]);
        let hv = HostValue::from_tensor(&t);
        let lit = hv.to_literal().unwrap();
        let back = HostValue::from_literal(&lit, &spec("x", &[2, 2], Dtype::F32)).unwrap();
        assert_eq!(back, hv);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32_u32() {
        let hv = HostValue::from_i32(&[3], vec![-1, 0, 5]);
        let lit = hv.to_literal().unwrap();
        let back = HostValue::from_literal(&lit, &spec("l", &[3], Dtype::I32)).unwrap();
        assert_eq!(back, hv);

        let hv = HostValue::scalar_u32(42);
        let lit = hv.to_literal().unwrap();
        let back = HostValue::from_literal(&lit, &spec("s", &[], Dtype::U32)).unwrap();
        assert_eq!(back.scalar(), 42.0);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn from_literal_rejects_count_mismatch() {
        let hv = HostValue::from_tensor(&Tensor::zeros(&[4]));
        let lit = hv.to_literal().unwrap();
        assert!(HostValue::from_literal(&lit, &spec("x", &[5], Dtype::F32)).is_err());
    }
}
