//! Servable SELL models and their checkpoint manifest codec.
//!
//! [`SellModel`] is the unit the registry loads, swaps and serves: one of
//! the repo's structured-efficient-linear-layer families wrapped behind a
//! uniform forward interface. Models round-trip through the binary
//! [`Checkpoint`] format bit-exactly (f32 payloads are stored verbatim;
//! permutations are stored as exactly-representable small integers), so a
//! `save → load → infer` cycle reproduces the in-memory model's outputs
//! to the last ulp on the same execution path.
//!
//! Layout (all under reserved `sell.`/`acdc.`/`ff.`/`lr.`/`dc.` key
//! prefixes):
//!
//! ```text
//! sell.meta            [_, ...]   kind code + shape header (see below)
//! acdc.layer{i}.{a,d,bias}  [n]   per-layer ACDC diagonals
//! acdc.perm{i}              [n]   optional §6.2 permutations
//! ff.{s,g,b,perm}           [n]   adaptive Fastfood diagonals + perm
//! lr.u / lr.v         [n,r]/[r,n] low-rank factors
//! dc.layer{i}.{signs,r,d}   [n]   diagonal-circulant cascade layers
//! ```

use std::sync::Arc;

use crate::checkpoint::Checkpoint;
use crate::coordinator::worker::BatchExecutor;
use crate::dct::PlanCache;
use crate::sell::acdc::{AcdcCascade, AcdcLayer};
use crate::sell::circulant::{DiagonalCirculantCascade, DiagonalCirculantLayer};
use crate::sell::fastfood::FastfoodLayer;
use crate::sell::lowrank::LowRankLayer;
use crate::tensor::Tensor;

/// Kind code stored in `sell.meta[0]`.
const KIND_ACDC: f32 = 0.0;
/// Kind code for [`FastfoodLayer`].
const KIND_FASTFOOD: f32 = 1.0;
/// Kind code for [`LowRankLayer`].
const KIND_LOWRANK: f32 = 2.0;
/// Kind code for [`DiagonalCirculantCascade`].
const KIND_CIRCULANT: f32 = 3.0;

/// Permutation indices are stored as f32; exact only below 2^24.
const MAX_EXACT_U32: u32 = 1 << 24;

/// A servable model: any SELL family behind one forward interface.
///
/// Cloning is cheap relative to model size (ACDC layers share one cached
/// [`crate::dct::DctPlan`]); the serving worker factory clones one per
/// worker thread.
#[derive(Debug, Clone)]
pub enum SellModel {
    /// Deep ACDC cascade (the paper's family).
    Acdc(AcdcCascade),
    /// Adaptive Fastfood `S·H·G·P·H·B` layer.
    Fastfood(FastfoodLayer),
    /// Low-rank `U·V` factorization.
    LowRank(LowRankLayer),
    /// Deep diagonal-circulant cascade (Araujo et al. 2019).
    Circulant(DiagonalCirculantCascade),
}

impl SellModel {
    /// Input/output width N.
    pub fn width(&self) -> usize {
        match self {
            SellModel::Acdc(c) => c.n(),
            SellModel::Fastfood(f) => crate::sell::LinearOp::width(f),
            SellModel::LowRank(l) => crate::sell::LinearOp::width(l),
            SellModel::Circulant(c) => c.n(),
        }
    }

    /// Family name (the checkpoint `kind` and the `/v1/models` field).
    pub fn kind(&self) -> &'static str {
        match self {
            SellModel::Acdc(_) => "acdc",
            SellModel::Fastfood(_) => "fastfood",
            SellModel::LowRank(_) => "lowrank",
            SellModel::Circulant(_) => "circulant",
        }
    }

    /// Learnable parameter count (the Table-1 quantity).
    pub fn param_count(&self) -> usize {
        match self {
            SellModel::Acdc(c) => c.param_count(),
            SellModel::Fastfood(f) => crate::sell::LinearOp::param_count(f),
            SellModel::LowRank(l) => crate::sell::LinearOp::param_count(l),
            SellModel::Circulant(c) => crate::sell::LinearOp::param_count(c),
        }
    }

    /// Forward a `[rows, N]` batch.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        match self {
            SellModel::Acdc(c) => c.forward(x),
            SellModel::Fastfood(f) => crate::sell::LinearOp::forward(f, x),
            SellModel::LowRank(l) => crate::sell::LinearOp::forward(l, x),
            SellModel::Circulant(c) => crate::sell::LinearOp::forward(c, x),
        }
    }

    /// Serialize into a checkpoint manifest (see the module docs for the
    /// key layout). Fails only on permutations too large to store exactly.
    pub fn to_checkpoint(&self) -> Result<Checkpoint, String> {
        let mut ckpt = Checkpoint::new();
        match self {
            SellModel::Acdc(c) => {
                let n = c.n();
                let k = c.k();
                ckpt.insert(
                    "sell.meta",
                    Tensor::from_vec(
                        &[6],
                        vec![
                            KIND_ACDC,
                            n as f32,
                            k as f32,
                            if c.relu { 1.0 } else { 0.0 },
                            if c.train_bias { 1.0 } else { 0.0 },
                            if c.perms.is_some() { 1.0 } else { 0.0 },
                        ],
                    ),
                );
                for (i, layer) in c.layers.iter().enumerate() {
                    ckpt.insert(&format!("acdc.layer{i}.a"), Tensor::from_vec(&[n], layer.a.clone()));
                    ckpt.insert(&format!("acdc.layer{i}.d"), Tensor::from_vec(&[n], layer.d.clone()));
                    ckpt.insert(
                        &format!("acdc.layer{i}.bias"),
                        Tensor::from_vec(&[n], layer.bias.clone()),
                    );
                }
                if let Some(perms) = &c.perms {
                    for (i, perm) in perms.iter().enumerate() {
                        ckpt.insert(&format!("acdc.perm{i}"), perm_to_tensor(perm)?);
                    }
                }
            }
            SellModel::Fastfood(f) => {
                let n = f.s.len();
                ckpt.insert(
                    "sell.meta",
                    Tensor::from_vec(&[2], vec![KIND_FASTFOOD, n as f32]),
                );
                ckpt.insert("ff.s", Tensor::from_vec(&[n], f.s.clone()));
                ckpt.insert("ff.g", Tensor::from_vec(&[n], f.g.clone()));
                ckpt.insert("ff.b", Tensor::from_vec(&[n], f.b.clone()));
                ckpt.insert("ff.perm", perm_to_tensor(&f.perm)?);
            }
            SellModel::LowRank(l) => {
                let n = l.u.rows();
                let r = l.u.cols();
                ckpt.insert(
                    "sell.meta",
                    Tensor::from_vec(&[3], vec![KIND_LOWRANK, n as f32, r as f32]),
                );
                ckpt.insert("lr.u", l.u.clone());
                ckpt.insert("lr.v", l.v.clone());
            }
            SellModel::Circulant(c) => {
                let n = c.n();
                let k = c.depth();
                ckpt.insert(
                    "sell.meta",
                    Tensor::from_vec(&[3], vec![KIND_CIRCULANT, n as f32, k as f32]),
                );
                for (i, layer) in c.layers.iter().enumerate() {
                    // Signs are ±1.0 — exactly representable, so the
                    // roundtrip stays bit-exact like the stored perms.
                    ckpt.insert(
                        &format!("dc.layer{i}.signs"),
                        Tensor::from_vec(&[n], layer.signs.clone()),
                    );
                    ckpt.insert(&format!("dc.layer{i}.r"), Tensor::from_vec(&[n], layer.r.clone()));
                    ckpt.insert(&format!("dc.layer{i}.d"), Tensor::from_vec(&[n], layer.d.clone()));
                }
            }
        }
        Ok(ckpt)
    }

    /// Reconstruct a model from a checkpoint manifest, validating the kind
    /// code and every shape.
    pub fn from_checkpoint(ckpt: &Checkpoint) -> Result<SellModel, String> {
        let meta = ckpt
            .get("sell.meta")
            .ok_or("checkpoint missing 'sell.meta' (not a model manifest)")?;
        let m = meta.data();
        let kind = *m.first().ok_or("empty sell.meta")?;
        if kind == KIND_ACDC {
            if m.len() != 6 {
                return Err(format!("acdc sell.meta must have 6 entries, got {}", m.len()));
            }
            let n = meta_usize(m[1], "n")?;
            let k = meta_usize(m[2], "k")?;
            if k == 0 {
                return Err("acdc cascade depth k must be >= 1".into());
            }
            // Guard before PlanCache::get, whose DctPlan constructor
            // asserts — a corrupt manifest must error, not panic.
            if !n.is_power_of_two() {
                return Err(format!("acdc width must be a power of two, got {n}"));
            }
            let plan = PlanCache::get(n);
            let mut layers = Vec::with_capacity(k);
            for i in 0..k {
                let a = vec_entry(ckpt, &format!("acdc.layer{i}.a"), n)?;
                let d = vec_entry(ckpt, &format!("acdc.layer{i}.d"), n)?;
                let bias = vec_entry(ckpt, &format!("acdc.layer{i}.bias"), n)?;
                layers.push(AcdcLayer::new(a, d, bias, Arc::clone(&plan)));
            }
            let perms = if m[5] != 0.0 {
                let mut ps = Vec::with_capacity(k);
                for i in 0..k {
                    let t = ckpt
                        .get(&format!("acdc.perm{i}"))
                        .ok_or_else(|| format!("checkpoint missing 'acdc.perm{i}'"))?;
                    ps.push(tensor_to_perm(t, n)?);
                }
                Some(ps)
            } else {
                None
            };
            Ok(SellModel::Acdc(AcdcCascade {
                layers,
                perms,
                relu: m[3] != 0.0,
                train_bias: m[4] != 0.0,
            }))
        } else if kind == KIND_FASTFOOD {
            if m.len() != 2 {
                return Err(format!("fastfood sell.meta must have 2 entries, got {}", m.len()));
            }
            let n = meta_usize(m[1], "n")?;
            if !n.is_power_of_two() {
                return Err(format!("fastfood width must be a power of two, got {n}"));
            }
            let s = vec_entry(ckpt, "ff.s", n)?;
            let g = vec_entry(ckpt, "ff.g", n)?;
            let b = vec_entry(ckpt, "ff.b", n)?;
            let perm = tensor_to_perm(
                ckpt.get("ff.perm").ok_or("checkpoint missing 'ff.perm'")?,
                n,
            )?;
            Ok(SellModel::Fastfood(FastfoodLayer::new(s, g, b, perm)))
        } else if kind == KIND_LOWRANK {
            if m.len() != 3 {
                return Err(format!("lowrank sell.meta must have 3 entries, got {}", m.len()));
            }
            let n = meta_usize(m[1], "n")?;
            let r = meta_usize(m[2], "r")?;
            let u = ckpt.get("lr.u").ok_or("checkpoint missing 'lr.u'")?.clone();
            let v = ckpt.get("lr.v").ok_or("checkpoint missing 'lr.v'")?.clone();
            if u.shape() != &[n, r] || v.shape() != &[r, n] {
                return Err(format!(
                    "lowrank factor shapes {:?}/{:?} do not match meta [n={n}, r={r}]",
                    u.shape(),
                    v.shape()
                ));
            }
            Ok(SellModel::LowRank(LowRankLayer::new(u, v)))
        } else if kind == KIND_CIRCULANT {
            if m.len() != 3 {
                return Err(format!(
                    "circulant sell.meta must have 3 entries, got {}",
                    m.len()
                ));
            }
            let n = meta_usize(m[1], "n")?;
            let k = meta_usize(m[2], "k")?;
            if k == 0 {
                return Err("circulant cascade depth k must be >= 1".into());
            }
            // Guard before FftPlan::new, whose constructor asserts —
            // a corrupt manifest must error, not panic.
            if !n.is_power_of_two() {
                return Err(format!("circulant width must be a power of two, got {n}"));
            }
            let mut layers = Vec::with_capacity(k);
            for i in 0..k {
                let signs = vec_entry(ckpt, &format!("dc.layer{i}.signs"), n)?;
                if let Some(bad) = signs.iter().find(|&&s| s != 1.0 && s != -1.0) {
                    return Err(format!("'dc.layer{i}.signs' entry {bad} is not ±1"));
                }
                let r = vec_entry(ckpt, &format!("dc.layer{i}.r"), n)?;
                let d = vec_entry(ckpt, &format!("dc.layer{i}.d"), n)?;
                layers.push(DiagonalCirculantLayer::new(signs, r, d));
            }
            Ok(SellModel::Circulant(DiagonalCirculantCascade::new(layers)))
        } else {
            Err(format!("unknown sell kind code {kind}"))
        }
    }
}

fn meta_usize(v: f32, what: &str) -> Result<usize, String> {
    if v < 0.0 || v.fract() != 0.0 || v >= MAX_EXACT_U32 as f32 {
        return Err(format!("sell.meta {what} = {v} is not a valid size"));
    }
    Ok(v as usize)
}

fn vec_entry(ckpt: &Checkpoint, name: &str, n: usize) -> Result<Vec<f32>, String> {
    let t = ckpt
        .get(name)
        .ok_or_else(|| format!("checkpoint missing '{name}'"))?;
    if t.shape() != &[n] {
        return Err(format!("'{name}' has shape {:?}, want [{n}]", t.shape()));
    }
    Ok(t.data().to_vec())
}

fn perm_to_tensor(perm: &[u32]) -> Result<Tensor, String> {
    if perm.iter().any(|&p| p >= MAX_EXACT_U32) {
        return Err("permutation index too large to store exactly".into());
    }
    Ok(Tensor::from_vec(
        &[perm.len()],
        perm.iter().map(|&p| p as f32).collect(),
    ))
}

fn tensor_to_perm(t: &Tensor, n: usize) -> Result<Vec<u32>, String> {
    if t.shape() != &[n] {
        return Err(format!("permutation has shape {:?}, want [{n}]", t.shape()));
    }
    let mut perm = Vec::with_capacity(n);
    let mut seen = vec![false; n];
    for &v in t.data() {
        if v < 0.0 || v.fract() != 0.0 || v >= n as f32 {
            return Err(format!("permutation entry {v} is not an index below {n}"));
        }
        let p = v as usize;
        if seen[p] {
            return Err(format!("permutation repeats index {p}"));
        }
        seen[p] = true;
        perm.push(p as u32);
    }
    Ok(perm)
}

/// [`BatchExecutor`] over any [`SellModel`] — the registry's per-worker
/// executor. ACDC cascades ride the batched SoA engine exactly like
/// [`crate::coordinator::worker::NativeCascadeExecutor`] (pooled panels
/// for buckets ≥ 32, otherwise the allocation-free worker-local
/// [`crate::sell::acdc::CascadeScratch`] path); the other families use
/// their own batch forwards.
pub struct SellModelExecutor {
    /// The model evaluated per batch (one clone per worker thread).
    pub model: SellModel,
    /// Worker-local reusable forward buffers (ACDC path).
    scratch: crate::sell::acdc::CascadeScratch,
}

impl SellModelExecutor {
    /// Executor over `model` with fresh (lazily grown) scratch.
    pub fn new(model: SellModel) -> SellModelExecutor {
        let n = model.width();
        SellModelExecutor {
            model,
            scratch: crate::sell::acdc::CascadeScratch::new(n, 1),
        }
    }
}

impl BatchExecutor for SellModelExecutor {
    fn width(&self) -> usize {
        self.model.width()
    }

    fn out_width(&self) -> usize {
        self.model.width()
    }

    fn execute_into(
        &mut self,
        bucket: usize,
        padded: &[f32],
        out: &mut [f32],
    ) -> Result<(), String> {
        let n = self.model.width();
        if padded.len() != bucket * n {
            return Err(format!(
                "padded buffer {} != bucket {bucket} × n {n}",
                padded.len()
            ));
        }
        if out.len() != bucket * n {
            return Err(format!(
                "output buffer {} != bucket {bucket} × n {n}",
                out.len()
            ));
        }
        if let SellModel::Acdc(cascade) = &self.model {
            if bucket >= 32 {
                let pool = crate::util::threadpool::global();
                let x = Tensor::from_vec(&[bucket, n], padded.to_vec());
                out.copy_from_slice(cascade.forward_pooled(&x, pool).data());
            } else {
                cascade.forward_rows_into(padded, bucket, out, &mut self.scratch);
            }
            return Ok(());
        }
        let x = Tensor::from_vec(&[bucket, n], padded.to_vec());
        out.copy_from_slice(self.model.forward(&x).data());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sell::init::DiagInit;
    use crate::util::rng::Pcg32;

    fn exact_eq(a: &Tensor, b: &Tensor) -> bool {
        a.shape() == b.shape()
            && a.data()
                .iter()
                .zip(b.data())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn acdc_checkpoint_roundtrip_is_bit_exact() {
        let mut rng = Pcg32::seeded(1);
        let cascade = AcdcCascade::nonlinear(16, 3, DiagInit::CAFFENET, &mut rng);
        let model = SellModel::Acdc(cascade);
        let re = SellModel::from_checkpoint(&model.to_checkpoint().unwrap()).unwrap();
        assert_eq!(re.kind(), "acdc");
        assert_eq!(re.width(), 16);
        let x = Tensor::from_vec(&[5, 16], rng.normal_vec(80, 0.0, 1.0));
        assert!(exact_eq(&model.forward(&x), &re.forward(&x)));
    }

    #[test]
    fn acdc_linear_cascade_roundtrips_without_perms() {
        let mut rng = Pcg32::seeded(2);
        let model = SellModel::Acdc(AcdcCascade::linear(8, 2, DiagInit::CAFFENET, &mut rng));
        let re = SellModel::from_checkpoint(&model.to_checkpoint().unwrap()).unwrap();
        match re {
            SellModel::Acdc(c) => {
                assert!(c.perms.is_none());
                assert!(!c.relu);
            }
            other => panic!("wrong kind {}", other.kind()),
        }
    }

    #[test]
    fn fastfood_checkpoint_roundtrip_is_bit_exact() {
        let mut rng = Pcg32::seeded(3);
        let model = SellModel::Fastfood(FastfoodLayer::random(32, &mut rng));
        let re = SellModel::from_checkpoint(&model.to_checkpoint().unwrap()).unwrap();
        assert_eq!(re.kind(), "fastfood");
        let x = Tensor::from_vec(&[3, 32], rng.normal_vec(96, 0.0, 1.0));
        assert!(exact_eq(&model.forward(&x), &re.forward(&x)));
    }

    #[test]
    fn lowrank_checkpoint_roundtrip_is_bit_exact() {
        let mut rng = Pcg32::seeded(4);
        let model = SellModel::LowRank(LowRankLayer::random(24, 4, &mut rng));
        let re = SellModel::from_checkpoint(&model.to_checkpoint().unwrap()).unwrap();
        assert_eq!(re.kind(), "lowrank");
        assert_eq!(re.param_count(), 2 * 24 * 4);
        let x = Tensor::from_vec(&[2, 24], rng.normal_vec(48, 0.0, 1.0));
        assert!(exact_eq(&model.forward(&x), &re.forward(&x)));
    }

    #[test]
    fn circulant_checkpoint_roundtrip_is_bit_exact() {
        let mut rng = Pcg32::seeded(8);
        let cascade = DiagonalCirculantCascade::init(16, 2, DiagInit::CAFFENET, &mut rng);
        let model = SellModel::Circulant(cascade);
        let re = SellModel::from_checkpoint(&model.to_checkpoint().unwrap()).unwrap();
        assert_eq!(re.kind(), "circulant");
        assert_eq!(re.width(), 16);
        assert_eq!(re.param_count(), 2 * 16 * 2);
        // Signs survive as exactly-representable ±1 integers.
        match (&model, &re) {
            (SellModel::Circulant(a), SellModel::Circulant(b)) => {
                for (la, lb) in a.layers.iter().zip(&b.layers) {
                    assert_eq!(la.signs, lb.signs);
                    assert!(lb.signs.iter().all(|&s| s == 1.0 || s == -1.0));
                }
            }
            _ => unreachable!(),
        }
        let x = Tensor::from_vec(&[5, 16], rng.normal_vec(80, 0.0, 1.0));
        assert!(exact_eq(&model.forward(&x), &re.forward(&x)));
    }

    #[test]
    fn circulant_from_checkpoint_rejects_corrupt_manifests() {
        let mut rng = Pcg32::seeded(9);
        let model = SellModel::Circulant(DiagonalCirculantCascade::init(
            16,
            2,
            DiagInit::CAFFENET,
            &mut rng,
        ));
        let good = model.to_checkpoint().unwrap();
        // Each corruption must surface as Err — never a panic.
        let mut bad = good.clone();
        bad.entries.remove("dc.layer1.r");
        assert!(SellModel::from_checkpoint(&bad)
            .unwrap_err()
            .contains("dc.layer1.r"));
        // Non-±1 sign entry.
        let mut bad = good.clone();
        let mut signs = vec![1.0f32; 16];
        signs[3] = 0.25;
        bad.insert("dc.layer0.signs", Tensor::from_vec(&[16], signs));
        assert!(SellModel::from_checkpoint(&bad).unwrap_err().contains("±1"));
        // Non-pow2 width must Err before the FFT plan's assert can fire.
        let mut bad = good.clone();
        bad.insert("sell.meta", Tensor::from_vec(&[3], vec![3.0, 12.0, 2.0]));
        assert!(SellModel::from_checkpoint(&bad)
            .unwrap_err()
            .contains("power of two"));
        // Zero depth.
        let mut bad = good.clone();
        bad.insert("sell.meta", Tensor::from_vec(&[3], vec![3.0, 16.0, 0.0]));
        assert!(SellModel::from_checkpoint(&bad).unwrap_err().contains("depth"));
        // Wrong-length bank.
        let mut bad = good.clone();
        bad.insert("dc.layer0.d", Tensor::from_vec(&[4], vec![1.0; 4]));
        assert!(SellModel::from_checkpoint(&bad).unwrap_err().contains("shape"));
    }

    #[test]
    fn from_checkpoint_rejects_corrupt_manifests() {
        let mut rng = Pcg32::seeded(5);
        let model = SellModel::Fastfood(FastfoodLayer::random(16, &mut rng));
        let good = model.to_checkpoint().unwrap();
        // Not a model manifest at all.
        assert!(SellModel::from_checkpoint(&Checkpoint::new())
            .unwrap_err()
            .contains("sell.meta"));
        // Missing a parameter bank.
        let mut bad = good.clone();
        bad.entries.remove("ff.g");
        assert!(SellModel::from_checkpoint(&bad).unwrap_err().contains("ff.g"));
        // Invalid permutation (repeated index).
        let mut bad = good.clone();
        bad.insert("ff.perm", Tensor::from_vec(&[16], vec![0.0; 16]));
        assert!(SellModel::from_checkpoint(&bad).unwrap_err().contains("repeats"));
        // Unknown kind code.
        let mut bad = good.clone();
        bad.insert("sell.meta", Tensor::from_vec(&[2], vec![9.0, 16.0]));
        assert!(SellModel::from_checkpoint(&bad).unwrap_err().contains("unknown"));
    }

    #[test]
    fn executor_matches_direct_forward() {
        let mut rng = Pcg32::seeded(6);
        let model = SellModel::LowRank(LowRankLayer::random(8, 2, &mut rng));
        let x = Tensor::from_vec(&[4, 8], rng.normal_vec(32, 0.0, 1.0));
        let mut exe = SellModelExecutor::new(model.clone());
        let mut got = vec![0.0f32; 32];
        exe.execute_into(4, x.data(), &mut got).unwrap();
        assert_eq!(got, model.forward(&x).data());
        let mut bad = vec![0.0f32; 32];
        assert!(
            exe.execute_into(4, &[0.0; 3], &mut bad).is_err(),
            "bad buffer length"
        );
    }

    #[test]
    fn acdc_executor_matches_direct_forward_across_buckets() {
        let mut rng = Pcg32::seeded(7);
        let cascade = AcdcCascade::nonlinear(16, 2, DiagInit::CAFFENET, &mut rng);
        let model = SellModel::Acdc(cascade);
        let mut exe = SellModelExecutor::new(model.clone());
        for bucket in [1usize, 4, 8] {
            let x = Tensor::from_vec(
                &[bucket, 16],
                rng.normal_vec(bucket * 16, 0.0, 1.0),
            );
            let mut got = vec![0.0f32; bucket * 16];
            exe.execute_into(bucket, x.data(), &mut got).unwrap();
            let want = model.forward(&x);
            // The scratch path must be bit-identical to the direct forward.
            for (g, w) in got.iter().zip(want.data()) {
                assert_eq!(g.to_bits(), w.to_bits(), "bucket={bucket}");
            }
        }
    }
}
