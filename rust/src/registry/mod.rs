//! Multi-tenant model registry with hot-swap checkpoint serving.
//!
//! The registry owns named, versioned [`SellModel`]s, each served by its
//! own batching coordinator (a [`Server`]), so batches are formed strictly
//! per `(model, version)` — rows of different tenants or different
//! checkpoint versions never share a padded batch.
//!
//! **Epoch handoff** is the swap mechanism (DESIGN.md §5): the live
//! version of a model is one `Arc<ModelEpoch>`; admission clones that
//! `Arc` into a [`ModelHandle`] held for the whole submit → response
//! window. Loading a new version atomically replaces the entry's current
//! epoch, so *new* admissions see the new version immediately while
//! *in-flight* requests keep their clone of the old epoch and finish on
//! the old coordinator. When the last handle to an old epoch drops, the
//! epoch's coordinator drains and its worker threads join — the `Arc`
//! refcount is the epoch's lifetime, no reference counting bolted on.
//!
//! [`ModelRegistry::unload`] refuses (with [`RegistryError::Busy`]) while
//! any handle is outstanding; handle counting shares the registry lock
//! with admission, so the refusal cannot race a concurrent resolve.

pub mod model;

pub use model::{SellModel, SellModelExecutor};

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::checkpoint::Checkpoint;
use crate::config::ServeConfig;
use crate::coordinator::request::{InferResponse, ResponseSlot, RowRef};
use crate::coordinator::worker::{BatchExecutor, ExecutorFactory};
use crate::coordinator::SubmitError;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::serve::Server;
use crate::trace::log::{self, Field, Level};

/// Why a registry operation failed. Maps onto HTTP statuses at the
/// gateway (404 / 409 / 400).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// No model or alias with that name.
    NotFound(String),
    /// Unload refused: requests are still in flight on the model.
    Busy {
        /// The model that refused to unload.
        name: String,
        /// Outstanding handle count at refusal time.
        inflight: u64,
    },
    /// Malformed request (bad checkpoint, name collision, …).
    Invalid(String),
}

impl RegistryError {
    /// The HTTP status this error maps to at the gateway.
    pub fn status(&self) -> u16 {
        match self {
            RegistryError::NotFound(_) => 404,
            RegistryError::Busy { .. } => 409,
            RegistryError::Invalid(_) => 400,
        }
    }
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::NotFound(name) => write!(f, "unknown model '{name}'"),
            RegistryError::Busy { name, inflight } => {
                write!(f, "model '{name}' is busy ({inflight} requests in flight)")
            }
            RegistryError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

/// One immutable loaded version of a model: the coordinator serving it
/// plus identity metadata. Lives behind an `Arc`; dropping the last
/// reference drains the coordinator (see the module docs).
pub struct ModelEpoch {
    version: u64,
    kind: String,
    width: usize,
    params: usize,
    server: Server,
}

impl ModelEpoch {
    /// Checkpoint version this epoch serves.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Model family name (`acdc` / `fastfood` / `lowrank` / `custom`).
    pub fn kind(&self) -> &str {
        &self.kind
    }

    /// Input width N.
    pub fn width(&self) -> usize {
        self.width
    }
}

/// A named model slot: the current epoch plus handle accounting.
struct ModelEntry {
    name: String,
    current: Mutex<Arc<ModelEpoch>>,
    /// Outstanding [`ModelHandle`]s across *all* epochs of this model.
    inflight: AtomicU64,
    next_version: AtomicU64,
    requests: Arc<Counter>,
    loads: Arc<Counter>,
    swaps: Arc<Counter>,
    version_gauge: Arc<Gauge>,
    inflight_gauge: Arc<Gauge>,
    /// Per-model end-to-end request latency, resolved once at install so
    /// the per-request hot path is a relaxed-atomic record — never a
    /// `format!` + registry lookup.
    request_ns: Arc<Histogram>,
}

/// RAII admission ticket: pins one epoch of one model for the lifetime of
/// a request. Holding a handle blocks [`ModelRegistry::unload`].
pub struct ModelHandle {
    entry: Arc<ModelEntry>,
    epoch: Arc<ModelEpoch>,
}

impl ModelHandle {
    /// The model's registered name.
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    /// The pinned epoch's version.
    pub fn version(&self) -> u64 {
        self.epoch.version
    }

    /// The pinned epoch's model family.
    pub fn kind(&self) -> &str {
        &self.epoch.kind
    }

    /// Input width N of the pinned epoch.
    pub fn width(&self) -> usize {
        self.epoch.width
    }

    /// Submit one feature row to the pinned epoch's coordinator.
    pub fn submit(&self, features: Vec<f32>) -> Result<Receiver<InferResponse>, SubmitError> {
        self.epoch.server.submit(features)
    }

    /// Submit one arena row on the zero-allocation slot path (see
    /// [`crate::coordinator::Coordinator::submit_slot`]). `trace` is the
    /// request's trace ID (0 = untraced); `deadline` is the
    /// admission-minted deadline past which the coordinator reaps
    /// instead of executing.
    pub fn submit_slot(
        &self,
        row: RowRef,
        slot: &Arc<ResponseSlot>,
        trace: u64,
        deadline: Option<std::time::Instant>,
    ) -> Result<(), SubmitError> {
        self.epoch.server.submit_slot(row, slot, trace, deadline)
    }

    /// Submit one row and block for the answer.
    pub fn infer(&self, features: Vec<f32>, timeout: Duration) -> Result<Vec<f32>, String> {
        self.epoch.server.infer(features, timeout)
    }

    /// Record one completed request's end-to-end latency into the model's
    /// cached histogram handle (`model.{name}.request_ns`) — one relaxed
    /// atomic op, no name formatting on the hot path.
    pub fn observe_request(&self, elapsed: Duration) {
        self.entry.request_ns.record(elapsed);
    }
}

impl Drop for ModelHandle {
    fn drop(&mut self) {
        self.entry.inflight.fetch_sub(1, Ordering::AcqRel);
        self.entry.inflight_gauge.dec();
        // `epoch` drops here; if this was the last reference to a
        // swapped-out epoch, its coordinator drains now.
    }
}

/// A row of `GET /v1/models` / `acdc registry list`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    /// Registered model name.
    pub name: String,
    /// Live checkpoint version.
    pub version: u64,
    /// Model family (`acdc` / `fastfood` / `lowrank` / `custom`).
    pub kind: String,
    /// Input width N.
    pub width: usize,
    /// Learnable parameter count (0 for custom servers).
    pub params: usize,
    /// Outstanding request handles right now.
    pub inflight: u64,
    /// Aliases resolving to this model, sorted.
    pub aliases: Vec<String>,
    /// Whether legacy `/v1/infer` routes here.
    pub is_default: bool,
}

struct Inner {
    models: HashMap<String, Arc<ModelEntry>>,
    aliases: HashMap<String, String>,
    default_model: Option<String>,
}

/// The multi-tenant model registry. See the module docs for the epoch
/// handoff protocol.
pub struct ModelRegistry {
    inner: Mutex<Inner>,
    /// Coordinator template applied to every loaded model (buckets,
    /// max_wait, workers, queue_cap).
    template: ServeConfig,
    metrics: Arc<Registry>,
}

impl ModelRegistry {
    /// Empty registry. `template` supplies the coordinator knobs every
    /// loaded model's server is started with; per-model instruments are
    /// registered in `metrics` (the gateway's shared registry).
    pub fn new(template: ServeConfig, metrics: Arc<Registry>) -> ModelRegistry {
        ModelRegistry {
            inner: Mutex::new(Inner {
                models: HashMap::new(),
                aliases: HashMap::new(),
                default_model: None,
            }),
            template,
            metrics,
        }
    }

    /// The shared metrics registry (per-model instruments live here).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Load (or hot-swap) `model` under `name`. Returns the version now
    /// live: `version` if given, else one past the previous version.
    ///
    /// On a swap, in-flight requests finish on the old epoch while new
    /// admissions immediately see the new one; the old coordinator drains
    /// when its last handle drops.
    pub fn load(
        &self,
        name: &str,
        model: SellModel,
        version: Option<u64>,
    ) -> Result<u64, RegistryError> {
        validate_name(name)?;
        let width = model.width();
        let kind = model.kind().to_string();
        let params = model.param_count();
        // Build the new epoch's coordinator *before* taking the registry
        // lock — worker-thread spawning must not serialize admissions.
        let factory: ExecutorFactory = Arc::new(move || {
            Ok(Box::new(SellModelExecutor::new(model.clone())) as Box<dyn BatchExecutor>)
        });
        // Coordinator/worker instruments share the registry-wide metrics,
        // so `GET /metrics` aggregates them fleet-wide.
        let server = Server::start_custom_with_metrics(
            &self.template,
            width,
            factory,
            Arc::clone(&self.metrics),
        );
        self.install(name, kind, width, params, server, version)
    }

    /// [`ModelRegistry::load`] from a checkpoint manifest on disk.
    pub fn load_path(
        &self,
        name: &str,
        path: &Path,
        version: Option<u64>,
    ) -> Result<u64, RegistryError> {
        let ckpt = Checkpoint::load(path).map_err(RegistryError::Invalid)?;
        let model = SellModel::from_checkpoint(&ckpt).map_err(RegistryError::Invalid)?;
        self.load(name, model, version)
    }

    /// Register an externally-constructed [`Server`] under `name` (the
    /// legacy single-model gateway path and custom-executor tests).
    pub fn insert_server(
        &self,
        name: &str,
        kind: &str,
        server: Server,
        version: Option<u64>,
    ) -> Result<u64, RegistryError> {
        validate_name(name)?;
        let width = server.width();
        self.install(name, kind.to_string(), width, 0, server, version)
    }

    fn install(
        &self,
        name: &str,
        kind: String,
        width: usize,
        params: usize,
        server: Server,
        version: Option<u64>,
    ) -> Result<u64, RegistryError> {
        let mut old_epoch = None;
        let v;
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.aliases.contains_key(name) {
                return Err(RegistryError::Invalid(format!(
                    "'{name}' is an alias; load under the model name instead"
                )));
            }
            match inner.models.get(name) {
                Some(entry) => {
                    v = version.unwrap_or_else(|| entry.next_version.load(Ordering::Relaxed));
                    entry.next_version.store(v + 1, Ordering::Relaxed);
                    let epoch = Arc::new(ModelEpoch {
                        version: v,
                        kind,
                        width,
                        params,
                        server,
                    });
                    let mut cur = entry.current.lock().unwrap();
                    old_epoch = Some(std::mem::replace(&mut *cur, epoch));
                    entry.swaps.inc();
                    entry.loads.inc();
                    entry.version_gauge.set(v);
                }
                None => {
                    v = version.unwrap_or(1);
                    let entry = Arc::new(ModelEntry {
                        name: name.to_string(),
                        current: Mutex::new(Arc::new(ModelEpoch {
                            version: v,
                            kind,
                            width,
                            params,
                            server,
                        })),
                        inflight: AtomicU64::new(0),
                        next_version: AtomicU64::new(v + 1),
                        requests: self.metrics.counter(&format!("model.{name}.requests")),
                        loads: self.metrics.counter(&format!("model.{name}.loads")),
                        swaps: self.metrics.counter(&format!("model.{name}.swaps")),
                        version_gauge: self.metrics.gauge(&format!("model.{name}.version")),
                        inflight_gauge: self.metrics.gauge(&format!("model.{name}.inflight")),
                        request_ns: self.metrics.histogram(&format!("model.{name}.request_ns")),
                    });
                    entry.loads.inc();
                    entry.version_gauge.set(v);
                    if inner.default_model.is_none() {
                        inner.default_model = Some(name.to_string());
                    }
                    inner.models.insert(name.to_string(), entry);
                }
            }
        }
        let swapped = old_epoch.is_some();
        // Drop the swapped-out epoch outside every lock: if no handles
        // pin it, its coordinator drains right here.
        drop(old_epoch);
        log::event(
            Level::Info,
            "registry",
            if swapped { "model_swapped" } else { "model_loaded" },
            0,
            &[("model", Field::Str(name)), ("version", Field::U64(v))],
        );
        Ok(v)
    }

    /// Unload `name`, refusing with [`RegistryError::Busy`] while any
    /// request handle is outstanding. Aliases to the model are removed.
    pub fn unload(&self, name: &str) -> Result<(), RegistryError> {
        let entry = {
            let mut inner = self.inner.lock().unwrap();
            let canonical = resolve_name(&inner, name)?;
            let entry = Arc::clone(&inner.models[&canonical]);
            // Handles are minted under this same lock, so the check and
            // the removal are one atomic step.
            let inflight = entry.inflight.load(Ordering::Acquire);
            if inflight > 0 {
                return Err(RegistryError::Busy {
                    name: canonical,
                    inflight,
                });
            }
            // Resolve the default *before* removing the model: the
            // default may be an alias to it, which would dangle forever
            // (install only assigns a default when none is set).
            let default_points_here = inner
                .default_model
                .as_ref()
                .and_then(|d| resolve_name(&inner, d).ok())
                .as_deref()
                == Some(canonical.as_str());
            inner.models.remove(&canonical);
            inner.aliases.retain(|_, target| *target != canonical);
            if default_points_here {
                inner.default_model = None;
            }
            entry
        };
        // Last registry reference: the epoch (and its coordinator) drain
        // here, outside the lock.
        drop(entry);
        log::event(
            Level::Info,
            "registry",
            "model_unloaded",
            0,
            &[("model", Field::Str(name))],
        );
        Ok(())
    }

    /// Point alias `alias` at model `target` (replacing any previous
    /// target). The alias namespace is disjoint from model names.
    pub fn alias(&self, alias: &str, target: &str) -> Result<(), RegistryError> {
        validate_name(alias)?;
        let mut inner = self.inner.lock().unwrap();
        if inner.models.contains_key(alias) {
            return Err(RegistryError::Invalid(format!(
                "'{alias}' is already a model name"
            )));
        }
        if !inner.models.contains_key(target) {
            return Err(RegistryError::NotFound(target.to_string()));
        }
        inner.aliases.insert(alias.to_string(), target.to_string());
        Ok(())
    }

    /// Route legacy `/v1/infer` traffic to `name` (a model or alias).
    pub fn set_default(&self, name: &str) -> Result<(), RegistryError> {
        let mut inner = self.inner.lock().unwrap();
        resolve_name(&inner, name)?;
        inner.default_model = Some(name.to_string());
        Ok(())
    }

    /// The current default model name, if any.
    pub fn default_model(&self) -> Option<String> {
        self.inner.lock().unwrap().default_model.clone()
    }

    /// Width of the default model (for `/healthz`), if one is set.
    pub fn default_width(&self) -> Option<usize> {
        let inner = self.inner.lock().unwrap();
        let name = inner.default_model.clone()?;
        let canonical = resolve_name(&inner, &name).ok()?;
        let entry = inner.models.get(&canonical)?;
        let w = entry.current.lock().unwrap().width;
        Some(w)
    }

    /// Admit one request: pin the current epoch of `name` (model or
    /// alias) behind a [`ModelHandle`]. Allocation-free on success (the
    /// admission fast path): name resolution borrows, the handle is two
    /// `Arc` clones, and every metric handle was cached at install.
    pub fn resolve(&self, name: &str) -> Result<ModelHandle, RegistryError> {
        let inner = self.inner.lock().unwrap();
        mint_handle(&inner, name)
    }

    /// [`ModelRegistry::resolve`] on the default model (also
    /// allocation-free on success — one lock, no name cloning).
    pub fn resolve_default(&self) -> Result<ModelHandle, RegistryError> {
        let inner = self.inner.lock().unwrap();
        match &inner.default_model {
            Some(name) => mint_handle(&inner, name),
            None => Err(RegistryError::NotFound("(no default model)".to_string())),
        }
    }

    /// Whether `name` is currently an alias (loads — and training jobs —
    /// must target the model name, never an alias).
    pub fn is_alias(&self, name: &str) -> bool {
        self.inner.lock().unwrap().aliases.contains_key(name)
    }

    /// Number of loaded models (cheaper than [`ModelRegistry::list`] for
    /// health probes).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().models.len()
    }

    /// Whether no models are loaded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of one model (or alias) by name, or `None` if not
    /// loaded. Backs the shard's `GET /v1/models/{name}` route — the
    /// cluster router polls the `inflight` field to decide when a
    /// replica has drained during a rolling swap.
    pub fn info(&self, name: &str) -> Option<ModelInfo> {
        let inner = self.inner.lock().unwrap();
        let canonical = resolve_name(&inner, name).ok()?;
        let entry = inner.models.get(&canonical)?;
        let epoch = entry.current.lock().unwrap();
        let mut aliases: Vec<String> = inner
            .aliases
            .iter()
            .filter(|(_, target)| **target == canonical)
            .map(|(alias, _)| alias.clone())
            .collect();
        aliases.sort();
        let default_canonical = inner
            .default_model
            .as_ref()
            .and_then(|d| resolve_name(&inner, d).ok());
        Some(ModelInfo {
            name: canonical.clone(),
            version: epoch.version,
            kind: epoch.kind.clone(),
            width: epoch.width,
            params: epoch.params,
            inflight: entry.inflight.load(Ordering::Acquire),
            aliases,
            is_default: default_canonical.as_deref() == Some(canonical.as_str()),
        })
    }

    /// Snapshot of every loaded model, sorted by name.
    pub fn list(&self) -> Vec<ModelInfo> {
        let inner = self.inner.lock().unwrap();
        let default_canonical = inner
            .default_model
            .as_ref()
            .and_then(|d| resolve_name(&inner, d).ok());
        let mut out: Vec<ModelInfo> = inner
            .models
            .iter()
            .map(|(name, entry)| {
                let epoch = entry.current.lock().unwrap();
                let mut aliases: Vec<String> = inner
                    .aliases
                    .iter()
                    .filter(|(_, target)| *target == name)
                    .map(|(alias, _)| alias.clone())
                    .collect();
                aliases.sort();
                ModelInfo {
                    name: name.clone(),
                    version: epoch.version,
                    kind: epoch.kind.clone(),
                    width: epoch.width,
                    params: epoch.params,
                    inflight: entry.inflight.load(Ordering::Acquire),
                    aliases,
                    is_default: default_canonical.as_deref() == Some(name.as_str()),
                }
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }
}

/// Pin the current epoch of `name` (model or alias) under the held
/// registry lock. Allocation-free on success: name resolution borrows,
/// the handle is two `Arc` clones, and every metric handle was cached at
/// install. Counting under the lock keeps unload's busy check race-free.
fn mint_handle(inner: &Inner, name: &str) -> Result<ModelHandle, RegistryError> {
    let entry = match inner.models.get(name) {
        Some(e) => e,
        None => inner
            .aliases
            .get(name)
            .and_then(|target| inner.models.get(target))
            .ok_or_else(|| RegistryError::NotFound(name.to_string()))?,
    };
    let entry = Arc::clone(entry);
    entry.inflight.fetch_add(1, Ordering::AcqRel);
    entry.inflight_gauge.inc();
    entry.requests.inc();
    let epoch = Arc::clone(&entry.current.lock().unwrap());
    Ok(ModelHandle { entry, epoch })
}

/// Canonical model name for `name` (resolving one level of alias).
fn resolve_name(inner: &Inner, name: &str) -> Result<String, RegistryError> {
    if inner.models.contains_key(name) {
        return Ok(name.to_string());
    }
    if let Some(target) = inner.aliases.get(name) {
        if inner.models.contains_key(target) {
            return Ok(target.clone());
        }
    }
    Err(RegistryError::NotFound(name.to_string()))
}

/// Model/alias names appear in URL paths and metric names; keep them to
/// a conservative charset. Shared with the trainer, whose job names are
/// the model names they promote into.
pub(crate) fn validate_name(name: &str) -> Result<(), RegistryError> {
    if name.is_empty() || name.len() > 64 {
        return Err(RegistryError::Invalid(
            "model name must be 1..=64 characters".to_string(),
        ));
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.')
    {
        return Err(RegistryError::Invalid(format!(
            "model name '{name}' may only contain [A-Za-z0-9._-]"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sell::acdc::AcdcCascade;
    use crate::sell::init::DiagInit;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    fn template() -> ServeConfig {
        ServeConfig {
            buckets: vec![1, 4],
            max_wait_us: 200,
            workers: 1,
            queue_cap: 64,
            ..Default::default()
        }
    }

    fn registry() -> ModelRegistry {
        ModelRegistry::new(template(), Arc::new(Registry::new()))
    }

    fn cascade(seed: u64, n: usize) -> AcdcCascade {
        let mut rng = Pcg32::seeded(seed);
        AcdcCascade::nonlinear(n, 2, DiagInit::CAFFENET, &mut rng)
    }

    #[test]
    fn load_resolve_infer_matches_direct_forward() {
        let reg = registry();
        let c = cascade(1, 16);
        let v = reg.load("m", SellModel::Acdc(c.clone()), None).unwrap();
        assert_eq!(v, 1);
        let handle = reg.resolve("m").unwrap();
        assert_eq!(handle.width(), 16);
        assert_eq!(handle.kind(), "acdc");
        let mut rng = Pcg32::seeded(9);
        let x = rng.normal_vec(16, 0.0, 1.0);
        let got = handle.infer(x.clone(), Duration::from_secs(5)).unwrap();
        let want = c.forward(&Tensor::from_vec(&[1, 16], x));
        for (g, w) in got.iter().zip(want.data()) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn first_load_becomes_default() {
        let reg = registry();
        reg.load("a", SellModel::Acdc(cascade(1, 8)), None).unwrap();
        reg.load("b", SellModel::Acdc(cascade(2, 8)), None).unwrap();
        assert_eq!(reg.default_model().as_deref(), Some("a"));
        assert_eq!(reg.default_width(), Some(8));
        reg.set_default("b").unwrap();
        assert_eq!(reg.resolve_default().unwrap().name(), "b");
        assert!(reg.set_default("nope").is_err());
    }

    #[test]
    fn hot_swap_versions_and_inflight_pinning() {
        let reg = registry();
        reg.load("m", SellModel::Acdc(cascade(1, 8)), None).unwrap();
        // A pre-swap admission pins version 1…
        let h1 = reg.resolve("m").unwrap();
        assert_eq!(h1.version(), 1);
        let rx = h1.submit(vec![0.5; 8]).unwrap();
        // …while the swap installs version 2 for new admissions.
        let v = reg.load("m", SellModel::Acdc(cascade(2, 8)), None).unwrap();
        assert_eq!(v, 2);
        let h2 = reg.resolve("m").unwrap();
        assert_eq!(h2.version(), 2);
        // The in-flight request still completes on the old epoch.
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output.unwrap().len(), 8);
        drop(h1);
        // Explicit version numbers advance the counter past themselves.
        let v = reg.load("m", SellModel::Acdc(cascade(3, 8)), Some(10)).unwrap();
        assert_eq!(v, 10);
        let v = reg.load("m", SellModel::Acdc(cascade(4, 8)), None).unwrap();
        assert_eq!(v, 11);
    }

    #[test]
    fn unload_refuses_while_busy_then_succeeds() {
        let reg = registry();
        reg.load("m", SellModel::Acdc(cascade(1, 8)), None).unwrap();
        let handle = reg.resolve("m").unwrap();
        match reg.unload("m").unwrap_err() {
            RegistryError::Busy { name, inflight } => {
                assert_eq!(name, "m");
                assert_eq!(inflight, 1);
            }
            other => panic!("expected Busy, got {other:?}"),
        }
        drop(handle);
        reg.unload("m").unwrap();
        assert!(matches!(
            reg.resolve("m").unwrap_err(),
            RegistryError::NotFound(_)
        ));
        assert!(reg.default_model().is_none(), "default cleared on unload");
    }

    #[test]
    fn aliases_resolve_and_follow_unload() {
        let reg = registry();
        reg.load("m-v2", SellModel::Acdc(cascade(1, 8)), None).unwrap();
        reg.alias("stable", "m-v2").unwrap();
        assert_eq!(reg.resolve("stable").unwrap().name(), "m-v2");
        // Alias namespace is disjoint from model names.
        assert!(reg.alias("m-v2", "m-v2").is_err());
        assert!(reg.alias("dangling", "nope").is_err());
        assert!(reg
            .load("stable", SellModel::Acdc(cascade(2, 8)), None)
            .is_err());
        let infos = reg.list();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].aliases, vec!["stable".to_string()]);
        assert!(infos[0].is_default);
        reg.unload("m-v2").unwrap();
        assert!(reg.resolve("stable").is_err(), "alias removed with model");
    }

    #[test]
    fn unload_clears_a_default_that_was_an_alias() {
        let reg = registry();
        reg.load("m1", SellModel::Acdc(cascade(1, 8)), None).unwrap();
        reg.alias("stable", "m1").unwrap();
        reg.set_default("stable").unwrap();
        reg.unload("m1").unwrap();
        // The aliased default must not dangle: a fresh load becomes the
        // default again instead of /v1/infer 404ing forever.
        assert!(reg.default_model().is_none());
        reg.load("m2", SellModel::Acdc(cascade(2, 8)), None).unwrap();
        assert_eq!(reg.resolve_default().unwrap().name(), "m2");
    }

    #[test]
    fn checkpoint_file_roundtrip_through_load_path() {
        let reg = registry();
        let c = cascade(7, 8);
        let dir = std::env::temp_dir().join(format!("acdc_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        SellModel::Acdc(c.clone())
            .to_checkpoint()
            .unwrap()
            .save(&path)
            .unwrap();
        let v = reg.load_path("m", &path, Some(3)).unwrap();
        assert_eq!(v, 3);
        let info = &reg.list()[0];
        assert_eq!((info.version, info.kind.as_str()), (3, "acdc"));
        assert!(reg.load_path("x", &dir.join("missing.ckpt"), None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_names() {
        let reg = registry();
        assert!(reg.load("", SellModel::Acdc(cascade(1, 8)), None).is_err());
        assert!(reg
            .load("has space", SellModel::Acdc(cascade(1, 8)), None)
            .is_err());
        assert!(reg
            .load("has/slash", SellModel::Acdc(cascade(1, 8)), None)
            .is_err());
    }

    #[test]
    fn per_model_metrics_registered() {
        let metrics = Arc::new(Registry::new());
        let reg = ModelRegistry::new(template(), Arc::clone(&metrics));
        reg.load("m", SellModel::Acdc(cascade(1, 8)), None).unwrap();
        let _h = reg.resolve("m").unwrap();
        assert_eq!(metrics.counter("model.m.requests").get(), 1);
        assert_eq!(metrics.gauge("model.m.version").get(), 1);
        assert_eq!(metrics.gauge("model.m.inflight").get(), 1);
        // The latency histogram handle is cached at install and recorded
        // through the handle (satellite: no per-request name formatting).
        _h.observe_request(Duration::from_micros(250));
        assert_eq!(metrics.histogram("model.m.request_ns").count(), 1);
        reg.load("m", SellModel::Acdc(cascade(2, 8)), None).unwrap();
        assert_eq!(metrics.counter("model.m.swaps").get(), 1);
        assert_eq!(metrics.gauge("model.m.version").get(), 2);
    }
}
