//! Consistent-hash ring for cluster-mode placement.
//!
//! Each shard address contributes `vnodes` points on a 64-bit hash
//! circle; a key is placed by hashing it onto the circle and walking
//! clockwise until `replicas` *distinct* shards have been collected.
//! The walk gives the classic consistent-hashing properties the property
//! suite pins (`tests/property_cluster_ring.rs`):
//!
//! * **uniformity** — with the default 128 vnodes per shard, 1k keys
//!   land within 15% of the ideal per-shard share;
//! * **minimal movement** — adding a shard only moves keys *onto* the
//!   new shard; removing one only moves the keys it owned;
//! * **distinct replicas** — a replica set never contains the same
//!   shard twice.
//!
//! Keys are model *names* (not name+version): a version promotion swaps
//! in place on the same replica set, which is what makes the rolling
//! swap's one-replica-at-a-time drain well-defined.
//!
//! The hash is FNV-1a/64 finalized with SplitMix64 — fully
//! deterministic across processes and platforms, so the router, the
//! tests, and any out-of-process tooling agree on placement without
//! coordination.

/// FNV-1a 64-bit over `data`.
fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 finalizer — full-avalanche mixing so nearby vnode labels
/// (`addr|0`, `addr|1`, …) spread across the whole circle.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Position of `key` on the hash circle.
fn ring_hash(key: &str) -> u64 {
    mix64(fnv1a(key.as_bytes()))
}

/// Default vnodes per shard. Validated by the property suite: at 128,
/// 1k-key placement stays within 15% of uniform for 3- and 5-shard
/// topologies.
pub const DEFAULT_VNODES: usize = 128;

/// An immutable consistent-hash ring over a static shard list.
///
/// Shards are identified by their index into the topology order (the
/// `[cluster] shards` array); the router's upstream table, the
/// `x-acdc-upstream` response header, and the per-shard metric names all
/// use the same index.
#[derive(Debug, Clone)]
pub struct Ring {
    shards: Vec<String>,
    /// Sorted circle points: (hash, shard index).
    points: Vec<(u64, u32)>,
}

impl Ring {
    /// Build a ring with `vnodes` points per shard (clamped to ≥ 1).
    /// Vnode labels are `"{addr}|{i}"`, so equal shard lists always
    /// produce identical rings.
    pub fn new(shards: &[String], vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(shards.len() * vnodes);
        for (si, addr) in shards.iter().enumerate() {
            for v in 0..vnodes {
                points.push((ring_hash(&format!("{addr}|{v}")), si as u32));
            }
        }
        points.sort_unstable();
        Ring {
            shards: shards.to_vec(),
            points,
        }
    }

    /// The topology's shard addresses, in index order.
    pub fn shards(&self) -> &[String] {
        &self.shards
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Whether the topology is empty.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// The replica set of `key`: up to `replicas` *distinct* shard
    /// indices in clockwise ring order starting at the key's position.
    /// The first entry is the primary. `replicas` is clamped to the
    /// shard count; an empty topology yields an empty set.
    pub fn place(&self, key: &str, replicas: usize) -> Vec<usize> {
        let want = replicas.clamp(1, self.shards.len().max(1));
        let mut out: Vec<usize> = Vec::with_capacity(want);
        if self.points.is_empty() {
            return out;
        }
        let kh = ring_hash(key);
        // First point strictly after the key's position (wrapping).
        let start = self.points.partition_point(|&(h, _)| h <= kh) % self.points.len();
        for step in 0..self.points.len() {
            let (_, si) = self.points[(start + step) % self.points.len()];
            let si = si as usize;
            if !out.contains(&si) {
                out.push(si);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    /// The primary shard index of `key` (first entry of its replica set).
    pub fn primary(&self, key: &str) -> usize {
        self.place(key, 1)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shards(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    #[test]
    fn placement_is_deterministic_across_rings() {
        let a = Ring::new(&shards(3), 64);
        let b = Ring::new(&shards(3), 64);
        for i in 0..200 {
            let key = format!("model-{i}");
            assert_eq!(a.place(&key, 2), b.place(&key, 2));
        }
    }

    #[test]
    fn replicas_are_distinct_and_clamped() {
        let ring = Ring::new(&shards(3), 32);
        for i in 0..200 {
            let set = ring.place(&format!("m{i}"), 5);
            assert_eq!(set.len(), 3, "clamped to shard count");
            let mut dedup = set.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), set.len(), "duplicate shard in {set:?}");
        }
    }

    #[test]
    fn primary_matches_first_replica() {
        let ring = Ring::new(&shards(4), 32);
        for i in 0..100 {
            let key = format!("model-{i}");
            assert_eq!(ring.primary(&key), ring.place(&key, 3)[0]);
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        let ring = Ring::new(&shards(1), 8);
        for i in 0..50 {
            assert_eq!(ring.place(&format!("k{i}"), 2), vec![0]);
        }
    }
}
