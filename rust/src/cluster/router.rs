//! The router role's core: upstream shard table, health-checked
//! membership, least-loaded replica fan-out, request hedging, and the
//! cluster-wide rolling swap.
//!
//! A [`RouterCore`] owns one upstream entry per `[cluster] shards`
//! address. Inference requests are placed on the consistent-hash ring
//! ([`super::ring::Ring`]) by model name, the replica set is filtered to
//! healthy, non-draining shards, and the least-loaded survivor gets the
//! request over a pooled keep-alive connection. Three reliability
//! mechanisms stack on top:
//!
//! * **retry** — a transport failure (connect refused, write error, EOF
//!   mid-response) marks the shard and moves to the next distinct
//!   replica. Inference is idempotent, so replaying the byte-identical
//!   body is safe; a SIGKILLed shard costs a retry, not a client error.
//! * **hedging** — if the chosen shard has not answered within a delay
//!   derived from its own latency percentile (`hedge_pct`, floored at
//!   `hedge_min_ms`), the same request is fired at the next replica and
//!   the first response wins.
//! * **hysteresis** — `down_after` consecutive failures (probe or
//!   request) mark a shard down; `up_after` consecutive `/healthz` probe
//!   successes mark it back up. A flapping shard cannot oscillate per
//!   request.
//!
//! The rolling swap ([`RouterCore::rolling_swap`]) upgrades a model
//! version across its replica set one shard at a time: mark the shard
//! draining (new placements skip it), poll the shard's per-model
//! in-flight count to zero, POST the shard-local hot-swap (the Arc-epoch
//! handoff in [`crate::registry`]), then re-admit. Traffic keeps flowing
//! to the other replicas throughout, so a promotion proceeds under live
//! load with zero failed requests.
//!
//! Everything here allocates freely — the router hop is a network proxy,
//! not the shard-local zero-allocation inference path.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::ring::Ring;
use crate::config::ClusterConfig;
use crate::gateway::http;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::trace::log::{self, Field, Level};
use crate::util::json::{obj, Json};

/// Raw `poll(2)` surface for hedged response waits (the router blocks on
/// one or two upstream sockets at once; constants are the Linux ABI).
mod sys {
    use std::os::raw::{c_int, c_ulong};

    pub const POLLIN: i16 = 0x1;
    pub const POLLERR: i16 = 0x8;
    pub const POLLHUP: i16 = 0x10;
    pub const POLLNVAL: i16 = 0x20;

    /// Mirrors `struct pollfd`.
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout_ms: c_int) -> c_int;
    }
}

/// Keep-alive connections retained per upstream.
const POOL_CAP: usize = 8;

/// Socket read timeout slice: `read_response_within` retries these until
/// its own deadline, so the slice only bounds shutdown latency.
const READ_SLICE: Duration = Duration::from_millis(50);

/// Poll cadence of the rolling swap's drain wait.
const DRAIN_POLL: Duration = Duration::from_millis(20);

/// One upstream shard: address, health/drain state, hysteresis counters,
/// the keep-alive connection pool, and the cached per-shard metric
/// handles (`cluster.shard{i}.*`).
struct Upstream {
    addr: String,
    healthy: AtomicBool,
    draining: AtomicBool,
    /// Requests currently outstanding against this shard (least-loaded
    /// fan-out key; includes hedges).
    inflight: AtomicU64,
    consec_fail: AtomicU64,
    consec_ok: AtomicU64,
    pool: Mutex<Vec<Live>>,
    healthy_gauge: Arc<Gauge>,
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    hedges: Arc<Counter>,
    request_ns: Arc<Histogram>,
}

/// A dialed upstream connection with its buffered reader half.
struct Live {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// What [`RouterCore::proxy`] hands back to the gateway for a successful
/// upstream exchange (any HTTP status — a shard's 4xx/5xx is passed
/// through verbatim, it is not a router failure).
pub struct ProxyReply {
    /// Upstream HTTP status, forwarded as-is.
    pub status: u16,
    /// Upstream `content-type` (JSON or the binary f32 frame).
    pub content_type: String,
    /// Upstream response body, forwarded byte-for-byte.
    pub body: Vec<u8>,
    /// Topology index of the shard that answered (echoed to the client
    /// as the `x-acdc-upstream` header).
    pub upstream: usize,
    /// Whether a hedge request was fired for this exchange.
    pub hedged: bool,
}

/// Shared router state: ring, upstream table, prober thread, counters.
pub struct RouterCore {
    cfg: ClusterConfig,
    ring: Ring,
    upstreams: Vec<Upstream>,
    proxy_requests: Arc<Counter>,
    proxy_errors: Arc<Counter>,
    proxy_retries: Arc<Counter>,
    proxy_hedges: Arc<Counter>,
    rolling_swaps: Arc<Counter>,
    stop: AtomicBool,
    prober: Mutex<Option<JoinHandle<()>>>,
}

impl RouterCore {
    /// Validate `cfg`, build the ring and upstream table (every shard
    /// starts healthy — optimistic admission until the first probe says
    /// otherwise), and spawn the background `/healthz` prober.
    pub fn start(cfg: ClusterConfig, metrics: &Arc<Registry>) -> Result<Arc<RouterCore>, String> {
        cfg.validate()?;
        let ring = Ring::new(&cfg.shards, cfg.vnodes);
        let upstreams = cfg
            .shards
            .iter()
            .enumerate()
            .map(|(i, addr)| {
                let healthy_gauge = metrics.gauge(&format!("cluster.shard{i}.healthy"));
                healthy_gauge.set(1);
                Upstream {
                    addr: addr.clone(),
                    healthy: AtomicBool::new(true),
                    draining: AtomicBool::new(false),
                    inflight: AtomicU64::new(0),
                    consec_fail: AtomicU64::new(0),
                    consec_ok: AtomicU64::new(0),
                    pool: Mutex::new(Vec::new()),
                    healthy_gauge,
                    requests: metrics.counter(&format!("cluster.shard{i}.requests")),
                    errors: metrics.counter(&format!("cluster.shard{i}.errors")),
                    hedges: metrics.counter(&format!("cluster.shard{i}.hedges")),
                    request_ns: metrics.histogram(&format!("cluster.shard{i}.request_ns")),
                }
            })
            .collect();
        let core = Arc::new(RouterCore {
            ring,
            upstreams,
            proxy_requests: metrics.counter("cluster.proxy_requests"),
            proxy_errors: metrics.counter("cluster.proxy_errors"),
            proxy_retries: metrics.counter("cluster.proxy_retries"),
            proxy_hedges: metrics.counter("cluster.proxy_hedges"),
            rolling_swaps: metrics.counter("cluster.rolling_swaps"),
            stop: AtomicBool::new(false),
            prober: Mutex::new(None),
            cfg,
        });
        let prober_core = Arc::clone(&core);
        let handle = std::thread::Builder::new()
            .name("acdc-cluster-probe".into())
            .spawn(move || prober_core.prober_loop())
            .map_err(|e| format!("spawn cluster prober: {e}"))?;
        *core.prober.lock().unwrap() = Some(handle);
        Ok(core)
    }

    /// The cluster topology knobs this router was built from.
    pub fn cfg(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Stop and join the prober thread (idempotent; called from the
    /// gateway's drain path).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.prober.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    // -- health ------------------------------------------------------------

    fn prober_loop(&self) {
        let interval = Duration::from_millis(self.cfg.probe_interval_ms);
        while !self.stop.load(Ordering::Acquire) {
            for (i, u) in self.upstreams.iter().enumerate() {
                if self.stop.load(Ordering::Acquire) {
                    return;
                }
                if self.probe(u) {
                    self.note_success(i);
                } else {
                    self.note_failure(i);
                }
            }
            // Sleep in short slices so shutdown is prompt.
            let deadline = Instant::now() + interval;
            while Instant::now() < deadline {
                if self.stop.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(25).min(interval));
            }
        }
    }

    /// One `/healthz` probe on a fresh connection (the pool is for
    /// request traffic; a probe must measure dial reachability too).
    fn probe(&self, u: &Upstream) -> bool {
        let Ok(mut live) = self.dial(&u.addr) else {
            return false;
        };
        if http::write_request(&mut live.stream, "GET", "/healthz", &[], &[]).is_err() {
            return false;
        }
        matches!(
            http::read_response_within(
                &mut live.reader,
                Duration::from_millis(self.cfg.connect_timeout_ms),
            ),
            Ok(resp) if resp.status == 200
        )
    }

    /// A successful probe or request exchange: reset the failure streak,
    /// and mark the shard back up after `up_after` consecutive successes.
    fn note_success(&self, i: usize) {
        let u = &self.upstreams[i];
        u.consec_fail.store(0, Ordering::Relaxed);
        let ok = u.consec_ok.fetch_add(1, Ordering::Relaxed) + 1;
        if !u.healthy.load(Ordering::Acquire) && ok >= self.cfg.up_after {
            u.healthy.store(true, Ordering::Release);
            u.healthy_gauge.set(1);
            log::event(
                Level::Info,
                "cluster",
                "shard_up",
                0,
                &[("shard", Field::U64(i as u64)), ("addr", Field::Str(&u.addr))],
            );
        }
    }

    /// A failed probe or transport-failed exchange: reset the success
    /// streak, and mark the shard down after `down_after` consecutive
    /// failures.
    fn note_failure(&self, i: usize) {
        let u = &self.upstreams[i];
        u.consec_ok.store(0, Ordering::Relaxed);
        u.errors.inc();
        let fails = u.consec_fail.fetch_add(1, Ordering::Relaxed) + 1;
        if u.healthy.load(Ordering::Acquire) && fails >= self.cfg.down_after {
            u.healthy.store(false, Ordering::Release);
            u.healthy_gauge.set(0);
            // Dead shard: drop its pooled connections so no request
            // wastes a retry on a stale socket after re-admission.
            u.pool.lock().unwrap().clear();
            log::event(
                Level::Warn,
                "cluster",
                "shard_down",
                0,
                &[("shard", Field::U64(i as u64)), ("addr", Field::Str(&u.addr))],
            );
        }
    }

    // -- connections -------------------------------------------------------

    fn dial(&self, addr: &str) -> Result<Live, String> {
        let sa = addr
            .to_socket_addrs()
            .map_err(|e| format!("resolve {addr}: {e}"))?
            .next()
            .ok_or_else(|| format!("resolve {addr}: no address"))?;
        let stream =
            TcpStream::connect_timeout(&sa, Duration::from_millis(self.cfg.connect_timeout_ms))
                .map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(READ_SLICE));
        let reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
        Ok(Live { stream, reader })
    }

    fn checkout(&self, i: usize) -> Option<Live> {
        self.upstreams[i].pool.lock().unwrap().pop()
    }

    fn checkin(&self, i: usize, live: Live) {
        let mut pool = self.upstreams[i].pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(live);
        }
    }

    /// Write one request on a pooled or fresh connection. A stale pooled
    /// socket (closed by the shard since checkout) costs one silent
    /// redial, not a shard failure mark.
    fn fire(&self, i: usize, path: &str, content_type: &str, body: &[u8]) -> Result<Live, String> {
        let headers = [("content-type", content_type)];
        if let Some(mut live) = self.checkout(i) {
            if http::write_request(&mut live.stream, "POST", path, &headers, body).is_ok() {
                return Ok(live);
            }
        }
        let mut live = self.dial(&self.upstreams[i].addr)?;
        http::write_request(&mut live.stream, "POST", path, &headers, body)
            .map_err(|e| format!("write {}: {e}", self.upstreams[i].addr))?;
        Ok(live)
    }

    // -- selection ---------------------------------------------------------

    /// The replica set of `key` ordered for attempts: healthy
    /// non-draining shards by ascending in-flight count, then (only if
    /// none exist — e.g. a single-replica model mid-swap) healthy
    /// draining shards. Down shards never appear.
    fn candidates(&self, key: &str) -> Vec<usize> {
        let replicas = self.ring.place(key, self.cfg.replication);
        let mut open: Vec<usize> = replicas
            .iter()
            .copied()
            .filter(|&i| {
                self.upstreams[i].healthy.load(Ordering::Acquire)
                    && !self.upstreams[i].draining.load(Ordering::Acquire)
            })
            .collect();
        open.sort_by_key(|&i| self.upstreams[i].inflight.load(Ordering::Acquire));
        if open.is_empty() {
            open = replicas
                .iter()
                .copied()
                .filter(|&i| self.upstreams[i].healthy.load(Ordering::Acquire))
                .collect();
            open.sort_by_key(|&i| self.upstreams[i].inflight.load(Ordering::Acquire));
        }
        open
    }

    /// Hedge trigger delay for shard `i`: its own `hedge_pct` latency
    /// percentile, floored at `hedge_min_ms` (the floor also covers a
    /// cold histogram).
    fn hedge_delay(&self, i: usize) -> Duration {
        let pct_ms = self.upstreams[i].request_ns.percentile_ns(self.cfg.hedge_pct) / 1_000_000;
        Duration::from_millis(pct_ms.max(self.cfg.hedge_min_ms))
    }

    // -- the proxy path ----------------------------------------------------

    /// Forward one inference request (`path` + `body` verbatim, placed by
    /// `key`) to the cluster; returns the winning shard's response or a
    /// router-level `(status, message)` failure. Retries distinct
    /// replicas on transport errors and hedges a slow shard against the
    /// next replica — any HTTP status from a shard (including 4xx/5xx)
    /// is a *successful* exchange and is passed through.
    pub fn proxy(
        &self,
        key: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<ProxyReply, (u16, String)> {
        self.proxy_requests.inc();
        let deadline = Instant::now() + Duration::from_millis(self.cfg.request_timeout_ms);
        let mut tried: Vec<usize> = Vec::new();
        let mut last_err = String::from("no healthy replica");
        let mut any_candidate = false;
        loop {
            let cands: Vec<usize> = self
                .candidates(key)
                .into_iter()
                .filter(|i| !tried.contains(i))
                .collect();
            let Some(&primary) = cands.first() else {
                break;
            };
            any_candidate = true;
            if !tried.is_empty() {
                self.proxy_retries.inc();
            }
            tried.push(primary);
            match self.exchange(primary, &cands[1..], &mut tried, path, content_type, body, deadline)
            {
                Ok(reply) => return Ok(reply),
                Err(e) => last_err = e,
            }
            if Instant::now() >= deadline {
                self.proxy_errors.inc();
                return Err((504, format!("upstream deadline exceeded: {last_err}")));
            }
        }
        self.proxy_errors.inc();
        if any_candidate {
            Err((502, format!("all replicas failed: {last_err}")))
        } else {
            Err((503, last_err))
        }
    }

    /// One hedged exchange: fire at `primary`, optionally fire at the
    /// first viable hedge from `hedge_pool` after the hedge delay, and
    /// return the first complete response. Shards that transport-fail
    /// here are marked and appended to `tried`.
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &self,
        primary: usize,
        hedge_pool: &[usize],
        tried: &mut Vec<usize>,
        path: &str,
        content_type: &str,
        body: &[u8],
        deadline: Instant,
    ) -> Result<ProxyReply, String> {
        let t0 = Instant::now();
        self.upstreams[primary].requests.inc();
        self.upstreams[primary].inflight.fetch_add(1, Ordering::AcqRel);
        let fired = self.fire(primary, path, content_type, body);
        let mut pending: Vec<(usize, Live)> = match fired {
            Ok(live) => vec![(primary, live)],
            Err(e) => {
                self.upstreams[primary].inflight.fetch_sub(1, Ordering::AcqRel);
                self.note_failure(primary);
                return Err(e);
            }
        };
        let mut hedged = false;
        let result = loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break Err("upstream timeout".to_string());
            }
            // Before the hedge fires, wait only up to the hedge delay.
            let hedge_at = if !hedged && !hedge_pool.is_empty() {
                Some(self.hedge_delay(primary))
            } else {
                None
            };
            let wait = match hedge_at {
                Some(d) => d.saturating_sub(t0.elapsed()).min(remaining),
                None => remaining,
            };
            let fds: Vec<i32> = pending.iter().map(|(_, l)| l.stream.as_raw_fd()).collect();
            match poll_readable(&fds, wait) {
                Some(idx) => {
                    let (ui, mut live) = pending.remove(idx);
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    match http::read_response_within(&mut live.reader, remaining) {
                        Ok(resp) => {
                            self.upstreams[ui].inflight.fetch_sub(1, Ordering::AcqRel);
                            self.note_success(ui);
                            self.upstreams[ui].request_ns.record(t0.elapsed());
                            if resp.keep_alive() {
                                self.checkin(ui, live);
                            }
                            break Ok((ui, resp));
                        }
                        Err(e) => {
                            self.upstreams[ui].inflight.fetch_sub(1, Ordering::AcqRel);
                            self.note_failure(ui);
                            if ui != primary {
                                tried.push(ui);
                            }
                            if pending.is_empty() {
                                break Err(format!("read {}: {e}", self.upstreams[ui].addr));
                            }
                        }
                    }
                }
                None => {
                    // Poll timed out: either the hedge window elapsed
                    // (fire the hedge and keep waiting on both) or the
                    // request deadline did (loop back and time out).
                    if hedge_at.is_some() && t0.elapsed() >= hedge_at.unwrap() {
                        hedged = true;
                        if let Some(&hi) = hedge_pool.iter().find(|i| !tried.contains(i)) {
                            self.upstreams[hi].requests.inc();
                            self.upstreams[hi].hedges.inc();
                            self.proxy_hedges.inc();
                            self.upstreams[hi].inflight.fetch_add(1, Ordering::AcqRel);
                            match self.fire(hi, path, content_type, body) {
                                Ok(live) => pending.push((hi, live)),
                                Err(_) => {
                                    self.upstreams[hi].inflight.fetch_sub(1, Ordering::AcqRel);
                                    self.note_failure(hi);
                                    tried.push(hi);
                                }
                            }
                        }
                    }
                }
            }
        };
        // Losers (a hedge that lost the race, or the primary after the
        // hedge won) carry an unread response: close them, never pool.
        for (ui, _live) in pending {
            self.upstreams[ui].inflight.fetch_sub(1, Ordering::AcqRel);
        }
        let (ui, resp) = result?;
        Ok(ProxyReply {
            status: resp.status,
            content_type: resp
                .header("content-type")
                .unwrap_or("application/json")
                .to_string(),
            body: resp.body,
            upstream: ui,
            hedged,
        })
    }

    // -- admin / rolling swap ----------------------------------------------

    /// One-shot admin exchange against a shard (fresh connection; the
    /// pool is reserved for the proxy hot path).
    fn admin_exchange(
        &self,
        addr: &str,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<(u16, Json), String> {
        let mut live = self.dial(addr)?;
        http::write_request(
            &mut live.stream,
            method,
            path,
            &[("content-type", "application/json")],
            body,
        )
        .map_err(|e| format!("write {addr}: {e}"))?;
        let resp = http::read_response_within(&mut live.reader, Duration::from_secs(10))
            .map_err(|e| format!("read {addr}: {e}"))?;
        let json = Json::parse(resp.body_str())
            .map_err(|e| format!("{addr} answered unparseable JSON: {e}"))?;
        Ok((resp.status, json))
    }

    /// Block until `name`'s in-flight count on the shard at `addr` is
    /// zero, or the drain deadline passes (a single-replica model under
    /// sustained traffic cannot drain; the shard-local Arc-epoch swap is
    /// safe regardless, so the swap proceeds either way).
    fn wait_drained(&self, addr: &str, name: &str) -> bool {
        let deadline = Instant::now() + Duration::from_millis(self.cfg.drain_timeout_ms);
        let path = format!("/v1/models/{name}");
        while Instant::now() < deadline {
            match self.admin_exchange(addr, "GET", &path, &[]) {
                Ok((200, v)) => {
                    if v.get("inflight").and_then(|x| x.as_i64()) == Some(0) {
                        return true;
                    }
                }
                // 404 (model not yet loaded on this shard) drains
                // trivially; transport errors retry until the deadline.
                Ok((404, _)) => return true,
                Ok(_) | Err(_) => {}
            }
            std::thread::sleep(DRAIN_POLL);
        }
        false
    }

    /// Cluster-wide rolling version swap of `name` from checkpoint
    /// `ckpt_path`: for each replica in ring (drain) order — mark the
    /// shard draining, wait its per-model in-flight count to zero, POST
    /// the shard-local hot swap, verify, re-admit. Returns the per-shard
    /// outcome list, or `(status, message)` on the first failed shard
    /// (already-swapped shards keep the new version; the failed shard is
    /// re-admitted on its old one).
    pub fn rolling_swap(
        &self,
        name: &str,
        ckpt_path: &str,
        version: Option<u64>,
    ) -> Result<Json, (u16, String)> {
        let replicas = self.ring.place(name, self.cfg.replication);
        let mut body_pairs = vec![("path", Json::Str(ckpt_path.to_string()))];
        if let Some(v) = version {
            body_pairs.push(("version", Json::Num(v as f64)));
        }
        let body = obj(body_pairs).to_string().into_bytes();
        let mut results: Vec<Json> = Vec::with_capacity(replicas.len());
        for &si in &replicas {
            let u = &self.upstreams[si];
            u.draining.store(true, Ordering::Release);
            let drained = self.wait_drained(&u.addr, name);
            let load = self.admin_exchange(
                &u.addr,
                "POST",
                &format!("/v1/admin/models/{name}/load"),
                &body,
            );
            u.draining.store(false, Ordering::Release);
            // Stale pooled sockets from before the swap are fine (the
            // shard never closed them), but drop them anyway so the next
            // requests observe the new version immediately rather than
            // after a pool cycle.
            u.pool.lock().unwrap().clear();
            match load {
                Ok((200, v)) => {
                    let loaded = v.get("version").and_then(|x| x.as_i64()).unwrap_or(-1);
                    log::event(
                        Level::Info,
                        "cluster",
                        "rolling_swap_shard",
                        0,
                        &[
                            ("model", Field::Str(name)),
                            ("shard", Field::U64(si as u64)),
                            ("version", Field::U64(loaded.max(0) as u64)),
                            ("drained", Field::Bool(drained)),
                        ],
                    );
                    results.push(obj(vec![
                        ("shard", Json::Num(si as f64)),
                        ("addr", Json::Str(u.addr.clone())),
                        ("version", Json::Num(loaded as f64)),
                        ("drained", Json::Bool(drained)),
                    ]));
                }
                Ok((status, v)) => {
                    let msg = v
                        .get("error")
                        .and_then(|e| e.as_str())
                        .unwrap_or("(no error body)")
                        .to_string();
                    return Err((502, format!("shard {si} ({}) answered {status}: {msg}", u.addr)));
                }
                Err(e) => return Err((502, format!("shard {si}: {e}"))),
            }
        }
        self.rolling_swaps.inc();
        Ok(obj(vec![
            ("model", Json::Str(name.to_string())),
            ("status", Json::Str("swapped".to_string())),
            ("replicas", Json::Arr(results)),
        ]))
    }

    /// Topology + live health snapshot for `GET /v1/cluster`.
    pub fn topology_json(&self) -> Json {
        let shards: Vec<Json> = self
            .upstreams
            .iter()
            .enumerate()
            .map(|(i, u)| {
                obj(vec![
                    ("index", Json::Num(i as f64)),
                    ("addr", Json::Str(u.addr.clone())),
                    ("healthy", Json::Bool(u.healthy.load(Ordering::Acquire))),
                    ("draining", Json::Bool(u.draining.load(Ordering::Acquire))),
                    (
                        "inflight",
                        Json::Num(u.inflight.load(Ordering::Acquire) as f64),
                    ),
                    ("requests", Json::Num(u.requests.get() as f64)),
                    ("errors", Json::Num(u.errors.get() as f64)),
                    ("hedges", Json::Num(u.hedges.get() as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("replication", Json::Num(self.cfg.replication as f64)),
            ("vnodes", Json::Num(self.cfg.vnodes as f64)),
            ("shards", Json::Arr(shards)),
        ])
    }
}

impl Drop for RouterCore {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Wait until one of `fds` is readable (or error/hangup-ready, which a
/// subsequent read surfaces as the actual error). Returns the index of
/// the first ready fd, or `None` on timeout. `EINTR` retries within the
/// budget.
fn poll_readable(fds: &[i32], timeout: Duration) -> Option<usize> {
    let deadline = Instant::now() + timeout;
    loop {
        let mut pfds: Vec<sys::PollFd> = fds
            .iter()
            .map(|&fd| sys::PollFd {
                fd,
                events: sys::POLLIN,
                revents: 0,
            })
            .collect();
        let remaining = deadline.saturating_duration_since(Instant::now());
        let timeout_ms = remaining.as_millis().min(i32::MAX as u128) as i32;
        let rc = unsafe { sys::poll(pfds.as_mut_ptr(), pfds.len() as _, timeout_ms) };
        if rc > 0 {
            for (i, p) in pfds.iter().enumerate() {
                if p.revents & (sys::POLLIN | sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0 {
                    return Some(i);
                }
            }
        }
        if rc == 0 || Instant::now() >= deadline {
            return None;
        }
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                // Treat a hard poll failure as "first fd ready": the
                // caller's read will produce the real error.
                return Some(0);
            }
        }
    }
}
