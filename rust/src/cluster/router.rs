//! The router role's core: upstream shard table, health-checked
//! membership, least-loaded replica fan-out, request hedging, and the
//! cluster-wide rolling swap.
//!
//! A [`RouterCore`] owns one upstream entry per `[cluster] shards`
//! address. Inference requests are placed on the consistent-hash ring
//! ([`super::ring::Ring`]) by model name, the replica set is filtered to
//! healthy, non-draining shards, and the least-loaded survivor gets the
//! request over a pooled keep-alive connection. Three reliability
//! mechanisms stack on top:
//!
//! * **retry** — a transport failure (connect refused, write error, EOF
//!   mid-response) marks the shard and moves to the next distinct
//!   replica. Inference is idempotent, so replaying the byte-identical
//!   body is safe; a SIGKILLed shard costs a retry, not a client error.
//! * **hedging** — if the chosen shard has not answered within a delay
//!   derived from its own latency percentile (`hedge_pct`, floored at
//!   `hedge_min_ms`), the same request is fired at the next replica and
//!   the first response wins.
//! * **hysteresis** — `down_after` consecutive failures (probe or
//!   request) mark a shard down; `up_after` consecutive `/healthz` probe
//!   successes mark it back up. A flapping shard cannot oscillate per
//!   request.
//! * **circuit breaking** — independently of probe health, each upstream
//!   carries a breaker fed only by *request-path* outcomes: when
//!   `breaker_trip_ratio` of the last `breaker_window` exchanges failed,
//!   the breaker opens and placement skips the shard. After
//!   `breaker_cooldown_ms` one request is admitted as a half-open probe;
//!   its success closes the breaker, its failure re-opens it. This
//!   catches a shard that answers `/healthz` but fails or stalls real
//!   work (the probe path never feeds the breaker, and vice versa).
//! * **deadline budget** — the gateway hands `proxy` the request's
//!   remaining deadline budget; every hop forwards the live remainder as
//!   the `x-acdc-deadline-ms` header, and a retry or hedge is refused
//!   when the remainder is below the target shard's live p50 latency —
//!   no attempt is started that the client will not wait for.
//!
//! The rolling swap ([`RouterCore::rolling_swap`]) upgrades a model
//! version across its replica set one shard at a time: mark the shard
//! draining (new placements skip it), poll the shard's per-model
//! in-flight count to zero, POST the shard-local hot-swap (the Arc-epoch
//! handoff in [`crate::registry`]), then re-admit. Traffic keeps flowing
//! to the other replicas throughout, so a promotion proceeds under live
//! load with zero failed requests.
//!
//! Everything here allocates freely — the router hop is a network proxy,
//! not the shard-local zero-allocation inference path.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::ring::Ring;
use crate::config::ClusterConfig;
use crate::gateway::http;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::trace::log::{self, Field, Level};
use crate::util::json::{obj, Json};

/// Raw `poll(2)` surface for hedged response waits (the router blocks on
/// one or two upstream sockets at once; constants are the Linux ABI).
mod sys {
    use std::os::raw::{c_int, c_ulong};

    pub const POLLIN: i16 = 0x1;
    pub const POLLERR: i16 = 0x8;
    pub const POLLHUP: i16 = 0x10;
    pub const POLLNVAL: i16 = 0x20;

    /// Mirrors `struct pollfd`.
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout_ms: c_int) -> c_int;
    }
}

/// Keep-alive connections retained per upstream.
const POOL_CAP: usize = 8;

/// Socket read timeout slice: `read_response_within` retries these until
/// its own deadline, so the slice only bounds shutdown latency.
const READ_SLICE: Duration = Duration::from_millis(50);

/// Poll cadence of the rolling swap's drain wait.
const DRAIN_POLL: Duration = Duration::from_millis(20);

/// One upstream shard: address, health/drain state, hysteresis counters,
/// the request-path circuit breaker, the keep-alive connection pool, and
/// the cached per-shard metric handles (`cluster.shard{i}.*`).
struct Upstream {
    addr: String,
    healthy: AtomicBool,
    draining: AtomicBool,
    /// Requests currently outstanding against this shard (least-loaded
    /// fan-out key; includes hedges).
    inflight: AtomicU64,
    consec_fail: AtomicU64,
    consec_ok: AtomicU64,
    pool: Mutex<Vec<Live>>,
    /// Circuit breaker over request-path outcomes only (probes never
    /// feed it).
    breaker: Mutex<Breaker>,
    healthy_gauge: Arc<Gauge>,
    /// 1 while the breaker is open or half-open, 0 when closed.
    breaker_gauge: Arc<Gauge>,
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    hedges: Arc<Counter>,
    request_ns: Arc<Histogram>,
}

/// Circuit-breaker position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    /// Normal service; outcomes fill the rolling window.
    Closed,
    /// Tripped; the shard is skipped until the cooldown elapses.
    Open,
    /// Cooldown elapsed; one request probes the shard.
    HalfOpen,
}

/// Per-upstream circuit breaker (guarded by the upstream's mutex). The
/// rolling window is a bitmask — `breaker_window` is capped at 64 by
/// config validation — so recording an outcome is a shift and a popcount.
struct Breaker {
    /// Newest outcome in bit 0; a set bit is a failure.
    window: u64,
    /// Valid bits in `window` (a breaker only trips on a full window, so
    /// a fresh or just-closed breaker needs `breaker_window` outcomes).
    len: u32,
    state: BreakerState,
    opened_at: Instant,
    /// A half-open probe request is in flight; admits block until its
    /// outcome is recorded.
    probing: bool,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker {
            window: 0,
            len: 0,
            state: BreakerState::Closed,
            opened_at: Instant::now(),
            probing: false,
        }
    }

    /// Record one request-path outcome; returns the new state if this
    /// outcome moved the breaker.
    fn record(
        &mut self,
        ok: bool,
        window: u32,
        trip_ratio: f64,
        now: Instant,
    ) -> Option<BreakerState> {
        match self.state {
            BreakerState::HalfOpen => {
                self.probing = false;
                if ok {
                    self.state = BreakerState::Closed;
                    self.window = 0;
                    self.len = 0;
                    Some(BreakerState::Closed)
                } else {
                    self.state = BreakerState::Open;
                    self.opened_at = now;
                    Some(BreakerState::Open)
                }
            }
            // A straggler outcome from an exchange fired before the trip
            // carries no new information about the open shard.
            BreakerState::Open => None,
            BreakerState::Closed => {
                let mask = if window >= 64 {
                    u64::MAX
                } else {
                    (1u64 << window) - 1
                };
                self.window = ((self.window << 1) | u64::from(!ok)) & mask;
                self.len = (self.len + 1).min(window);
                let fails = self.window.count_ones();
                if self.len >= window && f64::from(fails) >= trip_ratio * f64::from(window) {
                    self.state = BreakerState::Open;
                    self.opened_at = now;
                    self.window = 0;
                    self.len = 0;
                    Some(BreakerState::Open)
                } else {
                    None
                }
            }
        }
    }

    /// Whether a request may be sent to this upstream right now. An open
    /// breaker past its cooldown flips to half-open and admits the
    /// caller as the probe candidate.
    fn admit(&mut self, cooldown: Duration, now: Instant) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if now.saturating_duration_since(self.opened_at) >= cooldown {
                    self.state = BreakerState::HalfOpen;
                    self.probing = false;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => !self.probing,
        }
    }

    /// Mark the half-open probe as actually fired — further admits block
    /// until [`Breaker::record`] lands its outcome. (If an admitted
    /// candidate is never fired at, the next request simply probes
    /// instead; nothing can wedge.)
    fn on_fire(&mut self) {
        if self.state == BreakerState::HalfOpen {
            self.probing = true;
        }
    }

    /// State name for the topology snapshot.
    fn state_name(&self) -> &'static str {
        match self.state {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// A dialed upstream connection with its buffered reader half.
struct Live {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// What [`RouterCore::proxy`] hands back to the gateway for a successful
/// upstream exchange (any HTTP status — a shard's 4xx/5xx is passed
/// through verbatim, it is not a router failure).
pub struct ProxyReply {
    /// Upstream HTTP status, forwarded as-is.
    pub status: u16,
    /// Upstream `content-type` (JSON or the binary f32 frame).
    pub content_type: String,
    /// Upstream response body, forwarded byte-for-byte.
    pub body: Vec<u8>,
    /// Topology index of the shard that answered (echoed to the client
    /// as the `x-acdc-upstream` header).
    pub upstream: usize,
    /// Whether a hedge request was fired for this exchange.
    pub hedged: bool,
}

/// Shared router state: ring, upstream table, prober thread, counters.
pub struct RouterCore {
    cfg: ClusterConfig,
    ring: Ring,
    upstreams: Vec<Upstream>,
    proxy_requests: Arc<Counter>,
    proxy_errors: Arc<Counter>,
    proxy_retries: Arc<Counter>,
    proxy_hedges: Arc<Counter>,
    rolling_swaps: Arc<Counter>,
    breaker_trips: Arc<Counter>,
    /// Hedging master switch — the brownout ladder's level-1 action.
    hedging: AtomicBool,
    stop: AtomicBool,
    prober: Mutex<Option<JoinHandle<()>>>,
}

impl RouterCore {
    /// Validate `cfg`, build the ring and upstream table (every shard
    /// starts healthy — optimistic admission until the first probe says
    /// otherwise), and spawn the background `/healthz` prober.
    pub fn start(cfg: ClusterConfig, metrics: &Arc<Registry>) -> Result<Arc<RouterCore>, String> {
        cfg.validate()?;
        let ring = Ring::new(&cfg.shards, cfg.vnodes);
        let upstreams = cfg
            .shards
            .iter()
            .enumerate()
            .map(|(i, addr)| {
                let healthy_gauge = metrics.gauge(&format!("cluster.shard{i}.healthy"));
                healthy_gauge.set(1);
                Upstream {
                    addr: addr.clone(),
                    healthy: AtomicBool::new(true),
                    draining: AtomicBool::new(false),
                    inflight: AtomicU64::new(0),
                    consec_fail: AtomicU64::new(0),
                    consec_ok: AtomicU64::new(0),
                    pool: Mutex::new(Vec::new()),
                    breaker: Mutex::new(Breaker::new()),
                    healthy_gauge,
                    breaker_gauge: metrics.gauge(&format!("cluster.shard{i}.breaker_open")),
                    requests: metrics.counter(&format!("cluster.shard{i}.requests")),
                    errors: metrics.counter(&format!("cluster.shard{i}.errors")),
                    hedges: metrics.counter(&format!("cluster.shard{i}.hedges")),
                    request_ns: metrics.histogram(&format!("cluster.shard{i}.request_ns")),
                }
            })
            .collect();
        let core = Arc::new(RouterCore {
            ring,
            upstreams,
            proxy_requests: metrics.counter("cluster.proxy_requests"),
            proxy_errors: metrics.counter("cluster.proxy_errors"),
            proxy_retries: metrics.counter("cluster.proxy_retries"),
            proxy_hedges: metrics.counter("cluster.proxy_hedges"),
            rolling_swaps: metrics.counter("cluster.rolling_swaps"),
            breaker_trips: metrics.counter("cluster.breaker_trips"),
            hedging: AtomicBool::new(true),
            stop: AtomicBool::new(false),
            prober: Mutex::new(None),
            cfg,
        });
        let prober_core = Arc::clone(&core);
        let handle = std::thread::Builder::new()
            .name("acdc-cluster-probe".into())
            .spawn(move || prober_core.prober_loop())
            .map_err(|e| format!("spawn cluster prober: {e}"))?;
        *core.prober.lock().unwrap() = Some(handle);
        Ok(core)
    }

    /// The cluster topology knobs this router was built from.
    pub fn cfg(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Enable or disable request hedging (the brownout ladder's level-1
    /// action — duplicate upstream work is the first cost to shed).
    /// Retries are unaffected.
    pub fn set_hedging(&self, enabled: bool) {
        self.hedging.store(enabled, Ordering::Release);
    }

    /// Stop and join the prober thread (idempotent; called from the
    /// gateway's drain path).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.prober.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    // -- health ------------------------------------------------------------

    fn prober_loop(&self) {
        let interval = Duration::from_millis(self.cfg.probe_interval_ms);
        while !self.stop.load(Ordering::Acquire) {
            for (i, u) in self.upstreams.iter().enumerate() {
                if self.stop.load(Ordering::Acquire) {
                    return;
                }
                if self.probe(u) {
                    self.note_success(i);
                } else {
                    self.note_failure(i);
                }
            }
            // Sleep in short slices so shutdown is prompt.
            let deadline = Instant::now() + interval;
            while Instant::now() < deadline {
                if self.stop.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(25).min(interval));
            }
        }
    }

    /// One `/healthz` probe on a fresh connection (the pool is for
    /// request traffic; a probe must measure dial reachability too).
    fn probe(&self, u: &Upstream) -> bool {
        let Ok(mut live) = self.dial(&u.addr) else {
            return false;
        };
        if http::write_request(&mut live.stream, "GET", "/healthz", &[], &[]).is_err() {
            return false;
        }
        matches!(
            http::read_response_within(
                &mut live.reader,
                Duration::from_millis(self.cfg.connect_timeout_ms),
            ),
            Ok(resp) if resp.status == 200
        )
    }

    /// A successful probe or request exchange: reset the failure streak,
    /// and mark the shard back up after `up_after` consecutive successes.
    fn note_success(&self, i: usize) {
        let u = &self.upstreams[i];
        u.consec_fail.store(0, Ordering::Relaxed);
        let ok = u.consec_ok.fetch_add(1, Ordering::Relaxed) + 1;
        if !u.healthy.load(Ordering::Acquire) && ok >= self.cfg.up_after {
            u.healthy.store(true, Ordering::Release);
            u.healthy_gauge.set(1);
            log::event(
                Level::Info,
                "cluster",
                "shard_up",
                0,
                &[("shard", Field::U64(i as u64)), ("addr", Field::Str(&u.addr))],
            );
        }
    }

    /// A failed probe or transport-failed exchange: reset the success
    /// streak, and mark the shard down after `down_after` consecutive
    /// failures.
    fn note_failure(&self, i: usize) {
        let u = &self.upstreams[i];
        u.consec_ok.store(0, Ordering::Relaxed);
        u.errors.inc();
        let fails = u.consec_fail.fetch_add(1, Ordering::Relaxed) + 1;
        if u.healthy.load(Ordering::Acquire) && fails >= self.cfg.down_after {
            u.healthy.store(false, Ordering::Release);
            u.healthy_gauge.set(0);
            // Dead shard: drop its pooled connections so no request
            // wastes a retry on a stale socket after re-admission.
            u.pool.lock().unwrap().clear();
            log::event(
                Level::Warn,
                "cluster",
                "shard_down",
                0,
                &[("shard", Field::U64(i as u64)), ("addr", Field::Str(&u.addr))],
            );
        }
    }

    /// Feed one request-path outcome into shard `i`'s circuit breaker
    /// (never called from the prober — a shard that answers `/healthz`
    /// but fails real work must still trip). Transitions are logged and
    /// mirrored into `cluster.shard{i}.breaker_open`.
    fn breaker_record(&self, i: usize, ok: bool) {
        let u = &self.upstreams[i];
        let changed = u.breaker.lock().unwrap().record(
            ok,
            self.cfg.breaker_window as u32,
            self.cfg.breaker_trip_ratio,
            Instant::now(),
        );
        match changed {
            Some(BreakerState::Open) => {
                self.breaker_trips.inc();
                u.breaker_gauge.set(1);
                log::event(
                    Level::Warn,
                    "cluster",
                    "breaker_open",
                    0,
                    &[("shard", Field::U64(i as u64)), ("addr", Field::Str(&u.addr))],
                );
            }
            Some(BreakerState::Closed) => {
                u.breaker_gauge.set(0);
                log::event(
                    Level::Info,
                    "cluster",
                    "breaker_closed",
                    0,
                    &[("shard", Field::U64(i as u64)), ("addr", Field::Str(&u.addr))],
                );
            }
            _ => {}
        }
    }

    /// Whether shard `i`'s breaker admits a request right now (an open
    /// breaker past its cooldown flips half-open and admits the probe).
    fn breaker_admit(&self, i: usize) -> bool {
        self.upstreams[i].breaker.lock().unwrap().admit(
            Duration::from_millis(self.cfg.breaker_cooldown_ms),
            Instant::now(),
        )
    }

    /// Whether the remaining budget plausibly covers one more attempt at
    /// shard `i`: its live p50 must fit inside the remainder. A cold
    /// histogram reads 0 and always fits, so a fresh cluster is never
    /// gated on data it does not have.
    fn budget_covers_p50(&self, i: usize, deadline: Instant) -> bool {
        let p50 = Duration::from_nanos(self.upstreams[i].request_ns.percentile_ns(50.0));
        deadline.saturating_duration_since(Instant::now()) >= p50
    }

    // -- connections -------------------------------------------------------

    fn dial(&self, addr: &str) -> Result<Live, String> {
        let sa = addr
            .to_socket_addrs()
            .map_err(|e| format!("resolve {addr}: {e}"))?
            .next()
            .ok_or_else(|| format!("resolve {addr}: no address"))?;
        let stream =
            TcpStream::connect_timeout(&sa, Duration::from_millis(self.cfg.connect_timeout_ms))
                .map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(READ_SLICE));
        let reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
        Ok(Live { stream, reader })
    }

    fn checkout(&self, i: usize) -> Option<Live> {
        self.upstreams[i].pool.lock().unwrap().pop()
    }

    fn checkin(&self, i: usize, live: Live) {
        let mut pool = self.upstreams[i].pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(live);
        }
    }

    /// Write one request on a pooled or fresh connection, forwarding the
    /// live remaining deadline budget as `x-acdc-deadline-ms` so the
    /// shard's own pipeline can reap work this hop has already given up
    /// on. A stale pooled socket (closed by the shard since checkout)
    /// costs one silent redial, not a shard failure mark.
    fn fire(
        &self,
        i: usize,
        path: &str,
        content_type: &str,
        body: &[u8],
        remaining_ms: u64,
    ) -> Result<Live, String> {
        let ms = remaining_ms.to_string();
        let headers = [
            ("content-type", content_type),
            ("x-acdc-deadline-ms", ms.as_str()),
        ];
        if let Some(mut live) = self.checkout(i) {
            if http::write_request(&mut live.stream, "POST", path, &headers, body).is_ok() {
                self.upstreams[i].breaker.lock().unwrap().on_fire();
                return Ok(live);
            }
        }
        let mut live = self.dial(&self.upstreams[i].addr)?;
        http::write_request(&mut live.stream, "POST", path, &headers, body)
            .map_err(|e| format!("write {}: {e}", self.upstreams[i].addr))?;
        self.upstreams[i].breaker.lock().unwrap().on_fire();
        Ok(live)
    }

    // -- selection ---------------------------------------------------------

    /// The replica set of `key` ordered for attempts: healthy
    /// non-draining shards whose breakers admit, by ascending in-flight
    /// count. If every breaker is open the breaker filter is dropped
    /// (a fully-tripped replica set degrades to pre-breaker behavior
    /// instead of refusing all traffic); if even that is empty, healthy
    /// draining shards (e.g. a single-replica model mid-swap). Down
    /// shards never appear.
    fn candidates(&self, key: &str) -> Vec<usize> {
        let replicas = self.ring.place(key, self.cfg.replication);
        let alive = |i: &usize| {
            self.upstreams[*i].healthy.load(Ordering::Acquire)
                && !self.upstreams[*i].draining.load(Ordering::Acquire)
        };
        let mut open: Vec<usize> = replicas
            .iter()
            .copied()
            .filter(alive)
            .filter(|&i| self.breaker_admit(i))
            .collect();
        if open.is_empty() {
            open = replicas.iter().copied().filter(alive).collect();
        }
        open.sort_by_key(|&i| self.upstreams[i].inflight.load(Ordering::Acquire));
        if open.is_empty() {
            open = replicas
                .iter()
                .copied()
                .filter(|&i| self.upstreams[i].healthy.load(Ordering::Acquire))
                .collect();
            open.sort_by_key(|&i| self.upstreams[i].inflight.load(Ordering::Acquire));
        }
        open
    }

    /// Hedge trigger delay for shard `i`: its own `hedge_pct` latency
    /// percentile, floored at `hedge_min_ms` (the floor also covers a
    /// cold histogram).
    fn hedge_delay(&self, i: usize) -> Duration {
        let pct_ms = self.upstreams[i].request_ns.percentile_ns(self.cfg.hedge_pct) / 1_000_000;
        Duration::from_millis(pct_ms.max(self.cfg.hedge_min_ms))
    }

    // -- the proxy path ----------------------------------------------------

    /// Forward one inference request (`path` + `body` verbatim, placed by
    /// `key`) to the cluster; returns the winning shard's response or a
    /// router-level `(status, message)` failure. Retries distinct
    /// replicas on transport errors and hedges a slow shard against the
    /// next replica — any HTTP status from a shard (including 4xx/5xx)
    /// is a *successful* exchange and is passed through.
    ///
    /// `budget` is the request's remaining deadline budget at this hop
    /// (the gateway's clamped `x-acdc-deadline-ms` mint); the effective
    /// deadline is the tighter of it and `request_timeout_ms`, decremented
    /// by elapsed time at every decision point. A retry is refused when
    /// the remainder no longer covers the target shard's live p50.
    pub fn proxy(
        &self,
        key: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
        budget: Duration,
    ) -> Result<ProxyReply, (u16, String)> {
        self.proxy_requests.inc();
        let total = Duration::from_millis(self.cfg.request_timeout_ms).min(budget);
        if total.is_zero() {
            self.proxy_errors.inc();
            return Err((504, "deadline exceeded before forwarding".to_string()));
        }
        let deadline = Instant::now() + total;
        let mut tried: Vec<usize> = Vec::new();
        let mut last_err = String::from("no healthy replica");
        let mut any_candidate = false;
        let mut budget_refused = false;
        loop {
            let untried: Vec<usize> = self
                .candidates(key)
                .into_iter()
                .filter(|i| !tried.contains(i))
                .collect();
            // The first attempt always goes out; a *retry* is refused
            // against a shard whose p50 exceeds the remaining budget.
            let first = tried.is_empty();
            let cands: Vec<usize> = untried
                .iter()
                .copied()
                .filter(|&i| first || self.budget_covers_p50(i, deadline))
                .collect();
            let Some(&primary) = cands.first() else {
                budget_refused = !untried.is_empty();
                break;
            };
            any_candidate = true;
            if !first {
                self.proxy_retries.inc();
            }
            tried.push(primary);
            let res = self.exchange(
                primary,
                &cands[1..],
                &mut tried,
                path,
                content_type,
                body,
                deadline,
            );
            match res {
                Ok(reply) => return Ok(reply),
                Err(e) => last_err = e,
            }
            if Instant::now() >= deadline {
                self.proxy_errors.inc();
                return Err((504, format!("upstream deadline exceeded: {last_err}")));
            }
        }
        self.proxy_errors.inc();
        if budget_refused {
            // Replicas remain, but none the remaining budget can cover.
            Err((504, format!("deadline budget too low to retry: {last_err}")))
        } else if any_candidate {
            Err((502, format!("all replicas failed: {last_err}")))
        } else {
            Err((503, last_err))
        }
    }

    /// One hedged exchange: fire at `primary`, optionally fire at the
    /// first viable hedge from `hedge_pool` after the hedge delay, and
    /// return the first complete response. Shards that transport-fail
    /// here are marked and appended to `tried`.
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &self,
        primary: usize,
        hedge_pool: &[usize],
        tried: &mut Vec<usize>,
        path: &str,
        content_type: &str,
        body: &[u8],
        deadline: Instant,
    ) -> Result<ProxyReply, String> {
        let t0 = Instant::now();
        self.upstreams[primary].requests.inc();
        self.upstreams[primary].inflight.fetch_add(1, Ordering::AcqRel);
        let fired = self.fire(primary, path, content_type, body, remaining_ms(deadline));
        let mut pending: Vec<(usize, Live)> = match fired {
            Ok(live) => vec![(primary, live)],
            Err(e) => {
                self.upstreams[primary].inflight.fetch_sub(1, Ordering::AcqRel);
                self.note_failure(primary);
                self.breaker_record(primary, false);
                return Err(e);
            }
        };
        let mut hedged = false;
        let result = loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                // Deadline with responses still outstanding: every
                // pending shard timed out this exchange — a request-path
                // failure for the health hysteresis and the breaker both.
                for (ui, _) in &pending {
                    self.note_failure(*ui);
                    self.breaker_record(*ui, false);
                }
                break Err("upstream timeout".to_string());
            }
            // Before the hedge fires, wait only up to the hedge delay
            // (hedging can be switched off wholesale by the brownout
            // ladder's first rung).
            let hedge_at = if !hedged
                && !hedge_pool.is_empty()
                && self.hedging.load(Ordering::Acquire)
            {
                Some(self.hedge_delay(primary))
            } else {
                None
            };
            let wait = match hedge_at {
                Some(d) => d.saturating_sub(t0.elapsed()).min(remaining),
                None => remaining,
            };
            let fds: Vec<i32> = pending.iter().map(|(_, l)| l.stream.as_raw_fd()).collect();
            match poll_readable(&fds, wait) {
                Some(idx) => {
                    let (ui, mut live) = pending.remove(idx);
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    match http::read_response_within(&mut live.reader, remaining) {
                        Ok(resp) => {
                            self.upstreams[ui].inflight.fetch_sub(1, Ordering::AcqRel);
                            self.note_success(ui);
                            self.breaker_record(ui, true);
                            self.upstreams[ui].request_ns.record(t0.elapsed());
                            if resp.keep_alive() {
                                self.checkin(ui, live);
                            }
                            break Ok((ui, resp));
                        }
                        Err(e) => {
                            self.upstreams[ui].inflight.fetch_sub(1, Ordering::AcqRel);
                            self.note_failure(ui);
                            self.breaker_record(ui, false);
                            if ui != primary {
                                tried.push(ui);
                            }
                            if pending.is_empty() {
                                break Err(format!("read {}: {e}", self.upstreams[ui].addr));
                            }
                        }
                    }
                }
                None => {
                    // Poll timed out: either the hedge window elapsed
                    // (fire the hedge and keep waiting on both) or the
                    // request deadline did (loop back and time out).
                    if hedge_at.is_some() && t0.elapsed() >= hedge_at.unwrap() {
                        hedged = true;
                        // A hedge is refused against a shard whose live
                        // p50 exceeds the remaining budget — the extra
                        // attempt could not answer in time anyway.
                        if let Some(&hi) = hedge_pool
                            .iter()
                            .find(|i| !tried.contains(i) && self.budget_covers_p50(**i, deadline))
                        {
                            self.upstreams[hi].requests.inc();
                            self.upstreams[hi].hedges.inc();
                            self.proxy_hedges.inc();
                            self.upstreams[hi].inflight.fetch_add(1, Ordering::AcqRel);
                            match self.fire(hi, path, content_type, body, remaining_ms(deadline)) {
                                Ok(live) => pending.push((hi, live)),
                                Err(_) => {
                                    self.upstreams[hi].inflight.fetch_sub(1, Ordering::AcqRel);
                                    self.note_failure(hi);
                                    self.breaker_record(hi, false);
                                    tried.push(hi);
                                }
                            }
                        }
                    }
                }
            }
        };
        // Losers (a hedge that lost the race, or the primary after the
        // hedge won) carry an unread response: close them, never pool.
        for (ui, _live) in pending {
            self.upstreams[ui].inflight.fetch_sub(1, Ordering::AcqRel);
        }
        let (ui, resp) = result?;
        Ok(ProxyReply {
            status: resp.status,
            content_type: resp
                .header("content-type")
                .unwrap_or("application/json")
                .to_string(),
            body: resp.body,
            upstream: ui,
            hedged,
        })
    }

    // -- admin / rolling swap ----------------------------------------------

    /// One-shot admin exchange against a shard (fresh connection; the
    /// pool is reserved for the proxy hot path).
    fn admin_exchange(
        &self,
        addr: &str,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<(u16, Json), String> {
        let mut live = self.dial(addr)?;
        http::write_request(
            &mut live.stream,
            method,
            path,
            &[("content-type", "application/json")],
            body,
        )
        .map_err(|e| format!("write {addr}: {e}"))?;
        let resp = http::read_response_within(&mut live.reader, Duration::from_secs(10))
            .map_err(|e| format!("read {addr}: {e}"))?;
        let json = Json::parse(resp.body_str())
            .map_err(|e| format!("{addr} answered unparseable JSON: {e}"))?;
        Ok((resp.status, json))
    }

    /// Block until `name`'s in-flight count on the shard at `addr` is
    /// zero, or the drain deadline passes (a single-replica model under
    /// sustained traffic cannot drain; the shard-local Arc-epoch swap is
    /// safe regardless, so the swap proceeds either way).
    fn wait_drained(&self, addr: &str, name: &str) -> bool {
        let deadline = Instant::now() + Duration::from_millis(self.cfg.drain_timeout_ms);
        let path = format!("/v1/models/{name}");
        while Instant::now() < deadline {
            match self.admin_exchange(addr, "GET", &path, &[]) {
                Ok((200, v)) => {
                    if v.get("inflight").and_then(|x| x.as_i64()) == Some(0) {
                        return true;
                    }
                }
                // 404 (model not yet loaded on this shard) drains
                // trivially; transport errors retry until the deadline.
                Ok((404, _)) => return true,
                Ok(_) | Err(_) => {}
            }
            std::thread::sleep(DRAIN_POLL);
        }
        false
    }

    /// Cluster-wide rolling version swap of `name` from checkpoint
    /// `ckpt_path`: for each replica in ring (drain) order — mark the
    /// shard draining, wait its per-model in-flight count to zero, POST
    /// the shard-local hot swap, verify, re-admit. Returns the per-shard
    /// outcome list, or `(status, message)` on the first failed shard
    /// (already-swapped shards keep the new version; the failed shard is
    /// re-admitted on its old one).
    pub fn rolling_swap(
        &self,
        name: &str,
        ckpt_path: &str,
        version: Option<u64>,
    ) -> Result<Json, (u16, String)> {
        let replicas = self.ring.place(name, self.cfg.replication);
        let mut body_pairs = vec![("path", Json::Str(ckpt_path.to_string()))];
        if let Some(v) = version {
            body_pairs.push(("version", Json::Num(v as f64)));
        }
        let body = obj(body_pairs).to_string().into_bytes();
        let mut results: Vec<Json> = Vec::with_capacity(replicas.len());
        for &si in &replicas {
            let u = &self.upstreams[si];
            u.draining.store(true, Ordering::Release);
            let drained = self.wait_drained(&u.addr, name);
            let load = self.admin_exchange(
                &u.addr,
                "POST",
                &format!("/v1/admin/models/{name}/load"),
                &body,
            );
            u.draining.store(false, Ordering::Release);
            // Stale pooled sockets from before the swap are fine (the
            // shard never closed them), but drop them anyway so the next
            // requests observe the new version immediately rather than
            // after a pool cycle.
            u.pool.lock().unwrap().clear();
            match load {
                Ok((200, v)) => {
                    let loaded = v.get("version").and_then(|x| x.as_i64()).unwrap_or(-1);
                    log::event(
                        Level::Info,
                        "cluster",
                        "rolling_swap_shard",
                        0,
                        &[
                            ("model", Field::Str(name)),
                            ("shard", Field::U64(si as u64)),
                            ("version", Field::U64(loaded.max(0) as u64)),
                            ("drained", Field::Bool(drained)),
                        ],
                    );
                    results.push(obj(vec![
                        ("shard", Json::Num(si as f64)),
                        ("addr", Json::Str(u.addr.clone())),
                        ("version", Json::Num(loaded as f64)),
                        ("drained", Json::Bool(drained)),
                    ]));
                }
                Ok((status, v)) => {
                    let msg = v
                        .get("error")
                        .and_then(|e| e.as_str())
                        .unwrap_or("(no error body)")
                        .to_string();
                    return Err((502, format!("shard {si} ({}) answered {status}: {msg}", u.addr)));
                }
                Err(e) => return Err((502, format!("shard {si}: {e}"))),
            }
        }
        self.rolling_swaps.inc();
        Ok(obj(vec![
            ("model", Json::Str(name.to_string())),
            ("status", Json::Str("swapped".to_string())),
            ("replicas", Json::Arr(results)),
        ]))
    }

    /// Topology + live health snapshot for `GET /v1/cluster`.
    pub fn topology_json(&self) -> Json {
        let shards: Vec<Json> = self
            .upstreams
            .iter()
            .enumerate()
            .map(|(i, u)| {
                obj(vec![
                    ("index", Json::Num(i as f64)),
                    ("addr", Json::Str(u.addr.clone())),
                    ("healthy", Json::Bool(u.healthy.load(Ordering::Acquire))),
                    ("draining", Json::Bool(u.draining.load(Ordering::Acquire))),
                    (
                        "breaker",
                        Json::Str(u.breaker.lock().unwrap().state_name().to_string()),
                    ),
                    (
                        "inflight",
                        Json::Num(u.inflight.load(Ordering::Acquire) as f64),
                    ),
                    ("requests", Json::Num(u.requests.get() as f64)),
                    ("errors", Json::Num(u.errors.get() as f64)),
                    ("hedges", Json::Num(u.hedges.get() as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("replication", Json::Num(self.cfg.replication as f64)),
            ("vnodes", Json::Num(self.cfg.vnodes as f64)),
            ("shards", Json::Arr(shards)),
        ])
    }
}

impl Drop for RouterCore {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The live remainder until `deadline` in whole milliseconds, floored at
/// 1 — a just-in-time hop still tells the shard it has *some* budget
/// (forwarding 0 would clamp up to 1 downstream anyway).
fn remaining_ms(deadline: Instant) -> u64 {
    deadline
        .saturating_duration_since(Instant::now())
        .as_millis()
        .max(1) as u64
}

/// Wait until one of `fds` is readable (or error/hangup-ready, which a
/// subsequent read surfaces as the actual error). Returns the index of
/// the first ready fd, or `None` on timeout. `EINTR` retries within the
/// budget.
fn poll_readable(fds: &[i32], timeout: Duration) -> Option<usize> {
    let deadline = Instant::now() + timeout;
    loop {
        let mut pfds: Vec<sys::PollFd> = fds
            .iter()
            .map(|&fd| sys::PollFd {
                fd,
                events: sys::POLLIN,
                revents: 0,
            })
            .collect();
        let remaining = deadline.saturating_duration_since(Instant::now());
        let timeout_ms = remaining.as_millis().min(i32::MAX as u128) as i32;
        let rc = unsafe { sys::poll(pfds.as_mut_ptr(), pfds.len() as _, timeout_ms) };
        if rc > 0 {
            for (i, p) in pfds.iter().enumerate() {
                if p.revents & (sys::POLLIN | sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0 {
                    return Some(i);
                }
            }
        }
        if rc == 0 || Instant::now() >= deadline {
            return None;
        }
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                // Treat a hard poll failure as "first fd ready": the
                // caller's read will produce the real error.
                return Some(0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WINDOW: u32 = 4;
    const RATIO: f64 = 0.5;
    const COOLDOWN: Duration = Duration::from_millis(100);

    #[test]
    fn breaker_trips_only_on_a_full_window_at_the_ratio() {
        let mut b = Breaker::new();
        let t = Instant::now();
        // Three failures in a row: window not yet full, no trip.
        assert_eq!(b.record(false, WINDOW, RATIO, t), None);
        assert_eq!(b.record(false, WINDOW, RATIO, t), None);
        assert_eq!(b.record(false, WINDOW, RATIO, t), None);
        assert!(b.admit(COOLDOWN, t), "closed breaker admits");
        // Fourth outcome fills the window; 3/4 ≥ 0.5 trips.
        assert_eq!(b.record(true, WINDOW, RATIO, t), Some(BreakerState::Open));
        assert!(!b.admit(COOLDOWN, t), "open breaker blocks inside cooldown");
    }

    #[test]
    fn breaker_stays_closed_below_the_ratio() {
        let mut b = Breaker::new();
        let t = Instant::now();
        // Alternating outcomes: 2 failures in a window of 4 at ratio
        // 0.75 never trips.
        for _ in 0..16 {
            assert_eq!(b.record(false, WINDOW, 0.75, t), None);
            assert_eq!(b.record(true, WINDOW, 0.75, t), None);
        }
        assert!(b.admit(COOLDOWN, t));
    }

    #[test]
    fn breaker_half_open_probe_closes_on_success_reopens_on_failure() {
        let mut b = Breaker::new();
        let t = Instant::now();
        for _ in 0..WINDOW {
            b.record(false, WINDOW, RATIO, t);
        }
        assert!(!b.admit(COOLDOWN, t), "fresh trip blocks");
        // Cooldown elapses: one probe is admitted, a second is blocked
        // once the probe has actually fired.
        let after = t + COOLDOWN;
        assert!(b.admit(COOLDOWN, after), "cooldown elapsed → half-open");
        b.on_fire();
        assert!(!b.admit(COOLDOWN, after), "probe in flight blocks");
        // Probe failure re-opens and restarts the cooldown…
        assert_eq!(
            b.record(false, WINDOW, RATIO, after),
            Some(BreakerState::Open)
        );
        assert!(!b.admit(COOLDOWN, after + Duration::from_millis(1)));
        // …second probe succeeds and the breaker closes fully.
        let later = after + COOLDOWN;
        assert!(b.admit(COOLDOWN, later));
        b.on_fire();
        assert_eq!(
            b.record(true, WINDOW, RATIO, later),
            Some(BreakerState::Closed)
        );
        assert!(b.admit(COOLDOWN, later));
        // The window restarted: one failure cannot re-trip it.
        assert_eq!(b.record(false, WINDOW, RATIO, later), None);
    }

    #[test]
    fn breaker_admitted_but_unfired_probe_cannot_wedge() {
        let mut b = Breaker::new();
        let t = Instant::now();
        for _ in 0..WINDOW {
            b.record(false, WINDOW, RATIO, t);
        }
        let after = t + COOLDOWN;
        assert!(b.admit(COOLDOWN, after));
        // The admitted request was never fired at this shard (it lost
        // the least-loaded sort): the next request probes instead.
        assert!(b.admit(COOLDOWN, after), "no on_fire → still admitting");
    }

    #[test]
    fn breaker_ignores_stragglers_while_open() {
        let mut b = Breaker::new();
        let t = Instant::now();
        for _ in 0..WINDOW {
            b.record(false, WINDOW, RATIO, t);
        }
        // An exchange fired before the trip lands its outcome late:
        // no state change, no panic, cooldown clock untouched.
        assert_eq!(b.record(true, WINDOW, RATIO, t), None);
        assert_eq!(b.state_name(), "open");
    }

    #[test]
    fn remaining_ms_floors_at_one() {
        assert_eq!(remaining_ms(Instant::now() - Duration::from_secs(1)), 1);
        let ms = remaining_ms(Instant::now() + Duration::from_millis(500));
        assert!((400..=500).contains(&ms), "got {ms}");
    }
}
