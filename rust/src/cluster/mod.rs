//! Cluster mode: sharded, replicated serving.
//!
//! One process serves one registry; cluster mode composes N of them. A
//! **shard** (`acdc shard`) is the ordinary gateway+registry serving a
//! subset of models; a **router** (`acdc router`) fronts the shards,
//! placing each model on the consistent-hash ring ([`ring::Ring`]),
//! replicating it `replication` ways, and forwarding inference traffic
//! with least-loaded fan-out, transport-failure retry, and latency
//! hedging ([`router::RouterCore`]). Membership is a static TOML
//! topology (`[cluster]`, see [`crate::config::ClusterConfig`]) kept
//! live by `/healthz` probes with mark-down/mark-up hysteresis.
//!
//! The registry's Arc-epoch hot swap extends to a cluster-wide
//! **rolling swap**: `POST /v1/admin/cluster/models/{name}/load` on the
//! router drains and upgrades one replica at a time under live traffic,
//! so a version promotion completes with zero failed requests.

pub mod ring;
pub mod router;

pub use ring::{Ring, DEFAULT_VNODES};
pub use router::{ProxyReply, RouterCore};
