//! Low-rank factorized layer — the "Finetuned SVD" baselines of Table 1:
//! `W ≈ U·V` with `U ∈ R^{n×r}`, `V ∈ R^{r×n}`, 2nr parameters.

use super::LinearOp;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// `y = (x·U)·V`.
#[derive(Debug, Clone)]
pub struct LowRankLayer {
    /// Left factor `[n, r]`.
    pub u: Tensor,
    /// Right factor `[r, n]`.
    pub v: Tensor,
}

impl LowRankLayer {
    /// Layer from explicit factors (shapes must chain to square).
    pub fn new(u: Tensor, v: Tensor) -> LowRankLayer {
        assert_eq!(u.rank(), 2);
        assert_eq!(v.rank(), 2);
        assert_eq!(u.cols(), v.rows(), "rank dims must agree");
        assert_eq!(u.rows(), v.cols(), "square operator expected");
        LowRankLayer { u, v }
    }

    /// Random factors at 1/√n scale.
    pub fn random(n: usize, rank: usize, rng: &mut Pcg32) -> LowRankLayer {
        let s = 1.0 / (n as f64).sqrt();
        LowRankLayer::new(
            Tensor::from_vec(&[n, rank], rng.normal_vec(n * rank, 0.0, s)),
            Tensor::from_vec(&[rank, n], rng.normal_vec(rank * n, 0.0, s)),
        )
    }

    /// Best rank-r approximation of `w` via a few rounds of orthogonal
    /// iteration (enough for the experiments' fidelity checks).
    pub fn approximate(w: &Tensor, rank: usize, rng: &mut Pcg32, iters: usize) -> LowRankLayer {
        let n = w.rows();
        assert_eq!(w.cols(), n);
        // Orthogonal iteration on W·Wᵀ to find the top-r left subspace.
        let mut q = Tensor::from_vec(&[n, rank], rng.normal_vec(n * rank, 0.0, 1.0));
        gram_schmidt(&mut q);
        let wt = w.transpose();
        for _ in 0..iters {
            // Q <- orth(W·(Wᵀ·Q))
            let z = w.matmul(&wt.matmul(&q));
            q = z;
            gram_schmidt(&mut q);
        }
        // U = Q (orthonormal basis), V = Qᵀ·W so U·V = Q·Qᵀ·W ≈ W.
        let v = q.transpose().matmul(w);
        LowRankLayer::new(q, v)
    }

    /// The factorization rank r.
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    /// Batched backward. Returns `(∂L/∂x, grads)` with factor gradients
    /// summed over rows. With `z = x·U`, `y = z·V`:
    ///   ∂L/∂V = zᵀ·gy,  dz = gy·Vᵀ,  ∂L/∂U = xᵀ·dz,  ∂L/∂x = dz·Uᵀ.
    pub fn backward(&self, x: &Tensor, gy: &Tensor) -> (Tensor, LowRankGrads) {
        assert_eq!(x.cols(), self.width());
        assert_eq!(gy.shape(), x.shape());
        let z = x.matmul(&self.u);
        let dv = z.transpose().matmul(gy);
        let dz = gy.matmul(&self.v.transpose());
        let du = x.transpose().matmul(&dz);
        let gx = dz.matmul(&self.u.transpose());
        (gx, LowRankGrads { u: du, v: dv })
    }
}

/// Gradients of one [`LowRankLayer`], summed over batch rows.
#[derive(Debug, Clone)]
pub struct LowRankGrads {
    /// ∂L/∂U, shape `[n, r]`.
    pub u: Tensor,
    /// ∂L/∂V, shape `[r, n]`.
    pub v: Tensor,
}

/// In-place modified Gram–Schmidt on the columns of q [n, r].
fn gram_schmidt(q: &mut Tensor) {
    let (n, r) = (q.rows(), q.cols());
    for j in 0..r {
        for prev in 0..j {
            let mut dot = 0.0f64;
            for i in 0..n {
                dot += q.get2(i, j) as f64 * q.get2(i, prev) as f64;
            }
            for i in 0..n {
                let v = q.get2(i, j) - dot as f32 * q.get2(i, prev);
                q.set2(i, j, v);
            }
        }
        let norm = (0..n)
            .map(|i| (q.get2(i, j) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            .max(1e-12);
        for i in 0..n {
            let v = (q.get2(i, j) as f64 / norm) as f32;
            q.set2(i, j, v);
        }
    }
}

impl LinearOp for LowRankLayer {
    fn width(&self) -> usize {
        self.u.rows()
    }

    fn param_count(&self) -> usize {
        self.u.numel() + self.v.numel()
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        x.matmul(&self.u).matmul(&self.v)
    }

    fn name(&self) -> &'static str {
        "lowrank"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_is_2nr() {
        let mut rng = Pcg32::seeded(1);
        let l = LowRankLayer::random(64, 8, &mut rng);
        assert_eq!(l.param_count(), 2 * 64 * 8);
        assert_eq!(l.rank(), 8);
    }

    #[test]
    fn forward_shape() {
        let mut rng = Pcg32::seeded(2);
        let l = LowRankLayer::random(16, 4, &mut rng);
        let x = Tensor::from_vec(&[3, 16], rng.normal_vec(48, 0.0, 1.0));
        assert_eq!(l.forward(&x).shape(), &[3, 16]);
    }

    #[test]
    fn backward_matches_dense_gradients() {
        // y = x·M with M = U·V gives gx = gy·Mᵀ; factor gradients check
        // against the closed forms dU = xᵀ·gy·Vᵀ and dV = Uᵀ·xᵀ·gy.
        let mut rng = Pcg32::seeded(6);
        let (n, r, rows) = (16, 4, 5);
        let l = LowRankLayer::random(n, r, &mut rng);
        let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
        let y = l.forward(&x);
        let (gx, grads) = l.backward(&x, &y);
        let m = l.u.matmul(&l.v);
        assert!(gx.max_abs_diff(&y.matmul(&m.transpose())) < 1e-4);
        let xtgy = x.transpose().matmul(&y);
        assert!(grads.u.max_abs_diff(&xtgy.matmul(&l.v.transpose())) < 1e-4);
        assert!(grads.v.max_abs_diff(&l.u.transpose().matmul(&xtgy)) < 1e-4);
        assert_eq!(grads.u.shape(), &[n, r]);
        assert_eq!(grads.v.shape(), &[r, n]);
    }

    #[test]
    fn full_rank_approximation_recovers_matrix() {
        let mut rng = Pcg32::seeded(3);
        let n = 8;
        let w = Tensor::from_vec(&[n, n], rng.normal_vec(n * n, 0.0, 1.0));
        let l = LowRankLayer::approximate(&w, n, &mut rng, 30);
        let recon = l.u.matmul(&l.v);
        assert!(recon.max_abs_diff(&w) < 1e-2, "diff={}", recon.max_abs_diff(&w));
    }

    #[test]
    fn rank1_captures_rank1_matrix_exactly() {
        let mut rng = Pcg32::seeded(4);
        let n = 12;
        let u = rng.normal_vec(n, 0.0, 1.0);
        let v = rng.normal_vec(n, 0.0, 1.0);
        let mut w = Tensor::zeros(&[n, n]);
        for i in 0..n {
            for j in 0..n {
                w.set2(i, j, u[i] * v[j]);
            }
        }
        let l = LowRankLayer::approximate(&w, 1, &mut rng, 40);
        let recon = l.u.matmul(&l.v);
        assert!(recon.max_abs_diff(&w) < 1e-2);
    }

    #[test]
    fn truncated_rank_reduces_error_monotonically() {
        let mut rng = Pcg32::seeded(5);
        let n = 16;
        let w = Tensor::from_vec(&[n, n], rng.normal_vec(n * n, 0.0, 1.0));
        let mut errs = vec![];
        for r in [1usize, 4, 8, 16] {
            let l = LowRankLayer::approximate(&w, r, &mut rng, 30);
            errs.push(l.u.matmul(&l.v).sub(&w).norm());
        }
        for pair in errs.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-3, "errs={errs:?}");
        }
    }
}
