//! Structured Efficient Linear Layers — pure-rust reference implementations.
//!
//! The paper's eq. (2) family `y = x·Φ(D, P, S, B)`:
//!
//! * [`acdc`] — the paper's contribution: `A·C·D·C⁻¹` with fused
//!   ("single call", §5.1) and multipass ("multiple call", §5.2) execution
//!   strategies, plus deep cascades with ReLU/permutation interleaving;
//! * [`dense`] — the O(N²) baseline the paper compares against;
//! * [`circulant`] — Cheng et al. (2015): `D·F·D·F⁻¹` via real FFT;
//! * [`fastfood`] — Yang et al. (2015) Adaptive Fastfood `S·H·G·P·H·B`
//!   via the fast Walsh–Hadamard transform;
//! * [`lowrank`] — truncated factorization (the Finetuned-SVD rows);
//! * [`init`] — the §6 initialization strategies;
//! * [`params`] — parameter audits powering Table 1 / Figure 4.
//!
//! These serve three roles: the correctness oracle for the PJRT artifacts,
//! the measured "CPU testbed" legs of Figure 2, and the baselines the paper
//! compares against in Table 1.

pub mod acdc;
pub mod circulant;
pub mod dense;
pub mod fastfood;
pub mod init;
pub mod lowrank;
pub mod params;

use crate::tensor::Tensor;

/// The trainable/servable SELL families, as selected by the trainer's
/// `model_kind` knob and recorded in checkpoint manifests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Deep ACDC cascade (the paper's family).
    Acdc,
    /// Adaptive Fastfood `S·H·G·P·H·B` (Yang et al. 2015).
    Fastfood,
    /// Low-rank factorization `U·V` (the Finetuned-SVD rows).
    LowRank,
    /// Deep diagonal-circulant cascade (Araujo et al. 2019).
    Circulant,
}

impl ModelKind {
    /// Every family, in the order they appear in docs and benches.
    pub const ALL: [ModelKind; 4] = [
        ModelKind::Acdc,
        ModelKind::Fastfood,
        ModelKind::LowRank,
        ModelKind::Circulant,
    ];

    /// Wire name, as accepted by config and the HTTP train body.
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Acdc => "acdc",
            ModelKind::Fastfood => "fastfood",
            ModelKind::LowRank => "lowrank",
            ModelKind::Circulant => "circulant",
        }
    }

    /// Parse a wire name; `None` on unknown kinds (callers turn this into
    /// a typed 400 / config error listing [`ModelKind::ALL`]).
    pub fn parse(s: &str) -> Option<ModelKind> {
        ModelKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }

    /// Whether the family's transform substrate (DCT/FWHT/FFT) restricts
    /// the width to powers of two. Low-rank is plain matmul and is exempt.
    pub fn needs_pow2_width(&self) -> bool {
        !matches!(self, ModelKind::LowRank)
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A square linear(ish) operator on row-major batches.
///
/// Object-safe so harnesses can sweep heterogeneous layer families; the
/// training hot paths use the concrete types directly.
pub trait LinearOp {
    /// Input/output width N.
    fn width(&self) -> usize;
    /// Learnable parameter count (the Table-1 quantity).
    fn param_count(&self) -> usize;
    /// y = forward(x), x shape [batch, N].
    fn forward(&self, x: &Tensor) -> Tensor;
    /// Human-readable family name.
    fn name(&self) -> &'static str;
}

/// Materialize any LinearOp into its dense matrix (rows = unit vectors).
/// O(N²) — used by tests and the operator-approximation experiments.
pub fn materialize(op: &dyn LinearOp) -> Tensor {
    let n = op.width();
    let eye = Tensor::eye(n);
    op.forward(&eye)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn model_kind_round_trips_and_rejects_unknowns() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::parse(kind.as_str()), Some(kind));
            assert_eq!(format!("{kind}"), kind.as_str());
        }
        assert_eq!(ModelKind::parse("dense"), None);
        assert_eq!(ModelKind::parse("ACDC"), None); // case-sensitive wire names
        assert!(!ModelKind::LowRank.needs_pow2_width());
        assert!(ModelKind::Circulant.needs_pow2_width());
    }

    #[test]
    fn materialize_dense_recovers_matrix() {
        let mut rng = Pcg32::seeded(1);
        let n = 8;
        let w = Tensor::from_vec(&[n, n], rng.normal_vec(n * n, 0.0, 1.0));
        let layer = dense::DenseLayer::new(w.clone(), None);
        let m = materialize(&layer);
        assert!(m.max_abs_diff(&w) < 1e-5);
    }

    #[test]
    fn materialize_acdc_matches_forward() {
        let mut rng = Pcg32::seeded(2);
        let n = 16;
        let layer = acdc::AcdcLayer::random(n, &mut rng, 1.0, 0.2);
        let m = materialize(&layer);
        let x = Tensor::from_vec(&[3, n], rng.normal_vec(3 * n, 0.0, 1.0));
        let via_matrix = x.matmul(&m);
        let direct = layer.forward(&x);
        assert!(via_matrix.max_abs_diff(&direct) < 1e-3);
    }
}
