//! Dense linear layer — the O(N²) baseline of Figure 2 and Table 1.

use super::LinearOp;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// `y = x·W + b` with a full [n, n] weight matrix.
#[derive(Debug, Clone)]
pub struct DenseLayer {
    /// Weight matrix `[n, n]`.
    pub w: Tensor,
    /// Optional bias row.
    pub b: Option<Vec<f32>>,
}

impl DenseLayer {
    /// Layer from explicit weights (+ optional bias).
    pub fn new(w: Tensor, b: Option<Vec<f32>>) -> DenseLayer {
        assert_eq!(w.rank(), 2);
        if let Some(b) = &b {
            assert_eq!(b.len(), w.cols());
        }
        DenseLayer { w, b }
    }

    /// Glorot-uniform random square layer.
    pub fn random(n: usize, rng: &mut Pcg32) -> DenseLayer {
        let limit = (6.0 / (2 * n) as f64).sqrt();
        DenseLayer::new(
            Tensor::from_vec(&[n, n], rng.uniform_vec(n * n, -limit, limit)),
            None,
        )
    }

    /// Zero-initialized (for regression-from-scratch baselines).
    pub fn zeros(n: usize) -> DenseLayer {
        DenseLayer::new(Tensor::zeros(&[n, n]), None)
    }

    /// Backward for L wrt inputs and weights: given x and g = ∂L/∂y,
    /// returns (∂L/∂x = g·Wᵀ, ∂L/∂W = xᵀ·g, ∂L/∂b = Σg).
    pub fn backward(&self, x: &Tensor, g: &Tensor) -> (Tensor, Tensor, Vec<f32>) {
        let gx = g.matmul(&self.w.transpose());
        let gw = x.transpose().matmul(g);
        let mut gb = vec![0.0f32; self.w.cols()];
        for r in 0..g.rows() {
            for (bi, &gv) in gb.iter_mut().zip(g.row(r)) {
                *bi += gv;
            }
        }
        (gx, gw, gb)
    }

    /// Plain SGD update of weights (and bias when present).
    pub fn sgd_step(&mut self, gw: &Tensor, gb: &[f32], lr: f32) {
        self.w.axpy(-lr, gw);
        if let Some(b) = &mut self.b {
            for (bv, &gv) in b.iter_mut().zip(gb) {
                *bv -= lr * gv;
            }
        }
    }
}

impl LinearOp for DenseLayer {
    fn width(&self) -> usize {
        self.w.rows()
    }

    fn param_count(&self) -> usize {
        self.w.numel() + self.b.as_ref().map_or(0, |b| b.len())
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let mut y = x.matmul(&self.w);
        if let Some(b) = &self.b {
            for r in 0..y.rows() {
                for (yv, &bv) in y.row_mut(r).iter_mut().zip(b) {
                    *yv += bv;
                }
            }
        }
        y
    }

    fn name(&self) -> &'static str {
        "dense"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_with_bias() {
        let w = Tensor::eye(2);
        let layer = DenseLayer::new(w, Some(vec![1.0, -1.0]));
        let x = Tensor::from_vec(&[1, 2], vec![3.0, 4.0]);
        assert_eq!(layer.forward(&x).data(), &[4.0, 3.0]);
    }

    #[test]
    fn param_count_counts_bias() {
        let layer = DenseLayer::new(Tensor::zeros(&[4, 4]), Some(vec![0.0; 4]));
        assert_eq!(layer.param_count(), 20);
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(1);
        let n = 6;
        let layer = DenseLayer::random(n, &mut rng);
        let x = Tensor::from_vec(&[3, n], rng.normal_vec(3 * n, 0.0, 1.0));
        let y = layer.forward(&x);
        let (gx, gw, _) = layer.backward(&x, &y); // L = 0.5||y||²
        let loss = |l: &DenseLayer, x: &Tensor| -> f64 {
            l.forward(x)
                .data()
                .iter()
                .map(|v| 0.5 * (*v as f64).powi(2))
                .sum()
        };
        let eps = 1e-3;
        let mut lp = layer.clone();
        let v = lp.w.get2(2, 3) + eps;
        lp.w.set2(2, 3, v);
        let mut lm = layer.clone();
        let v = lm.w.get2(2, 3) - eps;
        lm.w.set2(2, 3, v);
        let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64);
        assert!((gw.get2(2, 3) as f64 - fd).abs() < 1e-2 * fd.abs().max(1.0));

        let mut xp = x.clone();
        let v = xp.get2(1, 4) + eps;
        xp.set2(1, 4, v);
        let mut xm = x.clone();
        let v = xm.get2(1, 4) - eps;
        xm.set2(1, 4, v);
        let fd = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps as f64);
        assert!((gx.get2(1, 4) as f64 - fd).abs() < 1e-2 * fd.abs().max(1.0));
    }

    #[test]
    fn sgd_fits_linear_regression() {
        let mut rng = Pcg32::seeded(2);
        let n = 8;
        let target = DenseLayer::random(n, &mut rng);
        let x = Tensor::from_vec(&[128, n], rng.uniform_vec(128 * n, 0.0, 1.0));
        let y_true = target.forward(&x);
        let mut model = DenseLayer::zeros(n);
        let mut loss = f32::INFINITY;
        for _ in 0..300 {
            let y = model.forward(&x);
            let mut diff = y.sub(&y_true);
            loss = diff.data().iter().map(|v| v * v).sum::<f32>() / 128.0;
            diff.scale(2.0 / 128.0);
            let (_, gw, gb) = model.backward(&x, &diff);
            model.sgd_step(&gw, &gb, 0.1);
        }
        assert!(loss < 1e-3, "loss={loss}");
        assert!(model.w.max_abs_diff(&target.w) < 0.05);
    }
}
