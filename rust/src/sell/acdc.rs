//! The ACDC layer and deep cascades — the paper's core contribution (§4).
//!
//! `y = ((x ⊙ a) · C ⊙ d + bias) · Cᵀ` with two execution strategies
//! mirroring §5:
//!
//! * **fused** ("single call", §5.1): each row makes one pass through a
//!   small scratch buffer — scale, DCT-II, scale+bias, DCT-III — touching
//!   main memory exactly once for load and once for store (the paper's
//!   8N-bytes/row ideal);
//! * **multipass** ("multiple call", §5.2): four separate full-batch
//!   passes materializing `h1..h3`, the way a naive framework composition
//!   (or the paper's cuFFT fallback) executes, with ~4× the memory
//!   traffic.
//!
//! The backward pass implements the paper's closed-form gradients
//! (eqs. 10–14) and *recomputes* `h2` rather than caching it — the same
//! memory/runtime trade the paper's §5 implementation makes.

use std::sync::Arc;

use super::LinearOp;
use crate::dct::{BatchEngine, DctPlan, PanelScratch, PlanCache, MIN_SOA_ROWS};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use crate::util::threadpool::ThreadPool;

/// One ACDC layer: diagonals `a`, `d` and a spectral-domain `bias` (§6.2
/// places biases on D only).
///
/// ```
/// use acdc::sell::acdc::AcdcLayer;
/// use acdc::tensor::Tensor;
/// let layer = AcdcLayer::identity(8); // a = d = 1, bias = 0
/// let x = Tensor::from_vec(&[2, 8], (0..16).map(|i| i as f32).collect());
/// let y = layer.forward_batch(&x); // identity ACDC leaves x unchanged
/// assert!(y.max_abs_diff(&x) < 1e-4);
/// ```
#[derive(Debug, Clone)]
pub struct AcdcLayer {
    /// Input-side diagonal `A`.
    pub a: Vec<f32>,
    /// Spectral-domain diagonal `D`.
    pub d: Vec<f32>,
    /// Spectral-domain bias (added after `D`, before `C⁻¹`).
    pub bias: Vec<f32>,
    plan: Arc<DctPlan>,
}

impl AcdcLayer {
    /// Layer from explicit parameters over a shared plan.
    pub fn new(a: Vec<f32>, d: Vec<f32>, bias: Vec<f32>, plan: Arc<DctPlan>) -> AcdcLayer {
        let n = plan.len();
        assert_eq!(a.len(), n);
        assert_eq!(d.len(), n);
        assert_eq!(bias.len(), n);
        AcdcLayer { a, d, bias, plan }
    }

    /// Identity layer (a = d = 1, bias = 0).
    pub fn identity(n: usize) -> AcdcLayer {
        AcdcLayer::new(vec![1.0; n], vec![1.0; n], vec![0.0; n], PlanCache::get(n))
    }

    /// Random layer with N(mean, sigma²) diagonals and zero bias.
    pub fn random(n: usize, rng: &mut Pcg32, mean: f64, sigma: f64) -> AcdcLayer {
        AcdcLayer::new(
            rng.normal_vec(n, mean, sigma),
            rng.normal_vec(n, mean, sigma),
            vec![0.0; n],
            PlanCache::get(n),
        )
    }

    /// Layer width N.
    pub fn n(&self) -> usize {
        self.plan.len()
    }

    /// The shared DCT plan (one per size, via [`PlanCache`]).
    pub fn plan(&self) -> &Arc<DctPlan> {
        &self.plan
    }

    /// Fused single-pass forward of one row into `out` using `scratch`
    /// (≥ 3n: n for the row buffer + 2n for the FFT). This is the §5.1
    /// single-call strategy: intermediates never leave the scratch.
    pub fn forward_row_fused(&self, x: &[f32], out: &mut [f32], scratch: &mut [f32]) {
        let n = self.n();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(out.len(), n);
        debug_assert!(scratch.len() >= 3 * n);
        let (buf, fft_scratch) = scratch.split_at_mut(n);
        // h1 = x ⊙ a
        for i in 0..n {
            buf[i] = x[i] * self.a[i];
        }
        // h2 = h1 · C
        self.plan.dct2(buf, fft_scratch);
        // h3 = h2 ⊙ d + bias
        for i in 0..n {
            buf[i] = buf[i] * self.d[i] + self.bias[i];
        }
        // y = h3 · Cᵀ
        self.plan.dct3(buf, fft_scratch);
        out.copy_from_slice(buf);
    }

    /// Fused forward of a PAIR of rows sharing one complex FFT per
    /// transform (2-for-1 real-FFT packing — perf pass, §Perf).
    /// `scratch` must be ≥ 4n: two row buffers + 2n FFT scratch.
    pub fn forward_rows_pair(
        &self,
        x1: &[f32],
        x2: &[f32],
        out1: &mut [f32],
        out2: &mut [f32],
        scratch: &mut [f32],
    ) {
        let n = self.n();
        debug_assert!(scratch.len() >= 4 * n);
        let (bufs, fft_scratch) = scratch.split_at_mut(2 * n);
        let (b1, b2) = bufs.split_at_mut(n);
        for i in 0..n {
            b1[i] = x1[i] * self.a[i];
            b2[i] = x2[i] * self.a[i];
        }
        self.plan.dct2_pair(b1, b2, fft_scratch);
        for i in 0..n {
            b1[i] = b1[i] * self.d[i] + self.bias[i];
            b2[i] = b2[i] * self.d[i] + self.bias[i];
        }
        self.plan.dct3_pair(b1, b2, fft_scratch);
        out1.copy_from_slice(b1);
        out2.copy_from_slice(b2);
    }

    /// Fused forward over a whole batch (serial over rows, paired FFTs).
    pub fn forward_fused(&self, x: &Tensor) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        let mut out = Tensor::zeros(&[rows, n]);
        let mut scratch = vec![0.0f32; 4 * n];
        let mut r = 0;
        while r + 1 < rows {
            // Disjoint row views of the output buffer.
            let (head, tail) = out.data_mut()[r * n..].split_at_mut(n);
            self.forward_rows_pair(x.row(r), x.row(r + 1), head, &mut tail[..n], &mut scratch);
            r += 2;
        }
        if r < rows {
            self.forward_row_fused(x.row(r), out.row_mut(r), &mut scratch);
        }
        out
    }

    /// Batched SoA forward through the fused [`BatchEngine`] — the
    /// serving hot path. One panel load and one panel store of traffic
    /// per 8 rows (DESIGN.md §4); falls back to the scalar fused path
    /// below [`MIN_SOA_ROWS`] rows, where padded lanes would waste work.
    pub fn forward_batch(&self, x: &Tensor) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        if rows < MIN_SOA_ROWS {
            return self.forward_fused(x);
        }
        let engine = BatchEngine::new(Arc::clone(&self.plan));
        let mut out = Tensor::zeros(&[rows, n]);
        engine.acdc_rows(&self.a, &self.d, &self.bias, x.data(), out.data_mut(), rows);
        out
    }

    /// [`AcdcLayer::forward_batch`] with panels fanned out across `pool`
    /// (the process-wide serving pool in production).
    pub fn forward_batch_pooled(&self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        if rows < MIN_SOA_ROWS {
            return self.forward_fused(x);
        }
        let engine = BatchEngine::new(Arc::clone(&self.plan));
        let mut out = Tensor::zeros(&[rows, n]);
        engine.acdc_rows_parallel(
            &self.a,
            &self.d,
            &self.bias,
            x.data(),
            out.data_mut(),
            rows,
            pool,
        );
        out
    }

    /// Multipass forward: materializes h1, h2, h3 as full batch tensors —
    /// the §5.2 "multiple call" strategy with ≫8N bytes of traffic.
    pub fn forward_multipass(&self, x: &Tensor) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        // pass 1: h1 = x ⊙ a (full batch materialized)
        let mut h = Tensor::zeros(&[rows, n]);
        for r in 0..rows {
            let src = x.row(r);
            let dst = h.row_mut(r);
            for i in 0..n {
                dst[i] = src[i] * self.a[i];
            }
        }
        // pass 2: h2 = h1 · C (separate full-batch DCT pass)
        self.plan.dct2_rows(h.data_mut(), rows);
        // pass 3: h3 = h2 ⊙ d + bias
        for r in 0..rows {
            let dst = h.row_mut(r);
            for i in 0..n {
                dst[i] = dst[i] * self.d[i] + self.bias[i];
            }
        }
        // pass 4: y = h3 · Cᵀ
        self.plan.dct3_rows(h.data_mut(), rows);
        h
    }

    /// Backward pass (paper eqs. 10–14) for a batch.
    ///
    /// Given x and g = ∂L/∂y, returns (∂L/∂x, grads). `h2` is recomputed
    /// (§5: "recompute these during the backward pass ... saving memory").
    /// From [`MIN_SOA_ROWS`] rows up all four DCTs run through the batched
    /// SoA engine (materializing two `[rows, n]` intermediates); below
    /// that the scalar path keeps the original O(n) scratch footprint.
    pub fn backward(&self, x: &Tensor, g: &Tensor) -> (Tensor, AcdcGrads) {
        let n = self.n();
        assert_eq!(x.cols(), n);
        assert_eq!(g.cols(), n);
        assert_eq!(x.rows(), g.rows());
        let rows = x.rows();
        if rows < MIN_SOA_ROWS {
            return self.backward_scalar(x, g);
        }
        let engine = BatchEngine::new(Arc::clone(&self.plan));
        let mut grads = AcdcGrads::zeros(n);
        // recompute h2 = (x ⊙ a) · C — batched
        let mut h2 = Tensor::zeros(&[rows, n]);
        for r in 0..rows {
            let xr = x.row(r);
            let dst = h2.row_mut(r);
            for i in 0..n {
                dst[i] = xr[i] * self.a[i];
            }
        }
        engine.dct2_rows(h2.data_mut(), rows);
        // gh3 = g · C (eq. 10's C·∂L/∂y in row form) — batched
        let mut gh = g.clone();
        engine.dct2_rows(gh.data_mut(), rows);
        for r in 0..rows {
            let h2r = h2.row(r);
            let ghr = gh.row_mut(r);
            for i in 0..n {
                grads.d[i] += h2r[i] * ghr[i]; // eq. 10
                grads.bias[i] += ghr[i];
                ghr[i] *= self.d[i]; // gh2
            }
        }
        // gh1 = gh2 · Cᵀ — batched
        engine.dct3_rows(gh.data_mut(), rows);
        let mut gx = Tensor::zeros(&[rows, n]);
        for r in 0..rows {
            let xr = x.row(r);
            let ghr = gh.row(r);
            let gxr = gx.row_mut(r);
            for i in 0..n {
                grads.a[i] += xr[i] * ghr[i]; // eq. 12
                gxr[i] = self.a[i] * ghr[i]; // eq. 14
            }
        }
        (gx, grads)
    }

    /// Scalar backward (one row at a time, two n-length scratch buffers —
    /// the original §5 memory trade, kept for tiny batches).
    fn backward_scalar(&self, x: &Tensor, g: &Tensor) -> (Tensor, AcdcGrads) {
        let n = self.n();
        let rows = x.rows();
        let mut gx = Tensor::zeros(&[rows, n]);
        let mut grads = AcdcGrads::zeros(n);
        let mut scratch = vec![0.0f32; 2 * n];
        let mut h2 = vec![0.0f32; n];
        let mut gh = vec![0.0f32; n];
        for r in 0..rows {
            let xr = x.row(r);
            // recompute h2 = (x ⊙ a) · C
            for i in 0..n {
                h2[i] = xr[i] * self.a[i];
            }
            self.plan.dct2(&mut h2, &mut scratch);
            // gh3 = g · C (eq. 10's C·∂L/∂y in row form)
            gh.copy_from_slice(g.row(r));
            self.plan.dct2(&mut gh, &mut scratch);
            for i in 0..n {
                grads.d[i] += h2[i] * gh[i]; // eq. 10
                grads.bias[i] += gh[i];
                gh[i] *= self.d[i]; // gh2
            }
            // gh1 = gh2 · Cᵀ
            self.plan.dct3(&mut gh, &mut scratch);
            let gxr = gx.row_mut(r);
            for i in 0..n {
                grads.a[i] += xr[i] * gh[i]; // eq. 12
                gxr[i] = self.a[i] * gh[i]; // eq. 14
            }
        }
        (gx, grads)
    }

    /// SGD update with per-diagonal learning-rate multipliers (§6.2).
    pub fn sgd_step(&mut self, grads: &AcdcGrads, lr: f32, lr_mult_a: f32, lr_mult_d: f32) {
        for i in 0..self.a.len() {
            self.a[i] -= lr * lr_mult_a * grads.a[i];
            self.d[i] -= lr * lr_mult_d * grads.d[i];
            self.bias[i] -= lr * lr_mult_d * grads.bias[i];
        }
    }
}

impl LinearOp for AcdcLayer {
    fn width(&self) -> usize {
        self.n()
    }

    fn param_count(&self) -> usize {
        3 * self.n() // a + d + bias
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_batch(x)
    }

    fn name(&self) -> &'static str {
        "acdc"
    }
}

/// Parameter gradients of one ACDC layer (batch-summed).
#[derive(Debug, Clone)]
pub struct AcdcGrads {
    /// ∂L/∂a (eq. 12).
    pub a: Vec<f32>,
    /// ∂L/∂d (eq. 10).
    pub d: Vec<f32>,
    /// ∂L/∂bias.
    pub bias: Vec<f32>,
}

impl AcdcGrads {
    /// Zero-initialized gradient accumulator of width `n`.
    pub fn zeros(n: usize) -> AcdcGrads {
        AcdcGrads {
            a: vec![0.0; n],
            d: vec![0.0; n],
            bias: vec![0.0; n],
        }
    }

    /// Multiply every gradient by `s` (batch-mean normalization).
    pub fn scale(&mut self, s: f32) {
        for v in self.a.iter_mut().chain(&mut self.d).chain(&mut self.bias) {
            *v *= s;
        }
    }
}

/// Deep ACDC cascade (Definition 1) with optional §6.2 interleaving:
/// fixed permutations after each layer and ReLU between layers.
#[derive(Debug, Clone)]
pub struct AcdcCascade {
    /// The stacked ACDC layers (all sharing one [`DctPlan`]).
    pub layers: Vec<AcdcLayer>,
    /// Per-layer permutation applied after the layer (None = identity).
    pub perms: Option<Vec<Vec<u32>>>,
    /// ReLU after every layer except the last.
    pub relu: bool,
    /// Whether SGD updates the spectral biases. The paper's Fig-3 linear
    /// cascade is pure `A·C·D·C⁻¹` (no bias); §6.2's nonlinear stack puts
    /// trainable biases on D.
    pub train_bias: bool,
}

impl AcdcCascade {
    /// Linear cascade (no perms / ReLU) with the given diagonal init —
    /// the Figure-3 model.
    pub fn linear(n: usize, k: usize, init: super::init::DiagInit, rng: &mut Pcg32) -> Self {
        let plan = PlanCache::get(n);
        let layers = (0..k)
            .map(|_| {
                AcdcLayer::new(
                    init.sample(n, rng),
                    init.sample(n, rng),
                    vec![0.0; n],
                    Arc::clone(&plan),
                )
            })
            .collect();
        AcdcCascade {
            layers,
            perms: None,
            relu: false,
            train_bias: false,
        }
    }

    /// §6.2-style cascade: ReLU + per-layer random permutations.
    pub fn nonlinear(n: usize, k: usize, init: super::init::DiagInit, rng: &mut Pcg32) -> Self {
        let mut c = Self::linear(n, k, init, rng);
        c.relu = true;
        c.train_bias = true;
        c.perms = Some((0..k).map(|_| rng.permutation(n)).collect());
        c
    }

    /// Cascade width N.
    pub fn n(&self) -> usize {
        self.layers[0].n()
    }

    /// Cascade depth K.
    pub fn k(&self) -> usize {
        self.layers.len()
    }

    /// Forward through all layers. Small batches take the scalar fused
    /// row path (each row stays in scratch across the whole cascade);
    /// from [`MIN_SOA_ROWS`] rows up, each layer runs through the batched
    /// SoA engine ([`AcdcLayer::forward_batch`]).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        if x.rows() < MIN_SOA_ROWS {
            self.forward_scalar(x)
        } else {
            self.forward_batch(x)
        }
    }

    /// Scalar fused forward (one row through every layer while it sits in
    /// scratch — the deep analogue of the single-call kernel; best for
    /// latency-critical single rows).
    fn forward_scalar(&self, x: &Tensor) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        let mut out = Tensor::zeros(&[rows, n]);
        let mut scratch = vec![0.0f32; 3 * n];
        let mut row = vec![0.0f32; n];
        let mut tmp = vec![0.0f32; n];
        for r in 0..rows {
            row.copy_from_slice(x.row(r));
            for (li, layer) in self.layers.iter().enumerate() {
                layer.forward_row_fused(&row, &mut tmp, &mut scratch);
                if let Some(perms) = &self.perms {
                    for (i, &p) in perms[li].iter().enumerate() {
                        row[i] = tmp[p as usize];
                    }
                } else {
                    row.copy_from_slice(&tmp);
                }
                if self.relu && li != self.layers.len() - 1 {
                    for v in row.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            out.row_mut(r).copy_from_slice(&row);
        }
        out
    }

    /// Batched SoA forward: every layer is one fused panel sweep over the
    /// whole batch, with perms/ReLU applied between layers.
    pub fn forward_batch(&self, x: &Tensor) -> Tensor {
        self.forward_layers(x, None)
    }

    /// [`AcdcCascade::forward_batch`] with panels fanned out across
    /// `pool` — the serving executors' bulk path.
    pub fn forward_pooled(&self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        self.forward_layers(x, Some(pool))
    }

    fn forward_layers(&self, x: &Tensor, pool: Option<&ThreadPool>) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            let mut y = match pool {
                Some(p) => layer.forward_batch_pooled(&h, p),
                None => layer.forward_batch(&h),
            };
            if let Some(perms) = &self.perms {
                y = apply_perm(&y, &perms[li]);
            }
            if self.relu && li != self.layers.len() - 1 {
                for v in y.data_mut().iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            h = y;
        }
        h
    }

    /// Allocation-free forward over a flat `[rows, n]` buffer — the
    /// serving executors' steady-state hot path. Numerically identical to
    /// [`AcdcCascade::forward`] (same per-rows-count engine selection,
    /// same kernels, bit for bit); all intermediates live in `scratch`,
    /// which is grown on first use and reused across batches, so the
    /// steady state performs **zero heap allocations**.
    pub fn forward_rows_into(
        &self,
        x: &[f32],
        rows: usize,
        out: &mut [f32],
        scratch: &mut CascadeScratch,
    ) {
        let n = self.n();
        assert_eq!(x.len(), rows * n, "x len vs rows × n");
        assert_eq!(out.len(), rows * n, "out len vs rows × n");
        scratch.ensure(n, rows);
        if rows < MIN_SOA_ROWS {
            return self.forward_scalar_into(x, rows, out, scratch);
        }
        let CascadeScratch {
            panel,
            buf_a,
            buf_b,
            ..
        } = scratch;
        let engine = BatchEngine::new(Arc::clone(&self.layers[0].plan));
        let mut cur: &mut [f32] = &mut buf_a[..rows * n];
        let mut nxt: &mut [f32] = &mut buf_b[..rows * n];
        cur.copy_from_slice(x);
        let last = self.layers.len() - 1;
        for (li, layer) in self.layers.iter().enumerate() {
            engine.acdc_rows_with_scratch(&layer.a, &layer.d, &layer.bias, cur, nxt, rows, panel);
            if let Some(perms) = &self.perms {
                // Gather the permutation back into `cur` (same column
                // gather as `apply_perm`, no allocation).
                let perm = &perms[li];
                for r in 0..rows {
                    let src = &nxt[r * n..(r + 1) * n];
                    let dst = &mut cur[r * n..(r + 1) * n];
                    for (i, &p) in perm.iter().enumerate() {
                        dst[i] = src[p as usize];
                    }
                }
            } else {
                std::mem::swap(&mut cur, &mut nxt);
            }
            if self.relu && li != last {
                for v in cur.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
        out.copy_from_slice(cur);
    }

    /// The scalar fused leg of [`AcdcCascade::forward_rows_into`]: one row
    /// rides the whole cascade while it sits in scratch (mirrors
    /// `forward_scalar` op for op, so both produce identical bits).
    fn forward_scalar_into(
        &self,
        x: &[f32],
        rows: usize,
        out: &mut [f32],
        scratch: &mut CascadeScratch,
    ) {
        let n = self.n();
        let CascadeScratch { row, tmp, fft, .. } = scratch;
        let row = &mut row[..n];
        let tmp = &mut tmp[..n];
        let fft = &mut fft[..3 * n];
        for r in 0..rows {
            row.copy_from_slice(&x[r * n..(r + 1) * n]);
            for (li, layer) in self.layers.iter().enumerate() {
                layer.forward_row_fused(row, tmp, fft);
                if let Some(perms) = &self.perms {
                    for (i, &p) in perms[li].iter().enumerate() {
                        row[i] = tmp[p as usize];
                    }
                } else {
                    row.copy_from_slice(tmp);
                }
                if self.relu && li != self.layers.len() - 1 {
                    for v in row.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            out[r * n..(r + 1) * n].copy_from_slice(row);
        }
    }

    /// Forward keeping per-layer inputs for the backward pass.
    pub fn forward_train(&self, x: &Tensor) -> (Tensor, CascadeCache) {
        self.forward_train_inner(x, None)
    }

    /// [`AcdcCascade::forward_train`] with each layer's batch sweep fanned
    /// across `pool` — the trainer's hot path. Panel ranges are disjoint,
    /// so the pooled sweep is **bit-identical** to the serial engine path
    /// (pinned by `tests/property_backward.rs`).
    pub fn forward_train_pooled(&self, x: &Tensor, pool: &ThreadPool) -> (Tensor, CascadeCache) {
        self.forward_train_inner(x, Some(pool))
    }

    fn forward_train_inner(&self, x: &Tensor, pool: Option<&ThreadPool>) -> (Tensor, CascadeCache) {
        let mut inputs = Vec::with_capacity(self.k());
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            inputs.push(h.clone());
            let mut y = match pool {
                Some(p) => layer.forward_batch_pooled(&h, p),
                None => layer.forward_batch(&h),
            };
            if let Some(perms) = &self.perms {
                y = apply_perm(&y, &perms[li]);
            }
            if self.relu && li != self.layers.len() - 1 {
                y = y.map(|v| v.max(0.0));
            }
            h = y;
        }
        (h.clone(), CascadeCache { inputs, output: h })
    }

    /// Backward through the cascade; returns ∂L/∂x and per-layer grads.
    pub fn backward(&self, cache: &CascadeCache, gy: &Tensor) -> (Tensor, Vec<AcdcGrads>) {
        let kk = self.k();
        let mut grads: Vec<Option<AcdcGrads>> = (0..kk).map(|_| None).collect();
        let mut g = gy.clone();
        for li in (0..kk).rev() {
            // Undo ReLU mask (post-perm activations feed the next layer;
            // recompute them as that layer's stored input).
            if self.relu && li != kk - 1 {
                // stored input of layer li+1 is ReLU(perm(layer li output));
                // mask where that input is 0 (inactive units).
                let act = &cache.inputs[li + 1];
                let mut masked = g.clone();
                for (mv, &av) in masked.data_mut().iter_mut().zip(act.data()) {
                    if av <= 0.0 {
                        *mv = 0.0;
                    }
                }
                g = masked;
            }
            if let Some(perms) = &self.perms {
                g = apply_perm_transpose(&g, &perms[li]);
            }
            let (gx, lg) = self.layers[li].backward(&cache.inputs[li], &g);
            grads[li] = Some(lg);
            g = gx;
        }
        (g, grads.into_iter().map(|g| g.unwrap()).collect())
    }

    /// Apply SGD to every layer (biases only when `train_bias`).
    pub fn sgd_step(&mut self, grads: &[AcdcGrads], lr: f32) {
        assert_eq!(grads.len(), self.layers.len());
        let bias_on = self.train_bias;
        for (layer, g) in self.layers.iter_mut().zip(grads) {
            for i in 0..layer.a.len() {
                layer.a[i] -= lr * g.a[i];
                layer.d[i] -= lr * g.d[i];
                if bias_on {
                    layer.bias[i] -= lr * g.bias[i];
                }
            }
        }
    }

    /// Total learnable parameters (a, d, bias per layer).
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| LinearOp::param_count(l)).sum()
    }

    /// Dense matrix this (linear) cascade represents.
    pub fn materialize(&self) -> Tensor {
        assert!(!self.relu, "materialize is only meaningful for linear cascades");
        self.forward(&Tensor::eye(self.n()))
    }
}

/// Reusable buffers for [`AcdcCascade::forward_rows_into`]: the SoA panel
/// scratch, two ping-pong `[rows, n]` activation buffers for the batched
/// leg, and the row/tmp/FFT scratch of the scalar leg. Grown on demand
/// (never shrunk), so a long-lived holder — one per serving worker —
/// allocates only until it has seen its largest batch.
#[derive(Debug)]
pub struct CascadeScratch {
    panel: PanelScratch,
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    row: Vec<f32>,
    tmp: Vec<f32>,
    fft: Vec<f32>,
    n: usize,
    rows_cap: usize,
}

impl CascadeScratch {
    /// Scratch sized for `[rows, n]` batches.
    pub fn new(n: usize, rows: usize) -> CascadeScratch {
        CascadeScratch {
            panel: PanelScratch::new(n),
            buf_a: vec![0.0; rows * n],
            buf_b: vec![0.0; rows * n],
            row: vec![0.0; n],
            tmp: vec![0.0; n],
            fft: vec![0.0; 3 * n],
            n,
            rows_cap: rows,
        }
    }

    /// Grow (never shrink) to serve `[rows, n]` batches.
    pub fn ensure(&mut self, n: usize, rows: usize) {
        self.panel.ensure(n);
        if n > self.n {
            self.row.resize(n, 0.0);
            self.tmp.resize(n, 0.0);
            self.fft.resize(3 * n, 0.0);
            self.n = n;
        }
        if rows > self.rows_cap {
            self.rows_cap = rows;
        }
        let need = self.rows_cap * self.n;
        if self.buf_a.len() < need {
            self.buf_a.resize(need, 0.0);
            self.buf_b.resize(need, 0.0);
        }
    }
}

/// Stored activations for the cascade backward pass.
#[derive(Debug, Clone)]
pub struct CascadeCache {
    /// inputs[i] = input fed to layer i.
    pub inputs: Vec<Tensor>,
    /// The cascade's final output (post-perm/ReLU of the last layer).
    pub output: Tensor,
}

/// y[:, i] = x[:, perm[i]] — gather columns (paper's incoherence perms).
pub fn apply_perm(x: &Tensor, perm: &[u32]) -> Tensor {
    let (rows, n) = (x.rows(), x.cols());
    assert_eq!(perm.len(), n);
    let mut out = Tensor::zeros(&[rows, n]);
    for r in 0..rows {
        let src = x.row(r);
        let dst = out.row_mut(r);
        for (i, &p) in perm.iter().enumerate() {
            dst[i] = src[p as usize];
        }
    }
    out
}

/// Transpose (inverse) of `apply_perm`: y[:, perm[i]] = x[:, i].
pub fn apply_perm_transpose(x: &Tensor, perm: &[u32]) -> Tensor {
    let (rows, n) = (x.rows(), x.cols());
    assert_eq!(perm.len(), n);
    let mut out = Tensor::zeros(&[rows, n]);
    for r in 0..rows {
        let src = x.row(r);
        let dst = out.row_mut(r);
        for (i, &p) in perm.iter().enumerate() {
            dst[p as usize] = src[i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sell::init::DiagInit;

    fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, 1.0))
    }

    #[test]
    fn identity_layer_is_identity() {
        let mut rng = Pcg32::seeded(1);
        let layer = AcdcLayer::identity(32);
        let x = rand_tensor(&mut rng, &[4, 32]);
        assert!(layer.forward_fused(&x).max_abs_diff(&x) < 1e-4);
    }

    #[test]
    fn batch_forward_equals_fused() {
        let mut rng = Pcg32::seeded(20);
        for n in [8usize, 64, 256] {
            let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.3);
            layer.bias = rng.normal_vec(n, 0.0, 0.2);
            for rows in [1usize, 3, 4, 9, 17] {
                let x = rand_tensor(&mut rng, &[rows, n]);
                let fused = layer.forward_fused(&x);
                let batch = layer.forward_batch(&x);
                assert!(fused.max_abs_diff(&batch) < 1e-4, "n={n} rows={rows}");
                let pool = crate::util::threadpool::ThreadPool::new(3);
                let pooled = layer.forward_batch_pooled(&x, &pool);
                assert!(fused.max_abs_diff(&pooled) < 1e-4, "n={n} rows={rows} pooled");
            }
        }
    }

    #[test]
    fn batched_backward_equals_scalar_backward() {
        // The SoA backward (rows ≥ MIN_SOA_ROWS) must agree with the
        // scalar per-row path: run each row alone (scalar) and sum.
        let mut rng = Pcg32::seeded(22);
        let n = 16;
        let rows = 9;
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.2);
        layer.bias = rng.normal_vec(n, 0.0, 0.1);
        let x = rand_tensor(&mut rng, &[rows, n]);
        let g = rand_tensor(&mut rng, &[rows, n]);
        let (gx, grads) = layer.backward(&x, &g);
        let mut want_grads = AcdcGrads::zeros(n);
        for r in 0..rows {
            let xr = Tensor::from_vec(&[1, n], x.row(r).to_vec());
            let gr = Tensor::from_vec(&[1, n], g.row(r).to_vec());
            let (gxr, lg) = layer.backward(&xr, &gr); // 1 row → scalar path
            for i in 0..n {
                want_grads.a[i] += lg.a[i];
                want_grads.d[i] += lg.d[i];
                want_grads.bias[i] += lg.bias[i];
                assert!((gx.get2(r, i) - gxr.get2(0, i)).abs() < 1e-4, "gx r={r} i={i}");
            }
        }
        for i in 0..n {
            assert!((grads.a[i] - want_grads.a[i]).abs() < 1e-3, "a[{i}]");
            assert!((grads.d[i] - want_grads.d[i]).abs() < 1e-3, "d[{i}]");
            assert!((grads.bias[i] - want_grads.bias[i]).abs() < 1e-3, "bias[{i}]");
        }
    }

    #[test]
    fn cascade_batch_equals_scalar_path() {
        let mut rng = Pcg32::seeded(21);
        let n = 32;
        let cascade = AcdcCascade::nonlinear(n, 3, DiagInit::CAFFENET, &mut rng);
        let x = rand_tensor(&mut rng, &[11, n]);
        let scalar = cascade.forward_scalar(&x);
        let batch = cascade.forward_batch(&x);
        assert!(scalar.max_abs_diff(&batch) < 1e-4);
        let pool = crate::util::threadpool::ThreadPool::new(2);
        let pooled = cascade.forward_pooled(&x, &pool);
        assert!(scalar.max_abs_diff(&pooled) < 1e-4);
    }

    #[test]
    fn forward_rows_into_is_bit_identical_to_forward() {
        // The allocation-free serving path must match the allocating
        // forward bit for bit on both the scalar (<MIN_SOA_ROWS) and the
        // batched leg, including with perms + ReLU, across scratch reuse.
        let mut rng = Pcg32::seeded(30);
        let n = 32;
        for cascade in [
            AcdcCascade::linear(n, 3, DiagInit::CAFFENET, &mut rng),
            AcdcCascade::nonlinear(n, 3, DiagInit::CAFFENET, &mut rng),
        ] {
            let mut scratch = CascadeScratch::new(n, 1);
            for rows in [1usize, 2, 3, 4, 9, 17] {
                let x = rand_tensor(&mut rng, &[rows, n]);
                let want = cascade.forward(&x);
                let mut got = vec![0.0f32; rows * n];
                cascade.forward_rows_into(x.data(), rows, &mut got, &mut scratch);
                for (g, w) in got.iter().zip(want.data()) {
                    assert_eq!(g.to_bits(), w.to_bits(), "rows={rows}");
                }
            }
        }
    }

    #[test]
    fn fused_equals_multipass() {
        let mut rng = Pcg32::seeded(2);
        for n in [8usize, 64, 256] {
            let layer = AcdcLayer::random(n, &mut rng, 1.0, 0.3);
            let x = rand_tensor(&mut rng, &[5, n]);
            let f = layer.forward_fused(&x);
            let m = layer.forward_multipass(&x);
            assert!(f.max_abs_diff(&m) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn forward_matches_naive_matrix_chain() {
        // y = x·diag(a)·C·diag(d)·Cᵀ + bias·Cᵀ, assembled densely.
        let mut rng = Pcg32::seeded(3);
        let n = 16;
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.2);
        layer.bias = rng.normal_vec(n, 0.0, 0.2);
        let c = Tensor::from_vec(&[n, n], layer.plan().matrix().to_vec());
        let mut da = Tensor::zeros(&[n, n]);
        let mut dd = Tensor::zeros(&[n, n]);
        for i in 0..n {
            da.set2(i, i, layer.a[i]);
            dd.set2(i, i, layer.d[i]);
        }
        let w = da.matmul(&c).matmul(&dd).matmul(&c.transpose());
        let x = rand_tensor(&mut rng, &[3, n]);
        let mut want = x.matmul(&w);
        // + bias·Cᵀ per row
        let bias_row = Tensor::from_vec(&[1, n], layer.bias.clone()).matmul(&c.transpose());
        for r in 0..want.rows() {
            for i in 0..n {
                let v = want.get2(r, i) + bias_row.get2(0, i);
                want.set2(r, i, v);
            }
        }
        assert!(layer.forward_fused(&x).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(4);
        let n = 8;
        let mut layer = AcdcLayer::random(n, &mut rng, 1.0, 0.2);
        layer.bias = rng.normal_vec(n, 0.0, 0.1);
        let x = rand_tensor(&mut rng, &[3, n]);
        // L = 0.5 * ||y||²  =>  g = y
        let y = layer.forward_fused(&x);
        let (gx, grads) = layer.backward(&x, &y);
        let loss = |l: &AcdcLayer, x: &Tensor| -> f64 {
            l.forward_fused(x)
                .data()
                .iter()
                .map(|v| 0.5 * (*v as f64).powi(2))
                .sum()
        };
        let eps = 1e-3;
        // check d/da, d/dd, d/dbias at a few indices
        for idx in [0usize, 3, n - 1] {
            let mut lp = layer.clone();
            lp.a[idx] += eps;
            let mut lm = layer.clone();
            lm.a[idx] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64);
            assert!(
                (grads.a[idx] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0),
                "a[{idx}]: got {} fd {}",
                grads.a[idx],
                fd
            );

            let mut lp = layer.clone();
            lp.d[idx] += eps;
            let mut lm = layer.clone();
            lm.d[idx] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64);
            assert!((grads.d[idx] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0));

            let mut lp = layer.clone();
            lp.bias[idx] += eps;
            let mut lm = layer.clone();
            lm.bias[idx] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64);
            assert!((grads.bias[idx] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0));
        }
        // check dx at one coordinate
        let mut xp = x.clone();
        let v = xp.get2(1, 2) + eps;
        xp.set2(1, 2, v);
        let mut xm = x.clone();
        let v = xm.get2(1, 2) - eps;
        xm.set2(1, 2, v);
        let fd = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps as f64);
        assert!((gx.get2(1, 2) as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0));
    }

    #[test]
    fn cascade_forward_matches_layer_composition() {
        let mut rng = Pcg32::seeded(5);
        let n = 32;
        let cascade = AcdcCascade::linear(n, 4, DiagInit::IDENTITY, &mut rng);
        let x = rand_tensor(&mut rng, &[3, n]);
        let mut want = x.clone();
        for layer in &cascade.layers {
            want = layer.forward_fused(&want);
        }
        assert!(cascade.forward(&x).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn cascade_with_perm_and_relu_matches_explicit() {
        let mut rng = Pcg32::seeded(6);
        let n = 16;
        let cascade = AcdcCascade::nonlinear(n, 3, DiagInit::IDENTITY, &mut rng);
        let x = rand_tensor(&mut rng, &[4, n]);
        let mut want = x.clone();
        for (li, layer) in cascade.layers.iter().enumerate() {
            want = layer.forward_fused(&want);
            want = apply_perm(&want, &cascade.perms.as_ref().unwrap()[li]);
            if li != cascade.layers.len() - 1 {
                want = want.map(|v| v.max(0.0));
            }
        }
        assert!(cascade.forward(&x).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn forward_train_output_matches_forward() {
        let mut rng = Pcg32::seeded(7);
        let n = 16;
        let cascade = AcdcCascade::nonlinear(n, 3, DiagInit::IDENTITY, &mut rng);
        let x = rand_tensor(&mut rng, &[4, n]);
        let (y, cache) = cascade.forward_train(&x);
        assert!(y.max_abs_diff(&cascade.forward(&x)) < 1e-4);
        assert_eq!(cache.inputs.len(), 3);
    }

    #[test]
    fn forward_train_pooled_is_bit_identical_to_serial() {
        let mut rng = Pcg32::seeded(23);
        let n = 32;
        let cascade = AcdcCascade::nonlinear(n, 3, DiagInit::CAFFENET, &mut rng);
        let pool = crate::util::threadpool::ThreadPool::new(3);
        for rows in [4usize, 9, 16, 33] {
            let x = rand_tensor(&mut rng, &[rows, n]);
            let (y_serial, cache_serial) = cascade.forward_train(&x);
            let (y_pooled, cache_pooled) = cascade.forward_train_pooled(&x, &pool);
            assert_eq!(y_serial.data().len(), y_pooled.data().len());
            for (a, b) in y_serial.data().iter().zip(y_pooled.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "rows={rows}");
            }
            for (ia, ib) in cache_serial.inputs.iter().zip(&cache_pooled.inputs) {
                for (a, b) in ia.data().iter().zip(ib.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "cache rows={rows}");
                }
            }
        }
    }

    #[test]
    fn cascade_backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(8);
        let n = 8;
        let mut cascade = AcdcCascade::linear(n, 3, DiagInit::IDENTITY, &mut rng);
        cascade.relu = true; // exercise relu masking too
        let x = rand_tensor(&mut rng, &[2, n]);
        let (y, cache) = cascade.forward_train(&x);
        let (_, grads) = cascade.backward(&cache, &y); // L = 0.5||y||²
        let loss = |c: &AcdcCascade| -> f64 {
            c.forward(&x)
                .data()
                .iter()
                .map(|v| 0.5 * (*v as f64).powi(2))
                .sum()
        };
        let eps = 1e-3;
        for li in 0..3 {
            for idx in [0usize, n / 2] {
                let mut cp = cascade.clone();
                cp.layers[li].d[idx] += eps;
                let mut cm = cascade.clone();
                cm.layers[li].d[idx] -= eps;
                let fd = (loss(&cp) - loss(&cm)) / (2.0 * eps as f64);
                let got = grads[li].d[idx] as f64;
                assert!(
                    (got - fd).abs() < 3e-2 * fd.abs().max(1.0),
                    "layer {li} d[{idx}]: got {got} fd {fd}"
                );
            }
        }
    }

    #[test]
    fn perm_roundtrip() {
        let mut rng = Pcg32::seeded(9);
        let x = rand_tensor(&mut rng, &[3, 16]);
        let p = rng.permutation(16);
        let y = apply_perm(&x, &p);
        let back = apply_perm_transpose(&y, &p);
        assert!(back.max_abs_diff(&x) < 1e-7);
    }

    #[test]
    fn sgd_step_moves_toward_target() {
        // One-layer cascade fitting a diagonalizable target must reduce loss.
        let mut rng = Pcg32::seeded(10);
        let n = 16;
        let target = AcdcLayer::random(n, &mut rng, 1.0, 0.3);
        let x = rand_tensor(&mut rng, &[64, n]);
        let y_true = target.forward_fused(&x);
        let mut model = AcdcCascade::linear(n, 1, DiagInit::IDENTITY, &mut rng);
        let mut last = f32::INFINITY;
        for step in 0..200 {
            let (y, cache) = model.forward_train(&x);
            let diff = y.sub(&y_true);
            let loss = diff.data().iter().map(|v| v * v).sum::<f32>() / x.rows() as f32;
            let mut g = diff;
            g.scale(2.0 / x.rows() as f32);
            let (_, grads) = model.backward(&cache, &g);
            model.sgd_step(&grads, 0.02);
            if step % 50 == 0 {
                assert!(loss.is_finite());
            }
            last = loss;
        }
        assert!(last < 0.05, "final loss {last}");
    }

    #[test]
    fn param_count_is_3n_per_layer() {
        let mut rng = Pcg32::seeded(11);
        let c = AcdcCascade::linear(64, 12, DiagInit::CAFFENET, &mut rng);
        assert_eq!(c.param_count(), 12 * 3 * 64);
    }
}
