//! Adaptive Fastfood SELL — Le et al. (2013) / Yang et al. (2015), eq. (4):
//! `Φ = S·H·G·P·H·B` with the three diagonals learned in the adaptive
//! variant. The Hadamard products use an in-place fast Walsh–Hadamard
//! transform (FWHT), the `H`-basis counterpart of this repo's DCT substrate.
//!
//! Batches ride the same lane-panel strategy as the batched ACDC engine
//! ([`crate::dct::batch`]): [`fwht_soa`] runs the butterfly over
//! [`crate::dct::LANES`] rows at once, and `FastfoodLayer::forward`
//! fuses the whole `S·H·G·P·H·B` chain into one pack/unpack per panel.

use super::LinearOp;
use crate::dct::batch::{lane, lane_mut};
use crate::dct::{LANES, MIN_SOA_ROWS};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// In-place fast Walsh–Hadamard transform (unnormalized).
/// Power-of-two length required.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(h * 2) {
            for i in start..start + h {
                let (a, b) = (x[i], x[i + h]);
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// Orthonormal FWHT (scales by 1/√n so the transform is orthogonal).
pub fn fwht_normalized(x: &mut [f32]) {
    let scale = 1.0 / (x.len() as f32).sqrt();
    fwht(x);
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Structure-of-arrays FWHT over a lane panel: `x[k*LANES + l]` holds
/// element `k` of lane `l` for `k < n`. Same butterfly schedule as
/// [`fwht`], with each addition applied to all [`LANES`] lanes — the
/// Hadamard counterpart of the batched DCT engine's SoA FFT.
pub fn fwht_soa(x: &mut [f32], n: usize) {
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    assert_eq!(x.len(), n * LANES);
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(h * 2) {
            for i in start..start + h {
                let (head, tail) = x.split_at_mut((i + h) * LANES);
                let a: &mut [f32; LANES] =
                    (&mut head[i * LANES..(i + 1) * LANES]).try_into().unwrap();
                let b: &mut [f32; LANES] = (&mut tail[..LANES]).try_into().unwrap();
                for l in 0..LANES {
                    let (va, vb) = (a[l], b[l]);
                    a[l] = va + vb;
                    b[l] = va - vb;
                }
            }
        }
        h *= 2;
    }
}

/// Orthonormal [`fwht_soa`] (scales by 1/√n).
pub fn fwht_soa_normalized(x: &mut [f32], n: usize) {
    fwht_soa(x, n);
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Gradients of one [`FastfoodLayer`], summed over batch rows.
#[derive(Debug, Clone)]
pub struct FastfoodGrads {
    /// ∂L/∂s.
    pub s: Vec<f32>,
    /// ∂L/∂g.
    pub g: Vec<f32>,
    /// ∂L/∂b.
    pub b: Vec<f32>,
}

/// Adaptive Fastfood layer: `y = ((((x ⊙ b)·H)[perm] ⊙ g)·H) ⊙ s`,
/// H orthonormal Hadamard, `b`, `g`, `s` learned diagonals, `perm` fixed.
#[derive(Debug, Clone)]
pub struct FastfoodLayer {
    /// Output-side scaling diagonal `S`.
    pub s: Vec<f32>,
    /// Mid-chain Gaussian diagonal `G`.
    pub g: Vec<f32>,
    /// Input-side binary diagonal `B`.
    pub b: Vec<f32>,
    /// Fixed permutation `P`.
    pub perm: Vec<u32>,
}

impl FastfoodLayer {
    /// Layer from explicit parameters (all length-n, n a power of two).
    pub fn new(s: Vec<f32>, g: Vec<f32>, b: Vec<f32>, perm: Vec<u32>) -> FastfoodLayer {
        let n = s.len();
        assert!(n.is_power_of_two());
        assert_eq!(g.len(), n);
        assert_eq!(b.len(), n);
        assert_eq!(perm.len(), n);
        FastfoodLayer { s, g, b, perm }
    }

    /// Random-initialized adaptive layer: b from ±1, g Gaussian, s
    /// Fastfood's chi-like scaling, perm uniform.
    pub fn random(n: usize, rng: &mut Pcg32) -> FastfoodLayer {
        let g = rng.normal_vec(n, 0.0, 1.0);
        let gnorm = (g.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt();
        let s = (0..n)
            .map(|_| (rng.normal().abs() / gnorm.max(1e-12)) as f32 * (n as f32).sqrt())
            .collect();
        FastfoodLayer::new(s, g, rng.sign_vec(n), rng.permutation(n))
    }

    fn forward_row(&self, x: &[f32], out: &mut [f32]) {
        let n = x.len();
        let mut buf: Vec<f32> = x.iter().zip(&self.b).map(|(&v, &b)| v * b).collect();
        fwht_normalized(&mut buf);
        // permute
        let permuted: Vec<f32> = self.perm.iter().map(|&p| buf[p as usize]).collect();
        buf.copy_from_slice(&permuted);
        for (v, &g) in buf.iter_mut().zip(&self.g) {
            *v *= g;
        }
        fwht_normalized(&mut buf);
        for i in 0..n {
            out[i] = buf[i] * self.s[i];
        }
    }

    /// One SoA lane panel through the full fused `S·H·G·P·H·B` chain:
    /// the `b` scale rides the pack, `g` rides the permutation gather,
    /// `s` rides the unpack — one load/store per panel, all butterflies
    /// over the lane dimension.
    fn forward_panel(
        &self,
        x: &[f32],
        out: &mut [f32],
        r0: usize,
        take: usize,
        buf: &mut [f32],
        buf2: &mut [f32],
    ) {
        let n = self.width();
        buf.fill(0.0);
        for l in 0..take {
            let row = &x[(r0 + l) * n..(r0 + l + 1) * n];
            for k in 0..n {
                buf[k * LANES + l] = row[k] * self.b[k];
            }
        }
        fwht_soa_normalized(buf, n);
        // P then G in one gather: buf2[k] = buf[perm[k]] · g[k] (lane-wise).
        for (k, &p) in self.perm.iter().enumerate() {
            let gk = self.g[k];
            let src = lane(buf, p as usize);
            let dst = lane_mut(buf2, k);
            for l in 0..LANES {
                dst[l] = src[l] * gk;
            }
        }
        fwht_soa_normalized(buf2, n);
        for l in 0..take {
            let row = &mut out[(r0 + l) * n..(r0 + l + 1) * n];
            for (k, v) in row.iter_mut().enumerate() {
                *v = buf2[k * LANES + l] * self.s[k];
            }
        }
    }

    /// Batched backward. Returns `(∂L/∂x, grads)` with parameter gradients
    /// summed over rows. Intermediates are recomputed (two extra FWHTs) so
    /// the forward stays allocation-free; like `forward`, small batches
    /// run per-row and larger ones ride the SoA lane panels.
    ///
    /// With `t1 = H(x ⊙ b)`, `t2 = t1[perm]`, `t4 = H(t2 ⊙ g)`,
    /// `y = t4 ⊙ s` (H symmetric orthonormal, so Hᵀ = H):
    ///   ∂L/∂s = Σ gy ⊙ t4,  gt3 = H(gy ⊙ s),  ∂L/∂g = Σ gt3 ⊙ t2,
    ///   gt1[perm[k]] = gt3[k]·g[k],  gt0 = H(gt1),
    ///   ∂L/∂b = Σ gt0 ⊙ x,  ∂L/∂x = gt0 ⊙ b.
    pub fn backward(&self, x: &Tensor, gy: &Tensor) -> (Tensor, FastfoodGrads) {
        let n = self.width();
        assert_eq!(x.cols(), n);
        assert_eq!(gy.shape(), x.shape());
        let rows = x.rows();
        let mut gx = Tensor::zeros(&[rows, n]);
        let mut acc = FastfoodGrads {
            s: vec![0.0; n],
            g: vec![0.0; n],
            b: vec![0.0; n],
        };
        if rows < MIN_SOA_ROWS {
            for r in 0..rows {
                let src = x.row(r).to_vec();
                let gyr = gy.row(r).to_vec();
                self.backward_row(&src, &gyr, gx.row_mut(r), &mut acc);
            }
            return (gx, acc);
        }
        let mut p_t2 = vec![0.0f32; n * LANES];
        let mut p_t4 = vec![0.0f32; n * LANES];
        let mut p_w = vec![0.0f32; n * LANES];
        let mut p_sc = vec![0.0f32; n * LANES];
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.backward_panel(
                x.data(),
                gy.data(),
                gx.data_mut(),
                r,
                take,
                &mut p_t2,
                &mut p_t4,
                &mut p_w,
                &mut p_sc,
                &mut acc,
            );
            r += take;
        }
        (gx, acc)
    }

    fn backward_row(&self, x: &[f32], gy: &[f32], gx: &mut [f32], acc: &mut FastfoodGrads) {
        let n = x.len();
        // Recompute the forward intermediates.
        let mut t1: Vec<f32> = x.iter().zip(&self.b).map(|(&v, &b)| v * b).collect();
        fwht_normalized(&mut t1);
        let t2: Vec<f32> = self.perm.iter().map(|&p| t1[p as usize]).collect();
        let mut t4: Vec<f32> = t2.iter().zip(&self.g).map(|(&v, &g)| v * g).collect();
        fwht_normalized(&mut t4);
        let mut w = vec![0.0f32; n];
        for k in 0..n {
            acc.s[k] += gy[k] * t4[k];
            w[k] = gy[k] * self.s[k];
        }
        fwht_normalized(&mut w); // gt3
        let mut gt1 = vec![0.0f32; n];
        for k in 0..n {
            acc.g[k] += w[k] * t2[k];
            // t2[k] = t1[perm[k]] ⇒ gt1[perm[k]] = gt3[k]·g[k]; perm is a
            // bijection, so plain assignment writes every slot exactly once.
            gt1[self.perm[k] as usize] = w[k] * self.g[k];
        }
        fwht_normalized(&mut gt1); // gt0
        for k in 0..n {
            acc.b[k] += gt1[k] * x[k];
            gx[k] = gt1[k] * self.b[k];
        }
    }

    /// SoA lane-panel backward: the same pack/gather/unpack layout as
    /// [`FastfoodLayer::forward_panel`]. Padding lanes are zero-filled on
    /// both the `x` and `gy` packs, so their contributions to the summed
    /// parameter gradients vanish through the linear chain.
    #[allow(clippy::too_many_arguments)]
    fn backward_panel(
        &self,
        x: &[f32],
        gy: &[f32],
        gx: &mut [f32],
        r0: usize,
        take: usize,
        p_t2: &mut [f32],
        p_t4: &mut [f32],
        p_w: &mut [f32],
        p_sc: &mut [f32],
        acc: &mut FastfoodGrads,
    ) {
        let n = self.width();
        // Forward recompute: p_sc holds t1, p_t2 the raw permuted copy
        // (kept un-scaled for ∂L/∂g), p_t4 the second transform.
        p_sc.fill(0.0);
        for l in 0..take {
            let row = &x[(r0 + l) * n..(r0 + l + 1) * n];
            for k in 0..n {
                p_sc[k * LANES + l] = row[k] * self.b[k];
            }
        }
        fwht_soa_normalized(p_sc, n); // t1
        for (k, &p) in self.perm.iter().enumerate() {
            let gk = self.g[k];
            let src = lane(p_sc, p as usize);
            lane_mut(p_t2, k).copy_from_slice(src);
            let dst = lane_mut(p_t4, k);
            for l in 0..LANES {
                dst[l] = src[l] * gk;
            }
        }
        fwht_soa_normalized(p_t4, n); // t4
        // Backward sweep.
        p_w.fill(0.0);
        for l in 0..take {
            let row = &gy[(r0 + l) * n..(r0 + l + 1) * n];
            for k in 0..n {
                p_w[k * LANES + l] = row[k];
            }
        }
        for k in 0..n {
            let sk = self.s[k];
            let wl = lane_mut(p_w, k);
            let t4l = lane(p_t4, k);
            let mut ssum = 0.0f32;
            for l in 0..LANES {
                ssum += wl[l] * t4l[l];
                wl[l] *= sk;
            }
            acc.s[k] += ssum;
        }
        fwht_soa_normalized(p_w, n); // gt3
        // ∂L/∂g rides the scatter: gt1[perm[k]] = gt3[k]·g[k] into p_sc
        // (t1 is dead past this point; bijection ⇒ every lane written once).
        for (k, &p) in self.perm.iter().enumerate() {
            let gk = self.g[k];
            let wl = lane(p_w, k);
            let t2l = lane(p_t2, k);
            let dst = lane_mut(p_sc, p as usize);
            let mut gsum = 0.0f32;
            for l in 0..LANES {
                gsum += wl[l] * t2l[l];
                dst[l] = wl[l] * gk;
            }
            acc.g[k] += gsum;
        }
        fwht_soa_normalized(p_sc, n); // gt0
        for l in 0..take {
            let xrow = &x[(r0 + l) * n..(r0 + l + 1) * n];
            let gxrow = &mut gx[(r0 + l) * n..(r0 + l + 1) * n];
            for k in 0..n {
                let g0 = p_sc[k * LANES + l];
                acc.b[k] += g0 * xrow[k];
                gxrow[k] = g0 * self.b[k];
            }
        }
    }
}

impl LinearOp for FastfoodLayer {
    fn width(&self) -> usize {
        self.s.len()
    }

    fn param_count(&self) -> usize {
        3 * self.s.len() // s, g, b learned in the adaptive variant
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let n = self.width();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        let mut out = Tensor::zeros(&[rows, n]);
        if rows < MIN_SOA_ROWS {
            for r in 0..rows {
                let src = x.row(r).to_vec();
                self.forward_row(&src, out.row_mut(r));
            }
            return out;
        }
        // Lane-panel SoA path (same batching strategy as dct::batch).
        let mut buf = vec![0.0f32; n * LANES];
        let mut buf2 = vec![0.0f32; n * LANES];
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.forward_panel(x.data(), out.data_mut(), r, take, &mut buf, &mut buf2);
            r += take;
        }
        out
    }

    fn name(&self) -> &'static str {
        "fastfood"
    }
}

/// Naive O(N²) Hadamard matrix (orthonormal), H[i,j] = (-1)^{popcount(i&j)}/√n.
pub fn hadamard_matrix(n: usize) -> Tensor {
    assert!(n.is_power_of_two());
    let scale = 1.0 / (n as f32).sqrt();
    let mut h = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            h.set2(i, j, sign * scale);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_matches_matrix() {
        let mut rng = Pcg32::seeded(1);
        for n in [2usize, 8, 32] {
            let x = rng.normal_vec(n, 0.0, 1.0);
            let h = hadamard_matrix(n);
            let want = Tensor::from_vec(&[1, n], x.clone()).matmul(&h);
            let mut got = x;
            fwht_normalized(&mut got);
            for i in 0..n {
                assert!((got[i] - want.data()[i]).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn fwht_involution() {
        // Orthonormal FWHT is its own inverse.
        let mut rng = Pcg32::seeded(2);
        let n = 64;
        let x0 = rng.normal_vec(n, 0.0, 1.0);
        let mut x = x0.clone();
        fwht_normalized(&mut x);
        fwht_normalized(&mut x);
        for i in 0..n {
            assert!((x[i] - x0[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn hadamard_is_orthogonal() {
        let n = 16;
        let h = hadamard_matrix(n);
        let prod = h.matmul(&h.transpose());
        assert!(prod.max_abs_diff(&Tensor::eye(n)) < 1e-5);
    }

    #[test]
    fn forward_matches_explicit_matrix_chain() {
        let mut rng = Pcg32::seeded(3);
        let n = 16;
        let layer = FastfoodLayer::random(n, &mut rng);
        let h = hadamard_matrix(n);
        // dense chain: diag(b)·H·Pᵀ·diag(g)·H·diag(s) acting on row vectors
        let mut db = Tensor::zeros(&[n, n]);
        let mut dg = Tensor::zeros(&[n, n]);
        let mut ds = Tensor::zeros(&[n, n]);
        let mut p = Tensor::zeros(&[n, n]);
        for i in 0..n {
            db.set2(i, i, layer.b[i]);
            dg.set2(i, i, layer.g[i]);
            ds.set2(i, i, layer.s[i]);
            // row-gather perm as matrix: y_i = x_{perm[i]} => P[perm[i], i] = 1
            p.set2(layer.perm[i] as usize, i, 1.0);
        }
        let chain = db.matmul(&h).matmul(&p).matmul(&dg).matmul(&h).matmul(&ds);
        let x = Tensor::from_vec(&[2, n], rng.normal_vec(2 * n, 0.0, 1.0));
        let want = x.matmul(&chain);
        let got = layer.forward(&x);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn param_count_is_3n() {
        let mut rng = Pcg32::seeded(4);
        assert_eq!(FastfoodLayer::random(64, &mut rng).param_count(), 192);
    }

    #[test]
    fn linear_in_x() {
        let mut rng = Pcg32::seeded(5);
        let n = 32;
        let layer = FastfoodLayer::random(n, &mut rng);
        let x1 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let x2 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let lhs = layer.forward(&x1.add(&x2));
        let rhs = layer.forward(&x1).add(&layer.forward(&x2));
        assert!(lhs.max_abs_diff(&rhs) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn fwht_rejects_non_pow2() {
        let mut x = vec![0.0; 12];
        fwht(&mut x);
    }

    #[test]
    fn soa_fwht_matches_scalar_per_lane() {
        let mut rng = Pcg32::seeded(6);
        for n in [1usize, 2, 16, 64] {
            let rows: Vec<Vec<f32>> = (0..LANES).map(|_| rng.normal_vec(n, 0.0, 1.0)).collect();
            let mut soa = vec![0.0f32; n * LANES];
            for (l, row) in rows.iter().enumerate() {
                for k in 0..n {
                    soa[k * LANES + l] = row[k];
                }
            }
            fwht_soa_normalized(&mut soa, n);
            for (l, row) in rows.iter().enumerate() {
                let mut want = row.clone();
                fwht_normalized(&mut want);
                for k in 0..n {
                    assert!((soa[k * LANES + l] - want[k]).abs() < 1e-4, "n={n} l={l} k={k}");
                }
            }
        }
    }

    #[test]
    fn backward_panel_matches_per_row() {
        let mut rng = Pcg32::seeded(8);
        for n in [8usize, 32] {
            let layer = FastfoodLayer::random(n, &mut rng);
            for rows in [4usize, 9, 17] {
                let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
                let gy = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
                let (gx_p, acc_p) = layer.backward(&x, &gy); // rows ≥ MIN_SOA_ROWS → panels
                let mut gx_s = Tensor::zeros(&[rows, n]);
                let mut acc_s = FastfoodGrads {
                    s: vec![0.0; n],
                    g: vec![0.0; n],
                    b: vec![0.0; n],
                };
                for r in 0..rows {
                    let (src, gyr) = (x.row(r).to_vec(), gy.row(r).to_vec());
                    layer.backward_row(&src, &gyr, gx_s.row_mut(r), &mut acc_s);
                }
                assert!(gx_p.max_abs_diff(&gx_s) < 1e-4, "n={n} rows={rows} gx");
                for k in 0..n {
                    assert!((acc_p.s[k] - acc_s.s[k]).abs() < 1e-3, "n={n} rows={rows} s[{k}]");
                    assert!((acc_p.g[k] - acc_s.g[k]).abs() < 1e-3, "n={n} rows={rows} g[{k}]");
                    assert!((acc_p.b[k] - acc_s.b[k]).abs() < 1e-3, "n={n} rows={rows} b[{k}]");
                }
            }
        }
    }

    #[test]
    fn backward_matches_dense_chain_gradients() {
        // The dense chain M = diag(b)·H·P·diag(g)·H·diag(s) gives closed-form
        // gradients for L = ½Σy²: gx = gy·Mᵀ with gy = y = x·M.
        let mut rng = Pcg32::seeded(9);
        let n = 16;
        let layer = FastfoodLayer::random(n, &mut rng);
        let h = hadamard_matrix(n);
        let mut db = Tensor::zeros(&[n, n]);
        let mut dg = Tensor::zeros(&[n, n]);
        let mut ds = Tensor::zeros(&[n, n]);
        let mut p = Tensor::zeros(&[n, n]);
        for i in 0..n {
            db.set2(i, i, layer.b[i]);
            dg.set2(i, i, layer.g[i]);
            ds.set2(i, i, layer.s[i]);
            p.set2(layer.perm[i] as usize, i, 1.0);
        }
        let chain = db.matmul(&h).matmul(&p).matmul(&dg).matmul(&h).matmul(&ds);
        let x = Tensor::from_vec(&[5, n], rng.normal_vec(5 * n, 0.0, 1.0));
        let y = layer.forward(&x);
        let (gx, _) = layer.backward(&x, &y);
        let want = y.matmul(&chain.transpose());
        assert!(gx.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn batched_forward_matches_per_row() {
        let mut rng = Pcg32::seeded(7);
        for n in [8usize, 32] {
            let layer = FastfoodLayer::random(n, &mut rng);
            for rows in [4usize, 9, 17] {
                let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
                let batched = layer.forward(&x); // rows ≥ MIN_SOA_ROWS → panel path
                for r in 0..rows {
                    let mut want = vec![0.0f32; n];
                    layer.forward_row(x.row(r), &mut want);
                    for k in 0..n {
                        assert!(
                            (batched.get2(r, k) - want[k]).abs() < 1e-4,
                            "n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
            }
        }
    }
}
