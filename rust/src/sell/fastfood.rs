//! Adaptive Fastfood SELL — Le et al. (2013) / Yang et al. (2015), eq. (4):
//! `Φ = S·H·G·P·H·B` with the three diagonals learned in the adaptive
//! variant. The Hadamard products use an in-place fast Walsh–Hadamard
//! transform (FWHT), the `H`-basis counterpart of this repo's DCT substrate.
//!
//! Batches ride the same lane-panel strategy as the batched ACDC engine
//! ([`crate::dct::batch`]): [`fwht_soa`] runs the butterfly over
//! [`crate::dct::LANES`] rows at once, and `FastfoodLayer::forward`
//! fuses the whole `S·H·G·P·H·B` chain into one pack/unpack per panel.

use super::LinearOp;
use crate::dct::batch::{lane, lane_mut};
use crate::dct::{LANES, MIN_SOA_ROWS};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// In-place fast Walsh–Hadamard transform (unnormalized).
/// Power-of-two length required.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(h * 2) {
            for i in start..start + h {
                let (a, b) = (x[i], x[i + h]);
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// Orthonormal FWHT (scales by 1/√n so the transform is orthogonal).
pub fn fwht_normalized(x: &mut [f32]) {
    let scale = 1.0 / (x.len() as f32).sqrt();
    fwht(x);
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Structure-of-arrays FWHT over a lane panel: `x[k*LANES + l]` holds
/// element `k` of lane `l` for `k < n`. Same butterfly schedule as
/// [`fwht`], with each addition applied to all [`LANES`] lanes — the
/// Hadamard counterpart of the batched DCT engine's SoA FFT.
pub fn fwht_soa(x: &mut [f32], n: usize) {
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    assert_eq!(x.len(), n * LANES);
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(h * 2) {
            for i in start..start + h {
                let (head, tail) = x.split_at_mut((i + h) * LANES);
                let a: &mut [f32; LANES] =
                    (&mut head[i * LANES..(i + 1) * LANES]).try_into().unwrap();
                let b: &mut [f32; LANES] = (&mut tail[..LANES]).try_into().unwrap();
                for l in 0..LANES {
                    let (va, vb) = (a[l], b[l]);
                    a[l] = va + vb;
                    b[l] = va - vb;
                }
            }
        }
        h *= 2;
    }
}

/// Orthonormal [`fwht_soa`] (scales by 1/√n).
pub fn fwht_soa_normalized(x: &mut [f32], n: usize) {
    fwht_soa(x, n);
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Adaptive Fastfood layer: `y = ((((x ⊙ b)·H)[perm] ⊙ g)·H) ⊙ s`,
/// H orthonormal Hadamard, `b`, `g`, `s` learned diagonals, `perm` fixed.
#[derive(Debug, Clone)]
pub struct FastfoodLayer {
    /// Output-side scaling diagonal `S`.
    pub s: Vec<f32>,
    /// Mid-chain Gaussian diagonal `G`.
    pub g: Vec<f32>,
    /// Input-side binary diagonal `B`.
    pub b: Vec<f32>,
    /// Fixed permutation `P`.
    pub perm: Vec<u32>,
}

impl FastfoodLayer {
    /// Layer from explicit parameters (all length-n, n a power of two).
    pub fn new(s: Vec<f32>, g: Vec<f32>, b: Vec<f32>, perm: Vec<u32>) -> FastfoodLayer {
        let n = s.len();
        assert!(n.is_power_of_two());
        assert_eq!(g.len(), n);
        assert_eq!(b.len(), n);
        assert_eq!(perm.len(), n);
        FastfoodLayer { s, g, b, perm }
    }

    /// Random-initialized adaptive layer: b from ±1, g Gaussian, s
    /// Fastfood's chi-like scaling, perm uniform.
    pub fn random(n: usize, rng: &mut Pcg32) -> FastfoodLayer {
        let g = rng.normal_vec(n, 0.0, 1.0);
        let gnorm = (g.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt();
        let s = (0..n)
            .map(|_| (rng.normal().abs() / gnorm.max(1e-12)) as f32 * (n as f32).sqrt())
            .collect();
        FastfoodLayer::new(s, g, rng.sign_vec(n), rng.permutation(n))
    }

    fn forward_row(&self, x: &[f32], out: &mut [f32]) {
        let n = x.len();
        let mut buf: Vec<f32> = x.iter().zip(&self.b).map(|(&v, &b)| v * b).collect();
        fwht_normalized(&mut buf);
        // permute
        let permuted: Vec<f32> = self.perm.iter().map(|&p| buf[p as usize]).collect();
        buf.copy_from_slice(&permuted);
        for (v, &g) in buf.iter_mut().zip(&self.g) {
            *v *= g;
        }
        fwht_normalized(&mut buf);
        for i in 0..n {
            out[i] = buf[i] * self.s[i];
        }
    }

    /// One SoA lane panel through the full fused `S·H·G·P·H·B` chain:
    /// the `b` scale rides the pack, `g` rides the permutation gather,
    /// `s` rides the unpack — one load/store per panel, all butterflies
    /// over the lane dimension.
    fn forward_panel(
        &self,
        x: &[f32],
        out: &mut [f32],
        r0: usize,
        take: usize,
        buf: &mut [f32],
        buf2: &mut [f32],
    ) {
        let n = self.width();
        buf.fill(0.0);
        for l in 0..take {
            let row = &x[(r0 + l) * n..(r0 + l + 1) * n];
            for k in 0..n {
                buf[k * LANES + l] = row[k] * self.b[k];
            }
        }
        fwht_soa_normalized(buf, n);
        // P then G in one gather: buf2[k] = buf[perm[k]] · g[k] (lane-wise).
        for (k, &p) in self.perm.iter().enumerate() {
            let gk = self.g[k];
            let src = lane(buf, p as usize);
            let dst = lane_mut(buf2, k);
            for l in 0..LANES {
                dst[l] = src[l] * gk;
            }
        }
        fwht_soa_normalized(buf2, n);
        for l in 0..take {
            let row = &mut out[(r0 + l) * n..(r0 + l + 1) * n];
            for (k, v) in row.iter_mut().enumerate() {
                *v = buf2[k * LANES + l] * self.s[k];
            }
        }
    }
}

impl LinearOp for FastfoodLayer {
    fn width(&self) -> usize {
        self.s.len()
    }

    fn param_count(&self) -> usize {
        3 * self.s.len() // s, g, b learned in the adaptive variant
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let n = self.width();
        assert_eq!(x.cols(), n);
        let rows = x.rows();
        let mut out = Tensor::zeros(&[rows, n]);
        if rows < MIN_SOA_ROWS {
            for r in 0..rows {
                let src = x.row(r).to_vec();
                self.forward_row(&src, out.row_mut(r));
            }
            return out;
        }
        // Lane-panel SoA path (same batching strategy as dct::batch).
        let mut buf = vec![0.0f32; n * LANES];
        let mut buf2 = vec![0.0f32; n * LANES];
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.forward_panel(x.data(), out.data_mut(), r, take, &mut buf, &mut buf2);
            r += take;
        }
        out
    }

    fn name(&self) -> &'static str {
        "fastfood"
    }
}

/// Naive O(N²) Hadamard matrix (orthonormal), H[i,j] = (-1)^{popcount(i&j)}/√n.
pub fn hadamard_matrix(n: usize) -> Tensor {
    assert!(n.is_power_of_two());
    let scale = 1.0 / (n as f32).sqrt();
    let mut h = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            h.set2(i, j, sign * scale);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_matches_matrix() {
        let mut rng = Pcg32::seeded(1);
        for n in [2usize, 8, 32] {
            let x = rng.normal_vec(n, 0.0, 1.0);
            let h = hadamard_matrix(n);
            let want = Tensor::from_vec(&[1, n], x.clone()).matmul(&h);
            let mut got = x;
            fwht_normalized(&mut got);
            for i in 0..n {
                assert!((got[i] - want.data()[i]).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn fwht_involution() {
        // Orthonormal FWHT is its own inverse.
        let mut rng = Pcg32::seeded(2);
        let n = 64;
        let x0 = rng.normal_vec(n, 0.0, 1.0);
        let mut x = x0.clone();
        fwht_normalized(&mut x);
        fwht_normalized(&mut x);
        for i in 0..n {
            assert!((x[i] - x0[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn hadamard_is_orthogonal() {
        let n = 16;
        let h = hadamard_matrix(n);
        let prod = h.matmul(&h.transpose());
        assert!(prod.max_abs_diff(&Tensor::eye(n)) < 1e-5);
    }

    #[test]
    fn forward_matches_explicit_matrix_chain() {
        let mut rng = Pcg32::seeded(3);
        let n = 16;
        let layer = FastfoodLayer::random(n, &mut rng);
        let h = hadamard_matrix(n);
        // dense chain: diag(b)·H·Pᵀ·diag(g)·H·diag(s) acting on row vectors
        let mut db = Tensor::zeros(&[n, n]);
        let mut dg = Tensor::zeros(&[n, n]);
        let mut ds = Tensor::zeros(&[n, n]);
        let mut p = Tensor::zeros(&[n, n]);
        for i in 0..n {
            db.set2(i, i, layer.b[i]);
            dg.set2(i, i, layer.g[i]);
            ds.set2(i, i, layer.s[i]);
            // row-gather perm as matrix: y_i = x_{perm[i]} => P[perm[i], i] = 1
            p.set2(layer.perm[i] as usize, i, 1.0);
        }
        let chain = db.matmul(&h).matmul(&p).matmul(&dg).matmul(&h).matmul(&ds);
        let x = Tensor::from_vec(&[2, n], rng.normal_vec(2 * n, 0.0, 1.0));
        let want = x.matmul(&chain);
        let got = layer.forward(&x);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn param_count_is_3n() {
        let mut rng = Pcg32::seeded(4);
        assert_eq!(FastfoodLayer::random(64, &mut rng).param_count(), 192);
    }

    #[test]
    fn linear_in_x() {
        let mut rng = Pcg32::seeded(5);
        let n = 32;
        let layer = FastfoodLayer::random(n, &mut rng);
        let x1 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let x2 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let lhs = layer.forward(&x1.add(&x2));
        let rhs = layer.forward(&x1).add(&layer.forward(&x2));
        assert!(lhs.max_abs_diff(&rhs) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn fwht_rejects_non_pow2() {
        let mut x = vec![0.0; 12];
        fwht(&mut x);
    }

    #[test]
    fn soa_fwht_matches_scalar_per_lane() {
        let mut rng = Pcg32::seeded(6);
        for n in [1usize, 2, 16, 64] {
            let rows: Vec<Vec<f32>> = (0..LANES).map(|_| rng.normal_vec(n, 0.0, 1.0)).collect();
            let mut soa = vec![0.0f32; n * LANES];
            for (l, row) in rows.iter().enumerate() {
                for k in 0..n {
                    soa[k * LANES + l] = row[k];
                }
            }
            fwht_soa_normalized(&mut soa, n);
            for (l, row) in rows.iter().enumerate() {
                let mut want = row.clone();
                fwht_normalized(&mut want);
                for k in 0..n {
                    assert!((soa[k * LANES + l] - want[k]).abs() < 1e-4, "n={n} l={l} k={k}");
                }
            }
        }
    }

    #[test]
    fn batched_forward_matches_per_row() {
        let mut rng = Pcg32::seeded(7);
        for n in [8usize, 32] {
            let layer = FastfoodLayer::random(n, &mut rng);
            for rows in [4usize, 9, 17] {
                let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
                let batched = layer.forward(&x); // rows ≥ MIN_SOA_ROWS → panel path
                for r in 0..rows {
                    let mut want = vec![0.0f32; n];
                    layer.forward_row(x.row(r), &mut want);
                    for k in 0..n {
                        assert!(
                            (batched.get2(r, k) - want[k]).abs() < 1e-4,
                            "n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
            }
        }
    }
}
