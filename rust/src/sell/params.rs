//! Parameter audits for Table 1 / Figure 4.
//!
//! Table 1's claim decomposes into (a) parameter arithmetic — exact and
//! reproducible at full CaffeNet scale, done here — and (b) accuracy
//! deltas, measured at MiniCaffeNet scale by the training harness
//! (DESIGN.md substitution S2). This module computes (a) from first
//! principles and carries the paper's published numbers alongside, so the
//! Table-1 bench can print `paper vs computed` for every row.

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Method name as printed in the paper.
    pub method: &'static str,
    /// Published top-1 error increase (percentage points).
    pub err_increase_pct: f64,
    /// Published parameter count of the whole model (None if not reported).
    pub published_params: Option<u64>,
    /// Published reduction factor ("x6.0").
    pub published_reduction: f64,
    /// Our from-first-principles parameter count (None where the method is
    /// a post-processing pipeline we only audit, substitution S4).
    pub computed_params: Option<u64>,
    /// True when the row's backbone is VGG16, not CaffeNet (starred in the
    /// paper — not directly comparable).
    pub vgg16: bool,
    /// Train-time applicable (SELL family) vs post-processing.
    pub train_time: bool,
}

/// CaffeNet (AlexNet-style) layer shapes.
///
/// conv: (out_ch, in_ch, kh, kw), fc: (in, out). Biases included.
pub mod caffenet {
    /// conv1..conv5 of CaffeNet.
    pub const CONVS: [(u64, u64, u64, u64); 5] = [
        (96, 3, 11, 11),
        (256, 48, 5, 5), // grouped conv (2 groups): in_ch = 96/2
        (384, 256, 3, 3),
        (384, 192, 3, 3), // grouped
        (256, 192, 3, 3), // grouped
    ];
    /// fc6 (in, out).
    pub const FC6: (u64, u64) = (9216, 4096);
    /// fc7 (in, out).
    pub const FC7: (u64, u64) = (4096, 4096);
    /// fc8 / classifier (in, out).
    pub const FC8: (u64, u64) = (4096, 1000);

    /// Width of the paper's ACDC stack replacing fc6/fc7. The paper's
    /// "combined 165,888 parameters" for 12 layers implies 3N·12 = 165,888
    /// → N = 4608 (the pooled conv5 features are reduced 9216→4608).
    pub const ACDC_WIDTH: u64 = 4608;
    /// Depth of the paper's ACDC stack.
    pub const ACDC_LAYERS: u64 = 12;

    /// Parameters of conv1..conv5 (biases included).
    pub fn conv_params() -> u64 {
        CONVS
            .iter()
            .map(|&(o, i, kh, kw)| o * i * kh * kw + o)
            .sum()
    }

    /// Parameters of fc6 + fc7 + fc8 (biases included).
    pub fn fc_params() -> u64 {
        let (i6, o6) = FC6;
        let (i7, o7) = FC7;
        let (i8, o8) = FC8;
        (i6 * o6 + o6) + (i7 * o7 + o7) + (i8 * o8 + o8)
    }

    /// Whole-model parameter count.
    pub fn total_params() -> u64 {
        conv_params() + fc_params()
    }
}

/// Parameters of a K-layer ACDC stack of width n with bias on D (§6.2).
pub fn acdc_stack_params(n: u64, k: u64) -> u64 {
    k * 3 * n // a + d + bias per layer
}

/// Parameters of an adaptive-Fastfood stack (3 diagonals per layer).
pub fn fastfood_stack_params(n: u64, k: u64) -> u64 {
    k * 3 * n
}

/// Parameters of a circulant layer (r learned, signs fixed).
pub fn circulant_params(n: u64) -> u64 {
    n
}

/// Parameters of a rank-r factorization of an [n_in, n_out] layer.
pub fn lowrank_params(n_in: u64, n_out: u64, rank: u64) -> u64 {
    rank * (n_in + n_out)
}

/// The paper's ACDC CaffeNet variant, computed from first principles:
/// convs + 12-layer ACDC stack at N=4608 + dense classifier from 4608.
pub fn acdc_caffenet_params() -> u64 {
    let cls = caffenet::ACDC_WIDTH * 1000 + 1000;
    caffenet::conv_params()
        + acdc_stack_params(caffenet::ACDC_WIDTH, caffenet::ACDC_LAYERS)
        + cls
}

/// All rows of Table 1, published numbers transcribed from the paper and
/// computed numbers derived here where the method is in-scope.
pub fn table1_rows() -> Vec<Table1Row> {
    let reference = caffenet::total_params();
    vec![
        Table1Row {
            method: "Collins & Kohli (2014)",
            err_increase_pct: 1.81,
            published_params: Some(15_200_000),
            published_reduction: 4.0,
            computed_params: None,
            vgg16: false,
            train_time: false,
        },
        Table1Row {
            method: "Han et al. (2015b)",
            err_increase_pct: 0.00,
            published_params: Some(6_700_000),
            published_reduction: 9.0,
            computed_params: None,
            vgg16: false,
            train_time: false,
        },
        Table1Row {
            method: "Han et al. (2015a) (P+Q)",
            err_increase_pct: 0.00,
            published_params: Some(2_300_000),
            published_reduction: 27.0,
            computed_params: None,
            vgg16: false,
            train_time: false,
        },
        Table1Row {
            method: "Cheng et al. (2015) (Circulant CNN 2)",
            err_increase_pct: 0.40,
            published_params: Some(16_300_000),
            published_reduction: 3.8,
            // convs + circulant fc6 (9216, needs projection) — audit the
            // dominant fc replacement: circulant needs N params per layer.
            computed_params: Some(
                caffenet::conv_params()
                    + circulant_params(caffenet::FC6.0)
                    + circulant_params(caffenet::FC7.0)
                    + caffenet::FC8.0 * 1000
                    + 1000
                    + 12_000_000, // the conv5 interface and remaining dense parts they retain
            ),
            vgg16: false,
            train_time: true,
        },
        Table1Row {
            method: "Novikov et al. (2015) (TT4 FC FC)",
            err_increase_pct: 0.30,
            published_params: None,
            published_reduction: 3.9,
            computed_params: None,
            vgg16: true,
            train_time: true,
        },
        Table1Row {
            method: "Novikov et al. (2015) (TT4 TT4 FC)",
            err_increase_pct: 1.30,
            published_params: None,
            published_reduction: 7.4,
            computed_params: None,
            vgg16: true,
            train_time: true,
        },
        Table1Row {
            method: "Yang et al. (2015) (Finetuned SVD 1)",
            err_increase_pct: 0.14,
            published_params: Some(46_600_000),
            published_reduction: 1.3,
            computed_params: Some(
                caffenet::conv_params()
                    + lowrank_params(caffenet::FC6.0, caffenet::FC6.1, 1024)
                    + lowrank_params(caffenet::FC7.0, caffenet::FC7.1, 1024)
                    + caffenet::FC8.0 * caffenet::FC8.1
                    + caffenet::FC8.1
                    + 25_000_000, // their SVD-1 keeps fc6 dense; approximation noted in EXPERIMENTS.md
            ),
            vgg16: false,
            train_time: true,
        },
        Table1Row {
            method: "Yang et al. (2015) (Finetuned SVD 2)",
            err_increase_pct: 1.22,
            published_params: Some(23_400_000),
            published_reduction: 2.0,
            computed_params: None,
            vgg16: false,
            train_time: true,
        },
        Table1Row {
            method: "Yang et al. (2015) (Adaptive Fastfood 16)",
            err_increase_pct: 0.30,
            published_params: Some(16_400_000),
            published_reduction: 3.6,
            computed_params: None,
            vgg16: false,
            train_time: true,
        },
        Table1Row {
            method: "ACDC (this paper)",
            err_increase_pct: 0.67,
            published_params: Some(9_700_000),
            published_reduction: 6.0,
            computed_params: Some(acdc_caffenet_params()),
            vgg16: false,
            train_time: true,
        },
        Table1Row {
            method: "CaffeNet Reference Model",
            err_increase_pct: 0.00,
            published_params: Some(58_700_000),
            published_reduction: 1.0,
            computed_params: Some(reference),
            vgg16: false,
            train_time: false,
        },
    ]
}

/// MiniCaffeNet (the measured S2 substitution) parameter audit, matching
/// `python/compile/model.py` exactly.
pub mod mini {
    /// FC-block width.
    pub const N_FEAT: u64 = 256;
    /// ACDC stack depth.
    pub const K: u64 = 12;
    /// Classifier classes.
    pub const N_CLASSES: u64 = 10;

    /// Conv feature-extractor parameters.
    pub fn conv_params() -> u64 {
        (5 * 5 * 1 * 8 + 8) + (3 * 3 * 8 * 16 + 16)
    }

    /// Dense FC-block parameters (the reference variant).
    pub fn dense_fc_params() -> u64 {
        2 * (N_FEAT * N_FEAT + N_FEAT)
    }

    /// ACDC FC-block parameters (the compressed variant).
    pub fn acdc_fc_params() -> u64 {
        super::acdc_stack_params(N_FEAT, K)
    }

    /// Classifier head parameters.
    pub fn classifier_params() -> u64 {
        N_FEAT * N_CLASSES + N_CLASSES
    }

    /// Whole-model parameters, dense variant.
    pub fn dense_total() -> u64 {
        conv_params() + dense_fc_params() + classifier_params()
    }

    /// Whole-model parameters, ACDC variant.
    pub fn acdc_total() -> u64 {
        conv_params() + acdc_fc_params() + classifier_params()
    }

    /// dense/ACDC parameter ratio (the Table-1 headline).
    pub fn reduction() -> f64 {
        dense_total() as f64 / acdc_total() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acdc_stack_matches_papers_165888() {
        // The paper: "SELL modules which contain a combined 165,888
        // parameters" for the 12-layer stack.
        assert_eq!(
            acdc_stack_params(caffenet::ACDC_WIDTH, caffenet::ACDC_LAYERS),
            165_888
        );
    }

    #[test]
    fn caffenet_fc_layers_over_41m() {
        // Paper: "The two fully connected layers of CaffeNet, consisting of
        // more than 41 million parameters".
        let (i6, o6) = caffenet::FC6;
        let (i7, o7) = caffenet::FC7;
        let fc67 = i6 * o6 + o6 + i7 * o7 + o7;
        assert!(fc67 > 41_000_000, "fc6+fc7 = {fc67}");
        assert!(fc67 < 56_000_000);
    }

    #[test]
    fn caffenet_total_near_published() {
        // Published 58.7M markets the weight count; our bias-inclusive
        // audit should land within ~6% of it.
        let total = caffenet::total_params();
        let published = 58_700_000u64;
        let rel = (total as f64 - published as f64).abs() / published as f64;
        assert!(rel < 0.06, "total={total} rel={rel}");
    }

    #[test]
    fn acdc_model_reduction_close_to_6x() {
        let red = caffenet::total_params() as f64 / acdc_caffenet_params() as f64;
        // Paper reports x6.0 vs its 9.7M; our classifier-from-4608 audit
        // gives a somewhat *smaller* model, so the computed reduction can
        // only be >= ~5.5.
        assert!(red > 5.0, "reduction={red}");
        assert!(red < 12.0, "reduction={red}");
    }

    #[test]
    fn table1_has_all_eleven_rows() {
        let rows = table1_rows();
        assert_eq!(rows.len(), 11);
        assert!(rows.iter().any(|r| r.method.starts_with("ACDC")));
        assert_eq!(rows.iter().filter(|r| r.vgg16).count(), 2);
    }

    #[test]
    fn acdc_row_reduction_consistent_with_published_params() {
        let rows = table1_rows();
        let acdc = rows.iter().find(|r| r.method.starts_with("ACDC")).unwrap();
        let reference = rows
            .iter()
            .find(|r| r.method.starts_with("CaffeNet"))
            .unwrap();
        let implied = reference.published_params.unwrap() as f64
            / acdc.published_params.unwrap() as f64;
        assert!((implied - acdc.published_reduction).abs() < 0.1);
    }

    #[test]
    fn mini_reduction_over_5x() {
        // The MiniCaffeNet swap must exhibit the Table-1 effect.
        assert!(mini::reduction() > 5.0, "reduction={}", mini::reduction());
        assert_eq!(mini::acdc_fc_params(), 9_216);
        assert_eq!(mini::dense_fc_params(), 131_584);
    }

    #[test]
    fn lowrank_param_formula() {
        assert_eq!(lowrank_params(9216, 4096, 1024), 1024 * (9216 + 4096));
    }
}
