//! Initialization strategies for diagonal parameters (paper §6).
//!
//! Figure 3's central finding: deep cascades only train when the diagonals
//! start near the identity — `N(1, σ²)` with σ ≈ 1e-1 — while the
//! "standard" near-zero linear-layer init (`N(0, σ²)`, σ ≈ 1e-3) stalls as
//! depth grows. §6.2's ImageNet run uses `N(1, 0.061)`.

use crate::util::rng::Pcg32;

/// A named diagonal-initialization scheme.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiagInit {
    /// Mean of the Gaussian draw.
    pub mean: f64,
    /// Standard deviation of the Gaussian draw.
    pub sigma: f64,
}

impl DiagInit {
    /// Figure 3 (left): identity-plus-noise, the init that works.
    pub const IDENTITY: DiagInit = DiagInit {
        mean: 1.0,
        sigma: 0.1,
    };

    /// Figure 3 (right): standard near-zero init, fails for deep cascades.
    pub const STANDARD: DiagInit = DiagInit {
        mean: 0.0,
        sigma: 1e-3,
    };

    /// §6.2 CaffeNet experiment: N(1, 0.061).
    pub const CAFFENET: DiagInit = DiagInit {
        mean: 1.0,
        sigma: 0.061,
    };

    /// Draw a diagonal of the given length.
    pub fn sample(&self, n: usize, rng: &mut Pcg32) -> Vec<f32> {
        rng.normal_vec(n, self.mean, self.sigma)
    }

    /// Figure-3-style label, e.g. `N(1, 1e-2)`.
    pub fn label(&self) -> String {
        format!("N({}, {:.0e})", self.mean, self.sigma * self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_init_centers_at_one() {
        let mut rng = Pcg32::seeded(1);
        let v = DiagInit::IDENTITY.sample(20_000, &mut rng);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!((mean - 1.0).abs() < 0.01);
    }

    #[test]
    fn standard_init_centers_at_zero() {
        let mut rng = Pcg32::seeded(2);
        let v = DiagInit::STANDARD.sample(20_000, &mut rng);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 1e-4);
    }

    #[test]
    fn caffenet_sigma_matches_paper() {
        assert_eq!(DiagInit::CAFFENET.sigma, 0.061);
        assert_eq!(DiagInit::CAFFENET.mean, 1.0);
    }

    #[test]
    fn sample_is_deterministic_in_seed() {
        let a = DiagInit::IDENTITY.sample(16, &mut Pcg32::seeded(7));
        let b = DiagInit::IDENTITY.sample(16, &mut Pcg32::seeded(7));
        assert_eq!(a, b);
    }

    #[test]
    fn labels_render() {
        assert!(DiagInit::IDENTITY.label().contains("N(1"));
        assert!(DiagInit::STANDARD.label().contains("N(0"));
    }
}
