//! Circulant SELL — Cheng et al. (2015), eq. (5): `Φ = D̃·R`.
//!
//! A circulant matrix `R` is diagonalized by the Fourier transform, so the
//! product is computed as a circular convolution via the FFT substrate.
//! The adaptive variant (this paper's framing) learns the defining vector
//! `r`; the `D̃` sign diagonal stays fixed random, as in the original.
//!
//! [`DiagonalCirculantLayer`] is the trainable extension: the
//! diagonal-circulant block `y = conv(x ⊙ signs, r) ⊙ d` of Araujo et al.
//! (2019, arXiv:1901.10255), with both `r` and the output diagonal `d`
//! learned. A single fixed-sign block cannot represent matrices whose
//! dominant component is rank-1 (the signs force sign changes across rows),
//! so the trainable family is a depth-K [`DiagonalCirculantCascade`] — the
//! deep diagonal-circulant network of 1901.10255, where K ≥ 2 already
//! removes the obstruction.

use std::sync::Arc;

use super::init::DiagInit;
use super::LinearOp;
use crate::dct::fft::FftPlan;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// `y = (x ⊙ signs) ⊛ r` — sign flip then circular convolution with `r`.
#[derive(Debug, Clone)]
pub struct CirculantLayer {
    /// Fixed random ±1 diagonal D̃.
    pub signs: Vec<f32>,
    /// Learned circulant-defining vector (first row of R).
    pub r: Vec<f32>,
    plan: Arc<FftPlan>,
    /// Cached spectrum of r (invalidated by `set_r`).
    r_spec: (Vec<f32>, Vec<f32>),
}

impl CirculantLayer {
    /// Layer from an explicit sign diagonal and defining vector.
    pub fn new(signs: Vec<f32>, r: Vec<f32>) -> CirculantLayer {
        let n = r.len();
        assert_eq!(signs.len(), n);
        let plan = Arc::new(FftPlan::new(n));
        let mut layer = CirculantLayer {
            signs,
            r,
            plan,
            r_spec: (vec![], vec![]),
        };
        layer.refresh_spectrum();
        layer
    }

    /// Random layer: ±1 signs, Gaussian r scaled like a dense init.
    pub fn random(n: usize, rng: &mut Pcg32) -> CirculantLayer {
        let std = 1.0 / (n as f64).sqrt();
        CirculantLayer::new(rng.sign_vec(n), rng.normal_vec(n, 0.0, std))
    }

    /// Replace the defining vector (refreshes the cached spectrum).
    pub fn set_r(&mut self, r: Vec<f32>) {
        assert_eq!(r.len(), self.r.len());
        self.r = r;
        self.refresh_spectrum();
    }

    fn refresh_spectrum(&mut self) {
        let n = self.r.len();
        let mut re = self.r.clone();
        let mut im = vec![0.0f32; n];
        self.plan.forward(&mut re, &mut im);
        self.r_spec = (re, im);
    }

    /// Circular convolution of one (sign-flipped) row with r, via FFT.
    fn convolve_row(&self, x: &[f32], out: &mut [f32]) {
        let n = x.len();
        let mut re: Vec<f32> = x
            .iter()
            .zip(&self.signs)
            .map(|(&v, &s)| v * s)
            .collect();
        let mut im = vec![0.0f32; n];
        self.plan.forward(&mut re, &mut im);
        let (rr, ri) = (&self.r_spec.0, &self.r_spec.1);
        for i in 0..n {
            let (ar, ai) = (re[i], im[i]);
            re[i] = ar * rr[i] - ai * ri[i];
            im[i] = ar * ri[i] + ai * rr[i];
        }
        self.plan.inverse(&mut re, &mut im);
        out.copy_from_slice(&re);
    }
}

impl LinearOp for CirculantLayer {
    fn width(&self) -> usize {
        self.r.len()
    }

    fn param_count(&self) -> usize {
        self.r.len() // only r is learned; signs are fixed random
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let n = self.width();
        assert_eq!(x.cols(), n);
        let mut out = Tensor::zeros(&[x.rows(), n]);
        for rix in 0..x.rows() {
            let src = x.row(rix).to_vec();
            self.convolve_row(&src, out.row_mut(rix));
        }
        out
    }

    fn name(&self) -> &'static str {
        "circulant"
    }
}

/// Gradients of one [`DiagonalCirculantLayer`], summed over batch rows.
#[derive(Debug, Clone)]
pub struct DiagonalCirculantGrads {
    /// ∂L/∂r.
    pub r: Vec<f32>,
    /// ∂L/∂d.
    pub d: Vec<f32>,
}

/// Trainable diagonal-circulant block (Araujo et al. 2019, eq. 2):
/// `y = conv(x ⊙ signs, r) ⊙ d` with learned `r` and `d`, fixed ±1 signs.
///
/// Unlike the serve-only [`CirculantLayer`], the spectrum of `r` is *not*
/// cached: the trainer mutates `r` in place every step, and recomputing
/// one length-n FFT per forward keeps the layer impossible to desync and
/// bit-exactly deterministic for the checkpoint/serve comparison tests.
#[derive(Debug, Clone)]
pub struct DiagonalCirculantLayer {
    /// Fixed random ±1 input diagonal D̃ (Cheng et al. 2015).
    pub signs: Vec<f32>,
    /// Learned circulant-defining vector (first column of R).
    pub r: Vec<f32>,
    /// Learned output diagonal (Araujo et al. 2019).
    pub d: Vec<f32>,
    plan: Arc<FftPlan>,
}

impl DiagonalCirculantLayer {
    /// Layer from explicit parts. `n` must be a power of two (FFT substrate);
    /// every `signs` entry must be exactly ±1.
    pub fn new(signs: Vec<f32>, r: Vec<f32>, d: Vec<f32>) -> DiagonalCirculantLayer {
        let n = r.len();
        assert_eq!(signs.len(), n);
        assert_eq!(d.len(), n);
        assert!(
            signs.iter().all(|&s| s == 1.0 || s == -1.0),
            "signs must be exactly ±1"
        );
        let plan = Arc::new(FftPlan::new(n));
        DiagonalCirculantLayer { signs, r, d, plan }
    }

    /// Identity-flavored trainable init: `r = mean·e₀ + σ·noise`,
    /// `d = mean·1 + σ·noise`. With `DiagInit::IDENTITY` the layer is
    /// exactly `x ⊙ signs`; the paper's §6 recipe (mean 1, small σ) keeps
    /// deep cascades trainable the same way it does for ACDC.
    pub fn init(n: usize, init: DiagInit, rng: &mut Pcg32) -> DiagonalCirculantLayer {
        let signs = rng.sign_vec(n);
        let mut r = rng.normal_vec(n, 0.0, init.sigma);
        r[0] += init.mean as f32;
        let d = rng.normal_vec(n, init.mean, init.sigma);
        DiagonalCirculantLayer::new(signs, r, d)
    }

    /// Width n.
    pub fn n(&self) -> usize {
        self.r.len()
    }

    /// `out = conv(x ⊙ signs, r)` for one row (no `d`): the pre-diagonal
    /// activation, also needed by the backward pass.
    fn convolve_row(&self, x: &[f32], r_spec: &(Vec<f32>, Vec<f32>), out: &mut [f32]) {
        let n = x.len();
        let mut re: Vec<f32> = x.iter().zip(&self.signs).map(|(&v, &s)| v * s).collect();
        let mut im = vec![0.0f32; n];
        self.plan.forward(&mut re, &mut im);
        for i in 0..n {
            let (ar, ai) = (re[i], im[i]);
            re[i] = ar * r_spec.0[i] - ai * r_spec.1[i];
            im[i] = ar * r_spec.1[i] + ai * r_spec.0[i];
        }
        self.plan.inverse(&mut re, &mut im);
        out.copy_from_slice(&re);
    }

    fn r_spectrum(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.n();
        let mut re = self.r.clone();
        let mut im = vec![0.0f32; n];
        self.plan.forward(&mut re, &mut im);
        (re, im)
    }

    /// Batched backward. Returns `(∂L/∂x, grads)` with parameter gradients
    /// summed over rows.
    ///
    /// With `v = x ⊙ signs`, `c = conv(v, r)`, `y = c ⊙ d`:
    ///   ∂L/∂d  = Σ_rows gy ⊙ c
    ///   gc     = gy ⊙ d
    ///   ∂L/∂r  = Σ_rows corr(gc, v)      (circular cross-correlation)
    ///   ∂L/∂x  = corr(gc, r) ⊙ signs
    /// Correlations ride the same FFT: `corr(a, b) = IFFT(FFT(a)·conj(FFT(b)))`.
    /// The row sum for ∂L/∂r is taken in the spectral domain (IFFT is
    /// linear), so the whole backward is three FFTs + one IFFT per row
    /// plus a single final IFFT.
    pub fn backward(&self, x: &Tensor, gy: &Tensor) -> (Tensor, DiagonalCirculantGrads) {
        let n = self.n();
        assert_eq!(x.cols(), n);
        assert_eq!(gy.shape(), x.shape());
        let rows = x.rows();
        let r_spec = self.r_spectrum();
        let mut gx = Tensor::zeros(&[rows, n]);
        let mut gd = vec![0.0f32; n];
        // Accumulated spectrum of Σ_rows FFT(gc)·conj(FFT(v)).
        let (mut acc_re, mut acc_im) = (vec![0.0f32; n], vec![0.0f32; n]);
        let mut c = vec![0.0f32; n];
        for rix in 0..rows {
            let xr = x.row(rix);
            self.convolve_row(xr, &r_spec, &mut c);
            let gyr = gy.row(rix);
            // v = x ⊙ signs, spectral.
            let mut v_re: Vec<f32> = xr.iter().zip(&self.signs).map(|(&a, &s)| a * s).collect();
            let mut v_im = vec![0.0f32; n];
            self.plan.forward(&mut v_re, &mut v_im);
            // gc = gy ⊙ d, spectral; dd accumulates in the signal domain.
            let mut gc_re = vec![0.0f32; n];
            let mut gc_im = vec![0.0f32; n];
            for i in 0..n {
                gd[i] += gyr[i] * c[i];
                gc_re[i] = gyr[i] * self.d[i];
            }
            self.plan.forward(&mut gc_re, &mut gc_im);
            // dr spectrum += GC · conj(V).
            for i in 0..n {
                acc_re[i] += gc_re[i] * v_re[i] + gc_im[i] * v_im[i];
                acc_im[i] += gc_im[i] * v_re[i] - gc_re[i] * v_im[i];
            }
            // gx = IFFT(GC · conj(R)) ⊙ signs.
            for i in 0..n {
                let (ar, ai) = (gc_re[i], gc_im[i]);
                gc_re[i] = ar * r_spec.0[i] + ai * r_spec.1[i];
                gc_im[i] = ai * r_spec.0[i] - ar * r_spec.1[i];
            }
            self.plan.inverse(&mut gc_re, &mut gc_im);
            let dst = gx.row_mut(rix);
            for i in 0..n {
                dst[i] = gc_re[i] * self.signs[i];
            }
        }
        self.plan.inverse(&mut acc_re, &mut acc_im);
        (gx, DiagonalCirculantGrads { r: acc_re, d: gd })
    }
}

impl LinearOp for DiagonalCirculantLayer {
    fn width(&self) -> usize {
        self.n()
    }

    fn param_count(&self) -> usize {
        2 * self.n() // r and d are learned; signs are fixed random
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let n = self.n();
        assert_eq!(x.cols(), n);
        let r_spec = self.r_spectrum();
        let mut out = Tensor::zeros(&[x.rows(), n]);
        let mut c = vec![0.0f32; n];
        for rix in 0..x.rows() {
            self.convolve_row(x.row(rix), &r_spec, &mut c);
            let dst = out.row_mut(rix);
            for i in 0..n {
                dst[i] = c[i] * self.d[i];
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "diagonal-circulant"
    }
}

/// Depth-K stack of [`DiagonalCirculantLayer`]s — the deep diagonal-
/// circulant network of Araujo et al. (2019). The trainable `circulant`
/// model kind; K ≥ 2 is required to fit general dense targets because a
/// single fixed-sign block has a rank-1 representational obstruction.
#[derive(Debug, Clone)]
pub struct DiagonalCirculantCascade {
    /// Layers applied first-to-last.
    pub layers: Vec<DiagonalCirculantLayer>,
}

impl DiagonalCirculantCascade {
    /// Cascade from explicit layers (non-empty, equal widths).
    pub fn new(layers: Vec<DiagonalCirculantLayer>) -> DiagonalCirculantCascade {
        assert!(!layers.is_empty());
        let n = layers[0].n();
        assert!(layers.iter().all(|l| l.n() == n));
        DiagonalCirculantCascade { layers }
    }

    /// K identity-flavored layers (the trainer's init path).
    pub fn init(n: usize, k: usize, init: DiagInit, rng: &mut Pcg32) -> DiagonalCirculantCascade {
        DiagonalCirculantCascade::new(
            (0..k.max(1))
                .map(|_| DiagonalCirculantLayer::init(n, init, rng))
                .collect(),
        )
    }

    /// Width n.
    pub fn n(&self) -> usize {
        self.layers[0].n()
    }

    /// Depth K.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Forward that also returns each layer's input — the activation cache
    /// consumed by [`DiagonalCirculantCascade::backward`].
    pub fn forward_train(&self, x: &Tensor) -> (Tensor, Vec<Tensor>) {
        let mut acts = Vec::with_capacity(self.layers.len());
        let mut cur = x.clone();
        for layer in &self.layers {
            let next = layer.forward(&cur);
            acts.push(cur);
            cur = next;
        }
        (cur, acts)
    }

    /// Backprop through the stack. `acts` is the cache from
    /// [`DiagonalCirculantCascade::forward_train`]; returns `(∂L/∂x, grads)`
    /// with one [`DiagonalCirculantGrads`] per layer, first-to-last.
    pub fn backward(&self, acts: &[Tensor], gy: &Tensor) -> (Tensor, Vec<DiagonalCirculantGrads>) {
        assert_eq!(acts.len(), self.layers.len());
        let mut grads = Vec::with_capacity(self.layers.len());
        let mut g = gy.clone();
        for (i, layer) in self.layers.iter().enumerate().rev() {
            let (gx, lg) = layer.backward(&acts[i], &g);
            grads.push(lg);
            g = gx;
        }
        grads.reverse();
        (g, grads)
    }
}

impl LinearOp for DiagonalCirculantCascade {
    fn width(&self) -> usize {
        self.n()
    }

    fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = layer.forward(&cur);
        }
        cur
    }

    fn name(&self) -> &'static str {
        "diagonal-circulant-cascade"
    }
}

/// O(N²) oracle: y_j = Σ_i v_i · r_{(j-i) mod n} with v = x ⊙ signs.
pub fn naive_circulant(signs: &[f32], r: &[f32], x: &[f32]) -> Vec<f32> {
    let n = r.len();
    let v: Vec<f64> = x
        .iter()
        .zip(signs)
        .map(|(&a, &s)| (a * s) as f64)
        .collect();
    (0..n)
        .map(|j| {
            (0..n)
                .map(|i| v[i] * r[(j + n - i) % n] as f64)
                .sum::<f64>() as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive_oracle() {
        let mut rng = Pcg32::seeded(1);
        for n in [4usize, 16, 64] {
            let layer = CirculantLayer::random(n, &mut rng);
            let x = rng.normal_vec(n, 0.0, 1.0);
            let want = naive_circulant(&layer.signs, &layer.r, &x);
            let got = layer.forward(&Tensor::from_vec(&[1, n], x));
            for i in 0..n {
                assert!((got.data()[i] - want[i]).abs() < 1e-3, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn delta_r_gives_shifted_signs() {
        // r = e_0 makes R = I, so y = x ⊙ signs.
        let n = 8;
        let mut rng = Pcg32::seeded(2);
        let signs = rng.sign_vec(n);
        let mut r = vec![0.0; n];
        r[0] = 1.0;
        let layer = CirculantLayer::new(signs.clone(), r);
        let x = rng.normal_vec(n, 0.0, 1.0);
        let y = layer.forward(&Tensor::from_vec(&[1, n], x.clone()));
        for i in 0..n {
            assert!((y.data()[i] - x[i] * signs[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn param_count_is_n() {
        let mut rng = Pcg32::seeded(3);
        let layer = CirculantLayer::random(32, &mut rng);
        assert_eq!(layer.param_count(), 32);
    }

    #[test]
    fn linear_in_x() {
        let mut rng = Pcg32::seeded(4);
        let n = 16;
        let layer = CirculantLayer::random(n, &mut rng);
        let x1 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let x2 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let lhs = layer.forward(&x1.add(&x2));
        let rhs = layer.forward(&x1).add(&layer.forward(&x2));
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn diagonal_circulant_matches_naive_oracle() {
        let mut rng = Pcg32::seeded(6);
        for n in [4usize, 16, 64] {
            let layer = DiagonalCirculantLayer::new(
                rng.sign_vec(n),
                rng.normal_vec(n, 0.0, 1.0),
                rng.normal_vec(n, 0.0, 1.0),
            );
            let x = rng.normal_vec(n, 0.0, 1.0);
            let conv = naive_circulant(&layer.signs, &layer.r, &x);
            let got = layer.forward(&Tensor::from_vec(&[1, n], x));
            for i in 0..n {
                let want = conv[i] * layer.d[i];
                assert!((got.data()[i] - want).abs() < 1e-3, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn identity_init_is_signed_identity() {
        let mut rng = Pcg32::seeded(7);
        let n = 16;
        let layer = DiagonalCirculantLayer::init(n, DiagInit::IDENTITY, &mut rng);
        let x = rng.normal_vec(n, 0.0, 1.0);
        let y = layer.forward(&Tensor::from_vec(&[1, n], x.clone()));
        for i in 0..n {
            assert!((y.data()[i] - x[i] * layer.signs[i]).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "signs must be exactly")]
    fn rejects_non_sign_diagonal() {
        DiagonalCirculantLayer::new(vec![0.5; 4], vec![0.0; 4], vec![1.0; 4]);
    }

    #[test]
    fn cascade_forward_train_matches_forward() {
        let mut rng = Pcg32::seeded(8);
        let n = 16;
        let cascade = DiagonalCirculantCascade::init(n, 3, DiagInit::CAFFENET, &mut rng);
        assert_eq!(cascade.param_count(), 2 * n * 3);
        let x = Tensor::from_vec(&[5, n], rng.normal_vec(5 * n, 0.0, 1.0));
        let (y, acts) = cascade.forward_train(&x);
        assert_eq!(acts.len(), 3);
        assert_eq!(y.max_abs_diff(&cascade.forward(&x)), 0.0);
        // Backward runs and shapes line up.
        let (gx, grads) = cascade.backward(&acts, &y);
        assert_eq!(gx.shape(), x.shape());
        assert_eq!(grads.len(), 3);
        assert!(grads.iter().all(|g| g.r.len() == n && g.d.len() == n));
    }

    #[test]
    fn backward_gradients_match_finite_differences() {
        // Full per-parameter FD coverage lives in tests/property_backward.rs;
        // this is the in-module smoke pin at one shape.
        let mut rng = Pcg32::seeded(9);
        let n = 8;
        let rows = 3;
        let mut layer = DiagonalCirculantLayer::new(
            rng.sign_vec(n),
            rng.normal_vec(n, 0.0, 0.7),
            rng.normal_vec(n, 0.5, 0.7),
        );
        let x = Tensor::from_vec(&[rows, n], rng.normal_vec(rows * n, 0.0, 1.0));
        let y = layer.forward(&x);
        let (_, grads) = layer.backward(&x, &y); // gy = y ⇒ L = ½Σy²
        let loss = |l: &DiagonalCirculantLayer| -> f64 {
            l.forward(&x).data().iter().map(|&v| 0.5 * v as f64 * v as f64).sum()
        };
        let eps = 1e-3;
        for i in 0..n {
            let keep = layer.r[i];
            layer.r[i] = keep + eps;
            let up = loss(&layer);
            layer.r[i] = keep - eps;
            let dn = loss(&layer);
            layer.r[i] = keep;
            let fd = (up - dn) / (2.0 * eps as f64);
            assert!((grads.r[i] as f64 - fd).abs() < 3e-2 * fd.abs().max(1.0), "r[{i}]");
            let keep = layer.d[i];
            layer.d[i] = keep + eps;
            let up = loss(&layer);
            layer.d[i] = keep - eps;
            let dn = loss(&layer);
            layer.d[i] = keep;
            let fd = (up - dn) / (2.0 * eps as f64);
            assert!((grads.d[i] as f64 - fd).abs() < 3e-2 * fd.abs().max(1.0), "d[{i}]");
        }
    }

    #[test]
    fn set_r_refreshes_spectrum() {
        let mut rng = Pcg32::seeded(5);
        let n = 8;
        let mut layer = CirculantLayer::random(n, &mut rng);
        let x = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let y1 = layer.forward(&x);
        let mut r2 = vec![0.0; n];
        r2[1] = 1.0; // shift-by-one circulant
        layer.set_r(r2);
        let y2 = layer.forward(&x);
        assert!(y1.max_abs_diff(&y2) > 1e-3);
    }
}
