//! Circulant SELL — Cheng et al. (2015), eq. (5): `Φ = D̃·R`.
//!
//! A circulant matrix `R` is diagonalized by the Fourier transform, so the
//! product is computed as a circular convolution via the FFT substrate.
//! The adaptive variant (this paper's framing) learns the defining vector
//! `r`; the `D̃` sign diagonal stays fixed random, as in the original.

use std::sync::Arc;

use super::LinearOp;
use crate::dct::fft::FftPlan;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// `y = (x ⊙ signs) ⊛ r` — sign flip then circular convolution with `r`.
#[derive(Debug, Clone)]
pub struct CirculantLayer {
    /// Fixed random ±1 diagonal D̃.
    pub signs: Vec<f32>,
    /// Learned circulant-defining vector (first row of R).
    pub r: Vec<f32>,
    plan: Arc<FftPlan>,
    /// Cached spectrum of r (invalidated by `set_r`).
    r_spec: (Vec<f32>, Vec<f32>),
}

impl CirculantLayer {
    /// Layer from an explicit sign diagonal and defining vector.
    pub fn new(signs: Vec<f32>, r: Vec<f32>) -> CirculantLayer {
        let n = r.len();
        assert_eq!(signs.len(), n);
        let plan = Arc::new(FftPlan::new(n));
        let mut layer = CirculantLayer {
            signs,
            r,
            plan,
            r_spec: (vec![], vec![]),
        };
        layer.refresh_spectrum();
        layer
    }

    /// Random layer: ±1 signs, Gaussian r scaled like a dense init.
    pub fn random(n: usize, rng: &mut Pcg32) -> CirculantLayer {
        let std = 1.0 / (n as f64).sqrt();
        CirculantLayer::new(rng.sign_vec(n), rng.normal_vec(n, 0.0, std))
    }

    /// Replace the defining vector (refreshes the cached spectrum).
    pub fn set_r(&mut self, r: Vec<f32>) {
        assert_eq!(r.len(), self.r.len());
        self.r = r;
        self.refresh_spectrum();
    }

    fn refresh_spectrum(&mut self) {
        let n = self.r.len();
        let mut re = self.r.clone();
        let mut im = vec![0.0f32; n];
        self.plan.forward(&mut re, &mut im);
        self.r_spec = (re, im);
    }

    /// Circular convolution of one (sign-flipped) row with r, via FFT.
    fn convolve_row(&self, x: &[f32], out: &mut [f32]) {
        let n = x.len();
        let mut re: Vec<f32> = x
            .iter()
            .zip(&self.signs)
            .map(|(&v, &s)| v * s)
            .collect();
        let mut im = vec![0.0f32; n];
        self.plan.forward(&mut re, &mut im);
        let (rr, ri) = (&self.r_spec.0, &self.r_spec.1);
        for i in 0..n {
            let (ar, ai) = (re[i], im[i]);
            re[i] = ar * rr[i] - ai * ri[i];
            im[i] = ar * ri[i] + ai * rr[i];
        }
        self.plan.inverse(&mut re, &mut im);
        out.copy_from_slice(&re);
    }
}

impl LinearOp for CirculantLayer {
    fn width(&self) -> usize {
        self.r.len()
    }

    fn param_count(&self) -> usize {
        self.r.len() // only r is learned; signs are fixed random
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        let n = self.width();
        assert_eq!(x.cols(), n);
        let mut out = Tensor::zeros(&[x.rows(), n]);
        for rix in 0..x.rows() {
            let src = x.row(rix).to_vec();
            self.convolve_row(&src, out.row_mut(rix));
        }
        out
    }

    fn name(&self) -> &'static str {
        "circulant"
    }
}

/// O(N²) oracle: y_j = Σ_i v_i · r_{(j-i) mod n} with v = x ⊙ signs.
pub fn naive_circulant(signs: &[f32], r: &[f32], x: &[f32]) -> Vec<f32> {
    let n = r.len();
    let v: Vec<f64> = x
        .iter()
        .zip(signs)
        .map(|(&a, &s)| (a * s) as f64)
        .collect();
    (0..n)
        .map(|j| {
            (0..n)
                .map(|i| v[i] * r[(j + n - i) % n] as f64)
                .sum::<f64>() as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive_oracle() {
        let mut rng = Pcg32::seeded(1);
        for n in [4usize, 16, 64] {
            let layer = CirculantLayer::random(n, &mut rng);
            let x = rng.normal_vec(n, 0.0, 1.0);
            let want = naive_circulant(&layer.signs, &layer.r, &x);
            let got = layer.forward(&Tensor::from_vec(&[1, n], x));
            for i in 0..n {
                assert!((got.data()[i] - want[i]).abs() < 1e-3, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn delta_r_gives_shifted_signs() {
        // r = e_0 makes R = I, so y = x ⊙ signs.
        let n = 8;
        let mut rng = Pcg32::seeded(2);
        let signs = rng.sign_vec(n);
        let mut r = vec![0.0; n];
        r[0] = 1.0;
        let layer = CirculantLayer::new(signs.clone(), r);
        let x = rng.normal_vec(n, 0.0, 1.0);
        let y = layer.forward(&Tensor::from_vec(&[1, n], x.clone()));
        for i in 0..n {
            assert!((y.data()[i] - x[i] * signs[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn param_count_is_n() {
        let mut rng = Pcg32::seeded(3);
        let layer = CirculantLayer::random(32, &mut rng);
        assert_eq!(layer.param_count(), 32);
    }

    #[test]
    fn linear_in_x() {
        let mut rng = Pcg32::seeded(4);
        let n = 16;
        let layer = CirculantLayer::random(n, &mut rng);
        let x1 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let x2 = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let lhs = layer.forward(&x1.add(&x2));
        let rhs = layer.forward(&x1).add(&layer.forward(&x2));
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn set_r_refreshes_spectrum() {
        let mut rng = Pcg32::seeded(5);
        let n = 8;
        let mut layer = CirculantLayer::random(n, &mut rng);
        let x = Tensor::from_vec(&[1, n], rng.normal_vec(n, 0.0, 1.0));
        let y1 = layer.forward(&x);
        let mut r2 = vec![0.0; n];
        r2[1] = 1.0; // shift-by-one circulant
        layer.set_r(r2);
        let y2 = layer.forward(&x);
        assert!(y1.max_abs_diff(&y2) > 1e-3);
    }
}
