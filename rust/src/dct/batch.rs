//! Batched structure-of-arrays (SoA) ACDC compute engine.
//!
//! The paper's §5 analysis shows the ACDC hot path is *memory-bound*: the
//! "single call" kernel wins because it touches each row once (8N bytes of
//! main-memory traffic per row — 4N in, 4N out; see DESIGN.md §4). The
//! scalar `DctPlan::dct2/dct3` path honours that traffic model but
//! transforms one row (or one packed pair) at a time, leaving batch-level
//! locality and SIMD on the table. This module is the batched counterpart,
//! the CPU analogue of cuFFT's batched transforms (DESIGN.md substitution
//! S3):
//!
//! * **Lane panels** — a `[rows, N]` batch is processed [`LANES`] rows at
//!   a time. Each panel is transposed into *structure-of-arrays* lanes:
//!   frequency bin `k` of all lanes lives contiguously at
//!   `buf[k*LANES .. (k+1)*LANES]`. Every inner loop of the transform then
//!   runs over the lane dimension with unit stride — trivially
//!   auto-vectorizable, and each twiddle load is amortized over [`LANES`]
//!   rows instead of one.
//! * **Fused Makhoul DCT** — the even/odd Makhoul reorder is folded into
//!   the transpose (pack/unpack), so the panel is read once and written
//!   once. One radix-2 FFT over the lanes replaces [`LANES`] scalar FFTs.
//! * **Fused `A`/`D`/bias** — [`BatchEngine::acdc_rows`] executes a whole
//!   `ACDC⁻¹` layer (`y = ((x ⊙ a)·C ⊙ d + bias)·Cᵀ`): the `a` scale rides
//!   the input pack, and `d`/`bias` ride the single twiddle stage between
//!   the forward post-twiddle and the inverse pre-twiddle. Intermediates
//!   never leave the panel scratch, so main memory sees exactly one load
//!   and one store per panel.
//! * **Panel parallelism** — [`BatchEngine::acdc_rows_parallel`] splits
//!   panels across the shared [`crate::util::threadpool`], the serving
//!   pool all SELL executors already use.
//!
//! Plans are cached process-wide in [`PlanCache`] so the gateway's serving
//! threads, the coordinator workers and every SELL variant share one
//! twiddle table per size.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::DctPlan;
use crate::util::threadpool::{split_ranges, ThreadPool};

/// Rows per SoA panel. Eight f32 lanes fill one 256-bit vector register;
/// the panel scratch for N=8192 (3 buffers × 8 lanes × 4 B) stays inside
/// L2. Exposed so callers (and the fastfood FWHT path) can size batches.
pub const LANES: usize = 8;

/// Below this many rows the scalar pair path (`DctPlan::dct2_pair`) wins:
/// a padded panel always computes all [`LANES`] lanes, so occupancy under
/// one half wastes more than the SoA layout saves.
pub const MIN_SOA_ROWS: usize = LANES / 2;

/// Process-wide `size → Arc<DctPlan>` cache.
///
/// Plan construction is O(N) trig plus an O(N²) lazily-built matrix;
/// serving threads, the batcher's executors and ad-hoc layer constructors
/// all want the same handful of power-of-two sizes. `get` hands out shared
/// handles so each size is built exactly once per process.
///
/// ```
/// use acdc::dct::PlanCache;
/// let a = PlanCache::get(64);
/// let b = PlanCache::get(64);
/// assert!(std::sync::Arc::ptr_eq(&a, &b)); // one plan per size, shared
/// ```
pub struct PlanCache;

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, Arc<DctPlan>>>> = OnceLock::new();

impl PlanCache {
    /// Shared plan for size `n` (built on first request). Panics if `n`
    /// is not a power of two, like [`DctPlan::new`].
    pub fn get(n: usize) -> Arc<DctPlan> {
        let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut guard = cache.lock().expect("plan cache poisoned");
        Arc::clone(guard.entry(n).or_insert_with(|| Arc::new(DctPlan::new(n))))
    }

    /// Sizes currently cached (ascending) — observability for tests and
    /// the `acdc info` diagnostics.
    pub fn cached_sizes() -> Vec<usize> {
        let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let guard = cache.lock().expect("plan cache poisoned");
        let mut sizes: Vec<usize> = guard.keys().copied().collect();
        sizes.sort_unstable();
        sizes
    }
}

/// Reusable per-panel scratch: three SoA buffers of `n × LANES` f32.
///
/// Allocated once per batch call (not per row, not per panel) and reused
/// across every panel, so the hot loop performs no allocation.
#[derive(Debug)]
pub struct PanelScratch {
    re: Vec<f32>,
    im: Vec<f32>,
    t: Vec<f32>,
}

impl PanelScratch {
    /// Scratch for panels of size `n`.
    pub fn new(n: usize) -> PanelScratch {
        PanelScratch {
            re: vec![0.0; n * LANES],
            im: vec![0.0; n * LANES],
            t: vec![0.0; n * LANES],
        }
    }
}

/// Batched SoA executor over a shared [`DctPlan`].
///
/// ```
/// use acdc::dct::{naive_dct2, BatchEngine};
/// let engine = BatchEngine::for_size(8);
/// let mut data = vec![0.0f32; 3 * 8];
/// data[0] = 1.0; // row 0 = impulse
/// let want = naive_dct2(&data[..8]);
/// engine.dct2_rows(&mut data, 3);
/// for k in 0..8 {
///     assert!((data[k] - want[k]).abs() < 1e-4);
/// }
/// ```
#[derive(Debug, Clone)]
pub struct BatchEngine {
    plan: Arc<DctPlan>,
}

impl BatchEngine {
    /// Engine over an existing plan handle.
    pub fn new(plan: Arc<DctPlan>) -> BatchEngine {
        BatchEngine { plan }
    }

    /// Engine over the process-wide cached plan for `n`.
    pub fn for_size(n: usize) -> BatchEngine {
        BatchEngine::new(PlanCache::get(n))
    }

    /// Transform size N.
    pub fn n(&self) -> usize {
        self.plan.len()
    }

    /// The underlying shared plan.
    pub fn plan(&self) -> &Arc<DctPlan> {
        &self.plan
    }

    // -- batch drivers ------------------------------------------------------

    /// Orthonormal DCT-II of every row of `data` (`[rows, n]` row-major),
    /// in place, through SoA panels.
    pub fn dct2_rows(&self, data: &mut [f32], rows: usize) {
        let n = self.n();
        assert_eq!(data.len(), rows * n, "data len vs rows × n");
        let mut s = PanelScratch::new(n);
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.dct2_panel(data, r, take, &mut s);
            r += take;
        }
    }

    /// Orthonormal DCT-III (inverse of [`BatchEngine::dct2_rows`]) of
    /// every row of `data`, in place, through SoA panels.
    pub fn dct3_rows(&self, data: &mut [f32], rows: usize) {
        let n = self.n();
        assert_eq!(data.len(), rows * n, "data len vs rows × n");
        let mut s = PanelScratch::new(n);
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.dct3_panel(data, r, take, &mut s);
            r += take;
        }
    }

    /// Fused `ACDC⁻¹` layer over a batch:
    /// `out[r] = ((x[r] ⊙ a)·C ⊙ d + bias)·Cᵀ` for every row, one panel
    /// load and one panel store of main-memory traffic (§5's 8N bytes per
    /// row once `a`/`d`/`bias` are cache-resident).
    pub fn acdc_rows(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        rows: usize,
    ) {
        let n = self.n();
        assert_eq!(a.len(), n);
        assert_eq!(d.len(), n);
        assert_eq!(bias.len(), n);
        assert_eq!(x.len(), rows * n, "x len vs rows × n");
        assert_eq!(out.len(), rows * n, "out len vs rows × n");
        let mut s = PanelScratch::new(n);
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.acdc_panel(a, d, bias, x, out, r, take, &mut s);
            r += take;
        }
    }

    /// [`BatchEngine::acdc_rows`] with panels split across `pool` — the
    /// serving path's thread-level parallelism. Falls back to the serial
    /// driver when the batch or pool is too small to amortize dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn acdc_rows_parallel(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        rows: usize,
        pool: &ThreadPool,
    ) {
        let n = self.n();
        assert_eq!(x.len(), rows * n, "x len vs rows × n");
        assert_eq!(out.len(), rows * n, "out len vs rows × n");
        let panels = rows.div_ceil(LANES);
        let parts = pool.size().min(panels);
        if parts <= 1 {
            return self.acdc_rows(a, d, bias, x, out, rows);
        }
        // Contiguous, disjoint row ranges on panel boundaries.
        let row_ranges: Vec<std::ops::Range<usize>> = split_ranges(panels, parts)
            .into_iter()
            .map(|p| (p.start * LANES)..(p.end * LANES).min(rows))
            .collect();
        struct Bufs {
            x: *const f32,
            out: *mut f32,
        }
        // SAFETY: the pointers are only dereferenced inside pool jobs, and
        // `ThreadPool::map` joins every job before returning, so the
        // borrows cannot outlive this call's `x`/`out` arguments.
        unsafe impl Send for Bufs {}
        unsafe impl Sync for Bufs {}
        let bufs = Arc::new(Bufs {
            x: x.as_ptr(),
            out: out.as_mut_ptr(),
        });
        let engine = self.clone();
        let params = Arc::new((a.to_vec(), d.to_vec(), bias.to_vec()));
        let ranges = Arc::new(row_ranges);
        pool.map(parts, move |i| {
            let r = ranges[i].clone();
            let count = r.end - r.start;
            // SAFETY: ranges are pairwise disjoint, so each job builds the
            // only mutable view of its own output rows; the shared input
            // view is read-only. Both stay within the caller's buffers
            // (r.end ≤ rows) and die before `map` returns.
            let (x_part, out_part) = unsafe {
                (
                    std::slice::from_raw_parts(bufs.x.add(r.start * n), count * n),
                    std::slice::from_raw_parts_mut(bufs.out.add(r.start * n), count * n),
                )
            };
            engine.acdc_rows(&params.0, &params.1, &params.2, x_part, out_part, count);
        });
    }

    // -- panel kernels ------------------------------------------------------

    /// Makhoul pack + transpose of rows `r0..r0+take` into SoA `re` lanes
    /// (`re[j*LANES + l] = row_l[2j]`, `re[(n-1-j)*LANES + l] = row_l[2j+1]`),
    /// optionally fusing a per-element `scale` (the ACDC `a` diagonal).
    /// Unused lanes are zero-filled, so padded tail panels stay exact.
    fn pack(&self, x: &[f32], r0: usize, take: usize, scale: Option<&[f32]>, re: &mut [f32]) {
        let n = self.n();
        re.fill(0.0);
        for l in 0..take {
            let row = &x[(r0 + l) * n..(r0 + l + 1) * n];
            if n == 1 {
                re[l] = row[0] * scale.map_or(1.0, |s| s[0]);
                continue;
            }
            match scale {
                Some(s) => {
                    for j in 0..n / 2 {
                        re[j * LANES + l] = row[2 * j] * s[2 * j];
                        re[(n - 1 - j) * LANES + l] = row[2 * j + 1] * s[2 * j + 1];
                    }
                }
                None => {
                    for j in 0..n / 2 {
                        re[j * LANES + l] = row[2 * j];
                        re[(n - 1 - j) * LANES + l] = row[2 * j + 1];
                    }
                }
            }
        }
    }

    /// Inverse of [`BatchEngine::pack`]: un-reorder SoA `re` lanes back
    /// into rows `r0..r0+take` of `out`.
    fn unpack(&self, re: &[f32], out: &mut [f32], r0: usize, take: usize) {
        let n = self.n();
        for l in 0..take {
            let row = &mut out[(r0 + l) * n..(r0 + l + 1) * n];
            if n == 1 {
                row[0] = re[l];
                continue;
            }
            for j in 0..n / 2 {
                row[2 * j] = re[j * LANES + l];
                row[2 * j + 1] = re[(n - 1 - j) * LANES + l];
            }
        }
    }

    /// DCT-II of one panel, in place in `data`.
    fn dct2_panel(&self, data: &mut [f32], r0: usize, take: usize, s: &mut PanelScratch) {
        let n = self.n();
        let (rev, twr, twi) = self.plan.fft.tables();
        self.pack(data, r0, take, None, &mut s.re);
        s.im.fill(0.0);
        fft_soa(&mut s.re, &mut s.im, n, rev, twr, twi, false);
        // Forward post-twiddle: X[k] = Re((fw_re + i·fw_im)·Z[k]).
        for k in 0..n {
            let (fr, fi) = (self.plan.fw_re[k], self.plan.fw_im[k]);
            let re = lane(&s.re, k);
            let im = lane(&s.im, k);
            let t = lane_mut(&mut s.t, k);
            for l in 0..LANES {
                t[l] = fr * re[l] - fi * im[l];
            }
        }
        // Plain transpose out (frequency order, no Makhoul reorder).
        for l in 0..take {
            let row = &mut data[(r0 + l) * n..(r0 + l + 1) * n];
            for (k, v) in row.iter_mut().enumerate() {
                *v = s.t[k * LANES + l];
            }
        }
    }

    /// DCT-III of one panel, in place in `data`.
    fn dct3_panel(&self, data: &mut [f32], r0: usize, take: usize, s: &mut PanelScratch) {
        let n = self.n();
        let (rev, twr, twi) = self.plan.fft.tables();
        // Plain transpose in (zero the padded lanes).
        s.t.fill(0.0);
        for l in 0..take {
            let row = &data[(r0 + l) * n..(r0 + l + 1) * n];
            for (k, &v) in row.iter().enumerate() {
                s.t[k * LANES + l] = v;
            }
        }
        self.dct3_twiddle_from_t(s);
        fft_soa(&mut s.re, &mut s.im, n, rev, twr, twi, true);
        self.unpack(&s.re, data, r0, take);
    }

    /// Inverse pre-twiddle: `V[k] = (bw_re + i·bw_im)[k] · (t[k] - i·t[n-k])`
    /// (with `t[n] ≡ 0`), from `s.t` into `s.re`/`s.im`.
    fn dct3_twiddle_from_t(&self, s: &mut PanelScratch) {
        let n = self.n();
        for k in 0..n {
            let (br, bi) = (self.plan.bw_re[k], self.plan.bw_im[k]);
            let re = lane_mut(&mut s.re, k);
            let im = lane_mut(&mut s.im, k);
            if k == 0 {
                let tk = lane(&s.t, 0);
                for l in 0..LANES {
                    re[l] = br * tk[l];
                    im[l] = bi * tk[l];
                }
            } else {
                let tk = lane(&s.t, k);
                let tnk = lane(&s.t, n - k);
                for l in 0..LANES {
                    re[l] = br * tk[l] + bi * tnk[l];
                    im[l] = bi * tk[l] - br * tnk[l];
                }
            }
        }
    }

    /// One fused `ACDC⁻¹` panel: pack(⊙a) → FFT → post-twiddle ⊙d +bias →
    /// pre-twiddle → inverse FFT → unpack. All intermediates stay in `s`.
    #[allow(clippy::too_many_arguments)]
    fn acdc_panel(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        r0: usize,
        take: usize,
        s: &mut PanelScratch,
    ) {
        let n = self.n();
        let (rev, twr, twi) = self.plan.fft.tables();
        self.pack(x, r0, take, Some(a), &mut s.re);
        s.im.fill(0.0);
        fft_soa(&mut s.re, &mut s.im, n, rev, twr, twi, false);
        // Fused middle stage: h3[k] = (fw·Z)[k] ⊙ d[k] + bias[k].
        for k in 0..n {
            let (fr, fi) = (self.plan.fw_re[k], self.plan.fw_im[k]);
            let (dk, bk) = (d[k], bias[k]);
            let re = lane(&s.re, k);
            let im = lane(&s.im, k);
            let t = lane_mut(&mut s.t, k);
            for l in 0..LANES {
                t[l] = (fr * re[l] - fi * im[l]) * dk + bk;
            }
        }
        self.dct3_twiddle_from_t(s);
        fft_soa(&mut s.re, &mut s.im, n, rev, twr, twi, true);
        self.unpack(&s.re, out, r0, take);
    }
}

/// Radix-2 complex FFT over SoA lane buffers: element `(k, l)` lives at
/// `k*LANES + l`. Identical schedule (bit-reversal + Danielson–Lanczos,
/// shared twiddle tables) to the scalar [`crate::dct::fft::FftPlan`], with
/// the butterfly applied to all [`LANES`] lanes per twiddle load. The
/// inverse includes the 1/n scaling, matching `FftPlan::inverse`.
fn fft_soa(
    re: &mut [f32],
    im: &mut [f32],
    n: usize,
    rev: &[u32],
    tw_re: &[f32],
    tw_im: &[f32],
    invert: bool,
) {
    debug_assert_eq!(re.len(), n * LANES);
    debug_assert_eq!(im.len(), n * LANES);
    if n == 1 {
        return;
    }
    // Bit-reversal reorder of whole lane blocks.
    for i in 0..n {
        let j = rev[i] as usize;
        if i < j {
            for l in 0..LANES {
                re.swap(i * LANES + l, j * LANES + l);
                im.swap(i * LANES + l, j * LANES + l);
            }
        }
    }
    // Danielson–Lanczos stages, lanes innermost.
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        let step = n / len;
        for start in (0..n).step_by(len) {
            let mut tidx = 0;
            for k in start..start + half {
                let wr = tw_re[tidx];
                let wi = if invert { -tw_im[tidx] } else { tw_im[tidx] };
                let m = k + half;
                // Disjoint lane blocks at k and m (k < m always).
                let (re_k, re_m) = lane_pair(re, k, m);
                let (im_k, im_m) = lane_pair(im, k, m);
                for l in 0..LANES {
                    let xr = re_m[l] * wr - im_m[l] * wi;
                    let xi = re_m[l] * wi + im_m[l] * wr;
                    re_m[l] = re_k[l] - xr;
                    im_m[l] = im_k[l] - xi;
                    re_k[l] += xr;
                    im_k[l] += xi;
                }
                tidx += step;
            }
        }
        len <<= 1;
    }
    if invert {
        let inv = 1.0 / n as f32;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }
}

/// Shared lane block at bin `k` as a fixed-size array reference (the
/// known length lets LLVM elide bounds checks and vectorize the 8-wide
/// lane loops).
#[inline]
pub(crate) fn lane(buf: &[f32], k: usize) -> &[f32; LANES] {
    (&buf[k * LANES..(k + 1) * LANES]).try_into().unwrap()
}

/// Mutable lane block at bin `k` as a fixed-size array reference.
#[inline]
pub(crate) fn lane_mut(buf: &mut [f32], k: usize) -> &mut [f32; LANES] {
    (&mut buf[k * LANES..(k + 1) * LANES]).try_into().unwrap()
}

/// Two disjoint mutable lane blocks at bins `k < m` of one SoA buffer.
#[inline]
fn lane_pair(buf: &mut [f32], k: usize, m: usize) -> (&mut [f32; LANES], &mut [f32; LANES]) {
    debug_assert!(k < m);
    let (head, tail) = buf.split_at_mut(m * LANES);
    (
        (&mut head[k * LANES..(k + 1) * LANES]).try_into().unwrap(),
        (&mut tail[..LANES]).try_into().unwrap(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::{naive_dct2, naive_dct3};
    use crate::util::rng::Pcg32;

    #[test]
    fn plan_cache_shares_one_plan_per_size() {
        let a = PlanCache::get(32);
        let b = PlanCache::get(32);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(PlanCache::cached_sizes().contains(&32));
    }

    #[test]
    fn dct2_rows_matches_oracle_across_panel_shapes() {
        let mut rng = Pcg32::seeded(1);
        for n in [1usize, 2, 8, 64] {
            let engine = BatchEngine::for_size(n);
            for rows in [1usize, 3, 8, 9, 16, 17] {
                let orig = rng.normal_vec(rows * n, 0.0, 1.0);
                let mut data = orig.clone();
                engine.dct2_rows(&mut data, rows);
                for r in 0..rows {
                    let want = naive_dct2(&orig[r * n..(r + 1) * n]);
                    for k in 0..n {
                        assert!(
                            (data[r * n + k] - want[k]).abs() < 1e-4,
                            "n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dct3_rows_matches_oracle() {
        let mut rng = Pcg32::seeded(2);
        for n in [2usize, 8, 64] {
            let engine = BatchEngine::for_size(n);
            for rows in [1usize, 5, 11] {
                let orig = rng.normal_vec(rows * n, 0.0, 1.0);
                let mut data = orig.clone();
                engine.dct3_rows(&mut data, rows);
                for r in 0..rows {
                    let want = naive_dct3(&orig[r * n..(r + 1) * n]);
                    for k in 0..n {
                        assert!(
                            (data[r * n + k] - want[k]).abs() < 1e-4,
                            "n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn soa_roundtrip_dct3_of_dct2_is_identity() {
        let mut rng = Pcg32::seeded(3);
        for n in [2usize, 16, 128] {
            let engine = BatchEngine::for_size(n);
            let rows = 13;
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            engine.dct2_rows(&mut data, rows);
            engine.dct3_rows(&mut data, rows);
            for i in 0..rows * n {
                assert!((data[i] - orig[i]).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn fused_acdc_matches_unfused_chain() {
        let mut rng = Pcg32::seeded(4);
        for n in [2usize, 8, 64, 256] {
            let engine = BatchEngine::for_size(n);
            let rows = 9;
            let a = rng.normal_vec(n, 1.0, 0.3);
            let d = rng.normal_vec(n, 1.0, 0.3);
            let bias = rng.normal_vec(n, 0.0, 0.2);
            let x = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut got = vec![0.0f32; rows * n];
            engine.acdc_rows(&a, &d, &bias, &x, &mut got, rows);
            // Unfused: scale, dct2_rows, scale+bias, dct3_rows.
            let mut want: Vec<f32> = x
                .chunks(n)
                .flat_map(|row| row.iter().zip(&a).map(|(&v, &av)| v * av))
                .collect();
            engine.dct2_rows(&mut want, rows);
            for r in 0..rows {
                for k in 0..n {
                    want[r * n + k] = want[r * n + k] * d[k] + bias[k];
                }
            }
            engine.dct3_rows(&mut want, rows);
            for i in 0..rows * n {
                assert!((got[i] - want[i]).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg32::seeded(5);
        let n = 64;
        let rows = 67; // several panels + ragged tail
        let engine = BatchEngine::for_size(n);
        let a = rng.normal_vec(n, 1.0, 0.2);
        let d = rng.normal_vec(n, 1.0, 0.2);
        let bias = rng.normal_vec(n, 0.0, 0.2);
        let x = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut serial = vec![0.0f32; rows * n];
        engine.acdc_rows(&a, &d, &bias, &x, &mut serial, rows);
        let pool = ThreadPool::new(4);
        let mut parallel = vec![0.0f32; rows * n];
        engine.acdc_rows_parallel(&a, &d, &bias, &x, &mut parallel, rows, &pool);
        assert_eq!(serial, parallel, "panel split must be bit-identical");
    }

    #[test]
    fn parallel_small_batch_falls_back_to_serial() {
        let mut rng = Pcg32::seeded(6);
        let n = 16;
        let rows = 3;
        let engine = BatchEngine::for_size(n);
        let a = vec![1.0; n];
        let d = vec![1.0; n];
        let bias = vec![0.0; n];
        let x = rng.normal_vec(rows * n, 0.0, 1.0);
        let pool = ThreadPool::new(4);
        let mut out = vec![0.0f32; rows * n];
        engine.acdc_rows_parallel(&a, &d, &bias, &x, &mut out, rows, &pool);
        // identity layer → output equals input
        for i in 0..rows * n {
            assert!((out[i] - x[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn size_one_engine_is_exact() {
        let engine = BatchEngine::for_size(1);
        let mut data = vec![2.0f32, -3.0, 0.5];
        engine.dct2_rows(&mut data, 3);
        assert_eq!(data, vec![2.0, -3.0, 0.5]); // 1-point orthonormal DCT = id
        let a = vec![2.0f32];
        let d = vec![0.5f32];
        let bias = vec![1.0f32];
        let x = vec![3.0f32, 4.0];
        let mut out = vec![0.0f32; 2];
        engine.acdc_rows(&a, &d, &bias, &x, &mut out, 2);
        // y = x·a·d + bias (all transforms identity at n=1)
        assert!((out[0] - 4.0).abs() < 1e-6);
        assert!((out[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn scalar_and_soa_paths_agree() {
        // The two execution strategies must be numerically interchangeable.
        let mut rng = Pcg32::seeded(7);
        let n = 128;
        let rows = 10;
        let plan = PlanCache::get(n);
        let engine = BatchEngine::new(Arc::clone(&plan));
        let orig = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut soa = orig.clone();
        engine.dct2_rows(&mut soa, rows);
        let mut scalar = orig;
        plan.dct2_rows(&mut scalar, rows);
        for i in 0..rows * n {
            assert!((soa[i] - scalar[i]).abs() < 1e-4, "i={i}");
        }
    }
}
